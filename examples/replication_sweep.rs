//! Replication-sweep scenario (DESIGN.md §11): the paper's "advantage grows
//! with scale" claim applied to the replication axis of the newsvendor
//! task.
//!
//! For each problem size, an R-replication experiment runs twice through
//! the coordinator — once with the sequential per-replication protocol,
//! once through the batched replication engine — and prints the timing
//! curve plus a bit-reproducibility check (same seed ⇒ identical
//! objectives in both modes, by construction of the stream subtrees).
//!
//!     cargo run --release --example replication_sweep [-- sizes...]
//!
//! Environment knobs: SIMOPT_SWEEP_REPS (default 8), SIMOPT_SWEEP_EPOCHS
//! (default 4).

use simopt::config::{BackendKind, ExecMode, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = {
        let args: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if args.is_empty() { vec![64, 256, 1024] } else { args }
    };
    let reps = env("SIMOPT_SWEEP_REPS", 8);
    let epochs = env("SIMOPT_SWEEP_EPOCHS", 4);
    let mut coord = Coordinator::new("artifacts", "results")?;

    println!(
        "replication sweep: newsvendor, R={} replications, {} epochs, {} \
         worker threads\n",
        reps, epochs, coord.native_threads
    );
    let shards = (reps / 2).max(1);
    // both ratios are vs the sequential protocol: sharding is a dispatch-
    // granularity knob, so its ratio shows the scheduling cost/benefit of
    // S shard workers rather than one monolithic panel
    println!("{:>6} {:>14} {:>14} {:>14} {:>9} {:>9}  bit-identical?",
             "size", "sequential", "batched",
             format!("sharded(S={})", shards), "seq/bat", "seq/shd");

    for &size in &sizes {
        let base = ExperimentSpec::new(TaskKind::Newsvendor,
                                       BackendKind::Native)
            .size(size)
            .epochs(epochs)
            .replications(reps)
            .seed(2024);

        // wall-clock of the whole experiment per execution mode
        let t0 = std::time::Instant::now();
        let seq = coord.run(&base.clone().execution(ExecMode::Sequential))?;
        let t_seq = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let bat = coord
            .run(&base.clone().execution(ExecMode::Batched { shards: 1 }))?;
        let t_bat = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let shd = coord.run(&base.clone().sharded(shards))?;
        let t_shd = t0.elapsed().as_secs_f64();

        let identical = seq.reps.iter().zip(&bat.reps).zip(&shd.reps).all(
            |((a, b), c)| a.objs == b.objs && a.objs == c.objs);
        println!(
            "{:>6} {:>13.4}s {:>13.4}s {:>13.4}s {:>8.2}× {:>8.2}×  {}",
            size,
            t_seq,
            t_bat,
            t_shd,
            t_seq / t_bat.max(1e-12),
            t_seq / t_shd.max(1e-12),
            if identical { "yes" } else { "NO (bug!)" }
        );
        assert!(identical,
                "batched, sharded, and sequential runs must agree bitwise");
    }

    println!(
        "\nThe batched engine advances all R replications per call \
         (replication-major parallelism on the native arm; one fused \
         artifact dispatch per epoch on the XLA arm — try --exec batch with \
         `simopt run --backend xla` once batch artifacts are AOT'd via \
         `python -m compile.aot --reps {}`).",
        reps
    );
    Ok(())
}
