//! Quickstart: the public API in ~40 lines.
//!
//! Runs Task 1 (mean-variance portfolio, Frank-Wolfe) on both execution
//! backends and prints the timing + accuracy comparison.
//!
//!     make artifacts && cargo run --release --example quickstart

use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec};

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new("artifacts", "results")?;

    for backend in [BackendKind::Native, BackendKind::Xla] {
        let spec = ExperimentSpec::new(TaskKind::MeanVariance, backend)
            .size(512)      // 512 assets
            .epochs(10)     // Algorithm 1 epochs (resample + 25 FW steps)
            .replications(3)
            .seed(7);
        let result = coord.run(&spec)?;
        println!("{}", result.summary());

        // the RSE trace the paper's Table 2 reports
        for (frac, iter, mean, std) in result.rse_checkpoints(&[0.1, 0.5, 1.0]) {
            println!(
                "  RSE at {:>3.0}% of the run (epoch {:>2}): {}",
                frac * 100.0,
                iter,
                simopt::util::stats::fmt_pm(mean, std)
            );
        }
    }
    println!("\nSee `simopt sweep --task mv` for the full Figure-2 protocol.");
    Ok(())
}
