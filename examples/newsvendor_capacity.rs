//! Task 2 scenario (paper §3.2): capacity-constrained multi-product
//! newsvendor.  Shows the pieces the paper's Algorithm 2 composes:
//! the Monte-Carlo gradient (backend), the LP linear subproblem (our
//! simplex), and the Frank-Wolfe loop — then inspects how binding the
//! resource constraints are at the solution.
//!
//!     cargo run --release --example newsvendor_capacity

use simopt::backend::native::{NativeMode, NativeNv};
use simopt::backend::xla::XlaNv;
use simopt::opt::run_nv;
use simopt::rng::StreamTree;
use simopt::runtime::Engine;
use simopt::sim::NewsvendorInstance;
use simopt::tasks::NvLmo;

fn main() -> anyhow::Result<()> {
    let d = 256; // products
    let m = 8; // resources
    let epochs = 12;
    let tree = StreamTree::new(77);
    let inst = NewsvendorInstance::generate(&tree, d, m, 0.6);
    println!("instance: {} products, {} resources, capacity at 60% of the \
              unconstrained optimum's usage\n", d, m);

    let x0 = inst.feasible_start();
    let unconstrained = inst.unconstrained_optimum();

    // run on both backends
    let mut solutions = Vec::new();
    {
        let mut lmo = NvLmo::new(&inst);
        let mut backend = NativeNv::new(inst.clone(), 32, NativeMode::Sequential);
        let t = std::time::Instant::now();
        let (x, trace) = run_nv(&mut backend, &mut lmo, x0.clone(), epochs, 25,
                                &tree.subtree(&[1]))?;
        println!("native : {:.3}s, {} LP solves, final cost {:.1}",
                 t.elapsed().as_secs_f64(), lmo.solves,
                 trace.objs.last().unwrap());
        solutions.push(("native", x));
    }
    match Engine::new("artifacts") {
        Ok(engine) => {
            let mut lmo = NvLmo::new(&inst);
            let mut backend = XlaNv::new(&engine, &inst, 32)?;
            let t = std::time::Instant::now();
            let (x, trace) = run_nv(&mut backend, &mut lmo, x0.clone(), epochs,
                                    25, &tree.subtree(&[1]))?;
            println!("xla    : {:.3}s, {} LP solves, final cost {:.1}",
                     t.elapsed().as_secs_f64(), lmo.solves,
                     trace.objs.last().unwrap());
            solutions.push(("xla", x));
        }
        Err(e) => println!("xla    : skipped ({:#})", e),
    }

    // constraint utilization at the solution (the economics of the instance)
    for (name, x) in &solutions {
        println!("\n{} solution:", name);
        assert!(inst.is_feasible(x, 1e-3));
        for i in 0..m {
            let usage: f32 = (0..d).map(|j| inst.a.get(i, j) * x[j]).sum();
            let util = usage / inst.cap[i] * 100.0;
            println!("  resource {:>2}: {:>6.1}% of capacity{}", i, util,
                     if util > 99.0 { "  ← binding" } else { "" });
        }
        // how far capacity pushed us below the unconstrained stock level
        let shrink: f64 = x.iter().zip(&unconstrained)
            .map(|(a, b)| (a / b.max(1e-6)) as f64)
            .sum::<f64>() / d as f64;
        println!("  mean stock level vs unconstrained fractile: {:.1}%",
                 shrink * 100.0);
    }
    Ok(())
}
