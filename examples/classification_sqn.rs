//! Task 3 scenario (paper §3.3): binary classification with the stochastic
//! quasi-Newton method (Byrd et al. 2016), comparing the explicit
//! Algorithm-4 Hessian against the two-loop recursion and printing the
//! convergence trace + classification accuracy.
//!
//!     cargo run --release --example classification_sqn

use simopt::backend::native::{NativeLr, NativeMode};
use simopt::backend::HessianMode;
use simopt::opt::{run_sqn, SqnConfig};
use simopt::rng::StreamTree;
use simopt::sim::ClassifyData;
use simopt::tasks::classification::sigmoid;

fn accuracy(data: &ClassifyData, w: &[f32]) -> f64 {
    let mut correct = 0usize;
    for i in 0..data.n_samples {
        let u: f32 = data.row(i).iter().zip(w).map(|(x, wj)| x * wj).sum();
        let pred = if sigmoid(u) > 0.5 { 1.0 } else { 0.0 };
        if pred == data.z[i] {
            correct += 1;
        }
    }
    correct as f64 / data.n_samples as f64
}

fn main() -> anyhow::Result<()> {
    let n = 256; // features (paper: 50..5000, N = 30n samples)
    let tree = StreamTree::new(31);
    let data = ClassifyData::generate(&tree, n);
    println!("dataset: {} samples × {} binary features, 10% label noise\n",
             data.n_samples, n);

    let cfg = SqnConfig {
        iters: 400,
        batch: 50,      // paper's b
        hbatch: 300,    // paper's b_H
        l_every: 10,    // paper's L
        memory: 25,     // paper's M
        beta: 2.0,      // paper's β
        track_every: 40,
        track_rows: 2048,
    };

    for (mode, tag) in [(HessianMode::Explicit, "Algorithm 4 (explicit H)"),
                        (HessianMode::TwoLoop, "two-loop recursion")] {
        let mut backend = NativeLr::new(&data, NativeMode::Sequential, mode);
        let t = std::time::Instant::now();
        let (w, trace) = run_sqn(&mut backend, &data, &cfg, &tree.subtree(&[1]))?;
        let secs = t.elapsed().as_secs_f64();
        println!("{}:", tag);
        println!("  time {:.3}s  pairs accepted {}  rejected {}",
                 secs, trace.pairs_accepted, trace.pairs_rejected);
        for &(k, loss) in &trace.checkpoints {
            println!("  iter {:>4}: tracked BCE {:.4}", k, loss);
        }
        println!("  train accuracy: {:.1}% (noise ceiling ≈ 90%)\n",
                 accuracy(&data, &w) * 100.0);
    }

    println!("Note: both Hessian applications compute the same direction — \
              the explicit form is the paper's GPU-friendly O(Mn²) matrix \
              showcase, the two-loop form the O(Mn) classic; see \
              `cargo bench --bench ablation_hessian`.");
    Ok(())
}
