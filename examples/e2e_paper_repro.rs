//! END-TO-END DRIVER: the full paper reproduction on a real small workload,
//! proving all three layers compose — Pallas kernels (L1) lowered through
//! the JAX graphs (L2) into HLO artifacts executed by the Rust coordinator
//! (L3), against the sequential native baseline.
//!
//! Runs all three tasks × both backends with replications, prints the
//! Figure-2-shaped timing table and the Table-2-shaped RSE table per task,
//! and writes the full CSV/markdown bundle under `results/e2e/`.
//! The run is recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_paper_repro
//!
//! Environment knobs: SIMOPT_E2E_REPS (default 5), SIMOPT_E2E_SCALE
//! (default 1 — multiplies epochs/iterations).

use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{report, Coordinator, SweepSpec};

fn env(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let reps = env("SIMOPT_E2E_REPS", 5);
    let scale = env("SIMOPT_E2E_SCALE", 1);
    let mut coord = Coordinator::new("artifacts", "results/e2e")?;
    let t_all = std::time::Instant::now();

    println!("=== simopt end-to-end paper reproduction ===");
    println!("paper: He, Liu, Wu, Zheng, Zhu (2024) — GPU-accelerated \
              simulation optimization");
    println!("substitution: GPU → AOT-XLA/PJRT arm, CPU → sequential native \
              arm (DESIGN.md §2)\n");

    let mut all_results = Vec::new();
    for (task, epochs) in [
        (TaskKind::MeanVariance, 10 * scale),
        (TaskKind::Newsvendor, 6 * scale),
        (TaskKind::Classification, 200 * scale),
    ] {
        let mut sweep = SweepSpec::figure2(task);
        sweep.reps = reps;
        sweep.epochs = epochs;
        sweep.backends = vec![BackendKind::Native, BackendKind::Xla];
        eprintln!("--- task: {} (sizes {:?}, {} epochs, {} reps)",
                  task, sweep.sizes, epochs, reps);
        let results = coord.sweep(&sweep)?;

        // Figure-2 panel for this task
        println!("{}", report::figure2_markdown(&results));
        // Table-2 panel at the middle size
        let mid = sweep.sizes[sweep.sizes.len() / 2];
        let mid_results: Vec<_> = results
            .iter()
            .filter(|r| r.spec.size == mid)
            .cloned()
            .collect();
        println!("{}",
                 report::table2_markdown(&mid_results,
                                         &[0.05, 0.1, 0.25, 0.5, 1.0]));
        report::write_report("results/e2e", &format!("{}", task), &results,
                             &[0.05, 0.1, 0.25, 0.5, 1.0])?;
        all_results.extend(results);
    }

    // headline check: who wins, and does the gap widen with size?
    println!("=== headline claims (paper §4.2 shape) ===");
    for task in [TaskKind::MeanVariance, TaskKind::Newsvendor,
                 TaskKind::Classification] {
        let mut rows: Vec<(usize, f64)> = Vec::new();
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = all_results
                .iter()
                .filter(|r| r.spec.task == task)
                .map(|r| r.spec.size)
                .collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        for &size in &sizes {
            let get = |b: BackendKind| {
                all_results
                    .iter()
                    .find(|r| r.spec.task == task && r.spec.size == size
                          && r.spec.backend == b)
                    .map(|r| r.time_stats().mean())
            };
            if let (Some(n), Some(x)) = (get(BackendKind::Native),
                                          get(BackendKind::Xla)) {
                rows.push((size, n / x.max(1e-12)));
            }
        }
        let trend = rows
            .windows(2)
            .all(|w| w[1].1 >= w[0].1 * 0.8); // monotone up to noise
        println!(
            "{:<16} speedups {:?} → gap {} with size",
            task.to_string(),
            rows.iter()
                .map(|(s, v)| format!("d{}: {:.2}×", s, v))
                .collect::<Vec<_>>(),
            if trend { "widens/holds" } else { "does NOT widen (see \
              EXPERIMENTS.md discussion)" }
        );
    }
    println!("\ntotal e2e wall-clock: {:.1}s; reports in results/e2e/",
             t_all.elapsed().as_secs_f64());
    Ok(())
}
