//! Task 1 scenario (paper §3.1 + Figure 2 top-left): mean-variance portfolio
//! selection across a size axis, comparing the sequential arm against the
//! fused-epoch XLA arm, and reporting the quality of the selected portfolio
//! against the generator's ground truth.
//!
//!     cargo run --release --example portfolio_sweep [-- sizes...]

use simopt::backend::MvBackend;
use simopt::opt::run_mv;
use simopt::rng::StreamTree;
use simopt::runtime::Engine;
use simopt::sim::AssetUniverse;
use simopt::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = {
        let args: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if args.is_empty() { vec![128, 512, 2048] } else { args }
    };
    let epochs = 10;
    let tree = StreamTree::new(2024);
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}\n", engine.platform());
    println!("{:>6} {:>14} {:>14} {:>9} {:>12} {:>12}",
             "assets", "native", "xla", "speedup", "exactObj", "gap-to-best");

    for &d in &sizes {
        let universe = AssetUniverse::generate(&tree, d);
        let w0 = vec![1.0f32 / d as f32; d];

        // sequential arm
        let mut native = simopt::backend::native::NativeMv::new(
            universe.clone(), 64, 25,
            simopt::backend::native::NativeMode::Sequential);
        let t0 = std::time::Instant::now();
        let (wn, _) = run_mv(&mut native, w0.clone(), epochs,
                             &tree.subtree(&[d as u64]))?;
        let t_native = t0.elapsed().as_secs_f64();

        // fused XLA arm
        let mut xla = simopt::backend::xla::XlaMv::new(&engine, &universe, 64, 25)?;
        // warm-up dispatch (compilation already cached by Engine)
        let _ = xla.epoch(&w0, 0, [9, 9])?;
        let t0 = std::time::Instant::now();
        let (wx, _) = run_mv(&mut xla, w0.clone(), epochs,
                             &tree.subtree(&[d as u64]))?;
        let t_xla = t0.elapsed().as_secs_f64();

        // quality vs the generator's ground truth
        let exact = universe.exact_objective(&wx);
        let (_, best) = universe.best_single_asset();
        let gap = exact - best;
        let _ = wn; // native portfolio quality is checked by tests
        println!(
            "{:>6} {:>14} {:>14} {:>8.2}× {:>12.5} {:>12.2e}",
            d,
            fmt_duration(t_native),
            fmt_duration(t_xla),
            t_native / t_xla.max(1e-12),
            exact,
            gap
        );
    }
    println!("\n(gap-to-best = exact objective minus the best single-asset \
              vertex; FW over the simplex should drive it toward ~0)");
    Ok(())
}
