"""Bench-telemetry trajectory tool (ROADMAP item): ingest the per-commit
``BENCH_*.json`` artifacts the CI bench-smoke matrix uploads (see
``Bench::to_json`` in rust/src/bench/mod.rs for the schema), print a
mean_s-per-case trend table across commits, and exit nonzero on a
regression.

A case regresses when its newest mean_s exceeds the mean of its history
by more than ``--sigma``× the history's standard deviation AND by a
``--rel-margin`` relative factor (so zero-variance micro-cases cannot
false-positive on scheduler noise).  Smoke runs (``"smoke": true``) and
real timing runs are tracked as separate series — CI smoke workloads are
bit-rot probes, not timings, and must never gate against real numbers.

Cases whose telemetry carries a ``per_phase`` object (the always-on
profiler of DESIGN.md §15) additionally get per-phase trend rows, so a
regression can be read down to the phase that moved — dispatch growing
while compute holds is a very different bug from compute growing.
Those cases are also held to a per-phase REGRESSION BUDGET: when a
phase's share of the case's attributed time grows by more than
``--phase-budget-pp`` percentage points over its baseline mean share,
the build fails even if total mean_s held — that is exactly how a
reduce/merge copy creeps back into a zero-copy spine (DESIGN.md §16),
or how a serial per-replication LP loop creeps back into the panel LMO
(the ``lmo`` phase of ``BENCH_lmo_panel.json``, DESIGN.md §17), while
faster kernels mask it.  Like the σ gate, the budget needs
``--min-history`` points per case; shorter histories pass advisorily.

Runs are ordered by ``ci_run`` id when present (GitHub run ids are
monotonic), else by file modification time, so both a directory of
per-run downloads and a local accumulation directory work.

The tool also ingests **service metrics snapshots** (DESIGN.md §18) via
``--service-metrics``: JSON files captured with ``simopt submit
--metrics --metrics-format json``, ordered by file mtime.  Each
snapshot contributes one trend row deriving the serving plane's health
numbers — runs executed, mean queue wait (``sum_s/count`` of the
``queue_wait_seconds`` histogram), and the cache-hit ratio
``hits/(hits+misses)``.  Service rows are observability, never a gate:
they cannot fail the build, and a service-metrics-only invocation (no
bench roots) exits 0 when snapshots were found.

Usage:
  python python/tools/trajectory.py DIR [DIR...]        # dirs are rglobbed
  python python/tools/trajectory.py DIR --sigma 2 --min-history 3
  python python/tools/trajectory.py --service-metrics METRICS_DIR

Exit codes: 0 = no regression (or not enough history), 1 = regression,
2 = no telemetry found.  The CI bench-trajectory job wiring this is a
BLOCKING perf gate: exit 1 fails the build.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path


def find_files(roots):
    """Every BENCH_*.json under the given roots (dirs rglobbed, files
    taken as-is), deduplicated, in deterministic order."""
    out = []
    for root in roots:
        p = Path(root)
        if p.is_dir():
            out.extend(sorted(p.rglob("BENCH_*.json")))
        elif p.is_file():
            out.append(p)
    seen, uniq = set(), []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    return uniq


def find_metrics_files(paths):
    """Service metrics snapshots under the given paths (dirs rglobbed
    for *.json, files taken as-is), deduplicated, ordered oldest-first
    by file mtime — snapshots have no embedded run id, so capture time
    IS the trend axis."""
    out = []
    for root in paths:
        p = Path(root)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.json")))
        elif p.is_file():
            out.append(p)
    seen, uniq = set(), []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    uniq.sort(key=lambda f: (f.stat().st_mtime, str(f)))
    return uniq


def load_service_snapshots(files):
    """Parse `simopt submit --metrics --metrics-format json` output
    (the MetricsSnapshot wire shape: counters/gauges/histograms maps);
    skip unreadable or shapeless files with a warning.  Returns a list
    of dicts with keys name, counters, gauges, histograms, in the given
    (mtime) order."""
    snaps = []
    for f in files:
        try:
            rec = json.loads(Path(f).read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trajectory] skipping {f}: {e}", file=sys.stderr)
            continue
        if not isinstance(rec, dict) \
                or not isinstance(rec.get("counters"), dict):
            print(f"[trajectory] {f}: not a metrics snapshot "
                  "(no 'counters' object)", file=sys.stderr)
            continue
        snaps.append({
            "name": Path(f).stem,
            "counters": {k: float(v) for k, v in rec["counters"].items()
                         if isinstance(v, (int, float))},
            "gauges": {k: float(v)
                       for k, v in (rec.get("gauges") or {}).items()
                       if isinstance(v, (int, float))},
            "histograms": {k: v
                           for k, v in (rec.get("histograms") or {}).items()
                           if isinstance(v, dict)},
        })
    return snaps


def service_derived(snap):
    """The three serving-plane health numbers one snapshot yields:
    (runs_executed, queue_wait_mean_s | None, cache_hit_ratio | None).
    Means and ratios are None when their denominator is zero — an idle
    server has no queue-wait distribution to average."""
    runs = snap["counters"].get("runs_executed_total", 0.0)
    hist = snap["histograms"].get("queue_wait_seconds") or {}
    count = hist.get("count") or 0
    wait = (float(hist.get("sum_s", 0.0)) / count) if count else None
    hits = snap["counters"].get("cache_hits_total", 0.0)
    misses = snap["counters"].get("cache_misses_total", 0.0)
    ratio = hits / (hits + misses) if (hits + misses) > 0 else None
    return runs, wait, ratio


def render_service_table(snaps):
    """One row per snapshot (oldest-first): the derived health numbers.
    Counters are cumulative since server start, so within one server's
    lifetime the runs column is monotone — a drop marks a restart."""
    lines = ["| snapshot | runs_executed | queue_wait mean | "
             "cache-hit ratio |",
             "|---|---|---|---|"]
    for snap in snaps:
        runs, wait, ratio = service_derived(snap)
        wait_s = "–" if wait is None else fmt_s(wait)
        ratio_s = "–" if ratio is None else f"{ratio * 100:.1f}%"
        lines.append(f"| {snap['name']} | {runs:.0f} | {wait_s} | "
                     f"{ratio_s} |")
    return "\n".join(lines)


def load_runs(files):
    """Parse telemetry records; skip unreadable files (and cases with
    non-numeric mean_s — `Bench::to_json` emits `null` for non-finite
    stats) with a warning.  Returns a list of dicts with keys: bench,
    commit, smoke, cases ({label: mean_s}), ordered oldest-first.

    Ordering: GitHub run ids (monotonic) when EVERY record carries one;
    otherwise file mtime for all records.  The two axes are never mixed —
    run ids (~1e10) would dwarf epoch mtimes (~1e9) and pin local records
    to the front regardless of recency."""
    runs = []
    for f in files:
        try:
            rec = json.loads(Path(f).read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trajectory] skipping {f}: {e}", file=sys.stderr)
            continue
        cases = {}
        phases = {}
        for c in rec.get("cases", []):
            label, mean_s = c.get("label"), c.get("mean_s")
            if not isinstance(label, str) \
                    or not isinstance(mean_s, (int, float)):
                print(f"[trajectory] {f}: skipping case with non-numeric "
                      f"mean_s: {c.get('label', '<unlabelled>')}",
                      file=sys.stderr)
                continue
            cases[label] = float(mean_s)
            pp = c.get("per_phase")
            if isinstance(pp, dict):
                clean = {k: float(v) for k, v in pp.items()
                         if isinstance(k, str)
                         and isinstance(v, (int, float))}
                if clean:
                    phases[label] = clean
        if not cases:
            continue
        try:
            ci_order = int(rec.get("ci_run", ""))
        except (TypeError, ValueError):
            ci_order = None
        runs.append({
            "bench": rec.get("bench", Path(f).stem),
            "commit": str(rec.get("commit", ""))[:12] or "<local>",
            "smoke": bool(rec.get("smoke", False)),
            "ci_order": ci_order,
            "mtime": int(Path(f).stat().st_mtime),
            "cases": cases,
            "phases": phases,
        })
    if runs and all(r["ci_order"] is not None for r in runs):
        runs.sort(key=lambda r: r["ci_order"])
    else:
        if any(r["ci_order"] is not None for r in runs):
            print("[trajectory] mixed local/CI telemetry — ordering every "
                  "record by file mtime", file=sys.stderr)
        runs.sort(key=lambda r: r["mtime"])
    return runs


def series_by_case(runs):
    """{(bench, label, smoke): [(commit, mean_s), ...]} in run order.
    Consecutive duplicates of the same commit keep the LAST record (a
    re-run supersedes)."""
    series = {}
    for run in runs:
        for label, mean_s in run["cases"].items():
            key = (run["bench"], label, run["smoke"])
            hist = series.setdefault(key, [])
            if hist and hist[-1][0] == run["commit"]:
                hist[-1] = (run["commit"], mean_s)
            else:
                hist.append((run["commit"], mean_s))
    return series


def phase_series_by_case(runs):
    """{(bench, label, smoke): [(commit, {phase: s}), ...]} in run order,
    for cases whose telemetry carries per-phase attribution (records from
    before the DESIGN.md §15 profiler simply contribute no points).  Same
    consecutive-duplicate supersede rule as series_by_case."""
    series = {}
    for run in runs:
        for label, phases in run.get("phases", {}).items():
            key = (run["bench"], label, run["smoke"])
            hist = series.setdefault(key, [])
            if hist and hist[-1][0] == run["commit"]:
                hist[-1] = (run["commit"], phases)
            else:
                hist.append((run["commit"], phases))
    return series


def detect_regressions(series, sigma=2.0, rel_margin=1.05, min_history=3):
    """Cases whose newest mean_s sits more than `sigma`σ above its history
    mean (and beyond the relative margin).  Needs `min_history` total
    points so one noisy pair can't fail a build."""
    out = []
    for key, hist in sorted(series.items()):
        if len(hist) < min_history:
            continue
        prev = [m for _, m in hist[:-1]]
        last_commit, last = hist[-1]
        mu = sum(prev) / len(prev)
        var = sum((m - mu) ** 2 for m in prev) / len(prev)
        sd = math.sqrt(var)
        if last > mu + sigma * sd and last > mu * rel_margin:
            out.append({
                "bench": key[0],
                "label": key[1],
                "smoke": key[2],
                "commit": last_commit,
                "last": last,
                "baseline_mean": mu,
                "baseline_std": sd,
            })
    return out


def detect_phase_budget_violations(phase_series, budget_pp=5.0,
                                   min_history=3):
    """Cases where a phase's share of the attributed total grew by more
    than `budget_pp` percentage points over the baseline mean share
    (history excluding the newest run).  Shares, not seconds: absolute
    phase times legitimately move with the workload, but the SPLIT
    between dispatch/compute/reduce is a structural property of the
    execution spine.  Needs `min_history` total points per case, so a
    cold history passes advisorily; runs whose phases sum to zero carry
    no attribution and contribute no point."""
    out = []
    for key, hist in sorted(phase_series.items()):
        shares = []
        for commit, phases in hist:
            total = sum(phases.values())
            if total > 0:
                shares.append(
                    (commit, {p: v / total for p, v in phases.items()}))
        if len(shares) < min_history:
            continue
        prev = [s for _, s in shares[:-1]]
        last_commit, last = shares[-1]
        names = sorted({p for s in prev for p in s} | set(last))
        for phase in names:
            base = sum(s.get(phase, 0.0) for s in prev) / len(prev)
            now = last.get(phase, 0.0)
            if (now - base) * 100.0 > budget_pp:
                out.append({
                    "bench": key[0],
                    "label": key[1],
                    "smoke": key[2],
                    "commit": last_commit,
                    "phase": phase,
                    "last_share": now,
                    "baseline_share": base,
                })
    return out


def fmt_s(v):
    if v < 1e-3:
        return f"{v * 1e6:.1f}µs"
    if v < 1.0:
        return f"{v * 1e3:.2f}ms"
    return f"{v:.3f}s"


def render_table(series):
    """Per-case trend rows: first → last mean_s with the commit count."""
    lines = ["| bench | case | runs | first | last | Δ |",
             "|---|---|---|---|---|---|"]
    for (bench, label, smoke), hist in sorted(series.items()):
        first, last = hist[0][1], hist[-1][1]
        delta = "–" if first == 0 else f"{(last / first - 1) * 100:+.1f}%"
        tag = " [smoke]" if smoke else ""
        lines.append(f"| {bench} | {label}{tag} | {len(hist)} | "
                     f"{fmt_s(first)} | {fmt_s(last)} | {delta} |")
    return "\n".join(lines)


def render_phase_table(phase_series):
    """Per-phase trend rows (one per case × phase, first → last seconds);
    empty string when no run carried per-phase telemetry."""
    if not phase_series:
        return ""
    lines = ["| bench | case | phase | runs | first | last | Δ |",
             "|---|---|---|---|---|---|---|"]
    for (bench, label, smoke), hist in sorted(phase_series.items()):
        tag = " [smoke]" if smoke else ""
        names = sorted({p for _, phases in hist for p in phases})
        for phase in names:
            pts = [(c, ph[phase]) for c, ph in hist if phase in ph]
            first, last = pts[0][1], pts[-1][1]
            delta = "–" if first == 0 \
                else f"{(last / first - 1) * 100:+.1f}%"
            lines.append(f"| {bench} | {label}{tag} | {phase} | "
                         f"{len(pts)} | {fmt_s(first)} | {fmt_s(last)} | "
                         f"{delta} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("roots", nargs="*",
                    help="directories (rglobbed) or BENCH_*.json files")
    ap.add_argument("--service-metrics", nargs="+", default=[],
                    metavar="PATH",
                    help="service metrics snapshots (`simopt submit "
                         "--metrics --metrics-format json` output; files "
                         "or dirs rglobbed for *.json), ordered by file "
                         "mtime — rendered as trend rows, never a gate")
    ap.add_argument("--sigma", type=float, default=2.0,
                    help="regression threshold in history σ (default 2)")
    ap.add_argument("--rel-margin", type=float, default=1.05,
                    help="additional relative guard (default 1.05 = +5%%)")
    ap.add_argument("--min-history", type=int, default=3,
                    help="points needed before a case can regress")
    ap.add_argument("--phase-budget-pp", type=float, default=5.0,
                    help="max growth of a phase's share of attributed "
                         "time, in percentage points (default 5)")
    args = ap.parse_args(argv)
    if not args.roots and not args.service_metrics:
        ap.print_usage(sys.stderr)
        print("[trajectory] nothing to do: give bench roots and/or "
              "--service-metrics", file=sys.stderr)
        return 2

    service_snaps = []
    if args.service_metrics:
        service_snaps = load_service_snapshots(
            find_metrics_files(args.service_metrics))
        if service_snaps:
            print(f"[trajectory] {len(service_snaps)} service metrics "
                  "snapshot(s)\n")
            print(render_service_table(service_snaps))
        else:
            print("[trajectory] no service metrics snapshots found under "
                  + ", ".join(args.service_metrics), file=sys.stderr)
    if not args.roots:
        # service rows are observability, never a gate: found snapshots
        # mean success, an empty ingest means no telemetry at all
        return 0 if service_snaps else 2
    if service_snaps:
        print()

    files = find_files(args.roots)
    if not files:
        print("[trajectory] no BENCH_*.json telemetry found under "
              + ", ".join(args.roots))
        return 2
    runs = load_runs(files)
    series = series_by_case(runs)
    print(f"[trajectory] {len(files)} telemetry files, {len(runs)} runs, "
          f"{len(series)} case series\n")
    print(render_table(series))
    phase_series = phase_series_by_case(runs)
    phase_table = render_phase_table(phase_series)
    if phase_table:
        print("\nper-phase attribution trends:\n" + phase_table)

    regressions = detect_regressions(series, sigma=args.sigma,
                                     rel_margin=args.rel_margin,
                                     min_history=args.min_history)
    if regressions:
        print(f"\n{len(regressions)} regression(s) > {args.sigma}σ:")
        for r in regressions:
            tag = " [smoke]" if r["smoke"] else ""
            print(f"  {r['bench']} / {r['label']}{tag} @ {r['commit']}: "
                  f"{fmt_s(r['last'])} vs baseline "
                  f"{fmt_s(r['baseline_mean'])} ±{fmt_s(r['baseline_std'])}")
    violations = detect_phase_budget_violations(
        phase_series, budget_pp=args.phase_budget_pp,
        min_history=args.min_history)
    if violations:
        print(f"\n{len(violations)} phase-budget violation(s) "
              f"> {args.phase_budget_pp}pp:")
        for v in violations:
            tag = " [smoke]" if v["smoke"] else ""
            print(f"  {v['bench']} / {v['label']}{tag} @ {v['commit']}: "
                  f"{v['phase']} share {v['last_share'] * 100:.1f}% vs "
                  f"baseline {v['baseline_share'] * 100:.1f}% "
                  f"(+{(v['last_share'] - v['baseline_share']) * 100:.1f}pp)")
    if regressions or violations:
        return 1
    print("\nno regressions beyond the thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
