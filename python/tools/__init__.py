"""Offline bench-telemetry tooling (no third-party dependencies)."""
