"""Device-resident entry points (§Perf P1): nv_panel / nv_grad_panel and
lr_grad_ds / lr_hvp_ds must compute exactly what the monolithic entries and
the oracle compute."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import ref

from .conftest import assert_close, rngkey


@given(st.integers(0, 5_000))
def test_nv_panel_plus_grad_equals_monolithic(seed):
    """nv_grad(x, μ, σ, ..., key) == nv_grad_panel(x, nv_panel(μ, σ, key))."""
    d, s = 32, 8
    mu = 20 + 30 * jax.random.uniform(rngkey(seed), (d,))
    sigma = 10 + 10 * jax.random.uniform(rngkey(seed + 1), (d,))
    x = mu * 0.9
    kc = jnp.full((d,), 2.0)
    h = jnp.full((d,), 0.4)
    v = jnp.full((d,), 5.0)
    key = jnp.array([3, seed], dtype=jnp.uint32)
    g1, o1 = model.nv_grad(x, mu, sigma, kc, h, v, key, n_samples=s)
    panel = model.nv_panel(mu, sigma, key, n_samples=s)
    g2, o2 = model.nv_grad_panel(x, panel, kc, h, v)
    assert_close(g1, g2, rtol=0, atol=0)
    assert_close(o1, o2, rtol=0, atol=0)


def test_nv_panel_statistics():
    d, s = 16, 4096
    mu = jnp.full((d,), 35.0)
    sigma = jnp.full((d,), 12.0)
    key = jnp.array([0, 11], dtype=jnp.uint32)
    panel = model.nv_panel(mu, sigma, key, n_samples=s)
    assert panel.shape == (s, d)
    col_means = np.asarray(panel.mean(axis=0))
    assert np.abs(col_means - 35.0).max() < 1.0
    col_stds = np.asarray(panel.std(axis=0))
    assert np.abs(col_stds - 12.0).max() < 1.0


@given(st.integers(0, 5_000))
def test_lr_grad_ds_equals_gathered(seed):
    """In-graph index gather == host-side row gather (the CRN contract
    between the native and xla arms)."""
    n, rows, b = 24, 96, 16
    x_full = (jax.random.uniform(rngkey(seed), (rows, n)) > 0.5).astype(jnp.float32)
    z_full = (jax.random.uniform(rngkey(seed + 1), (rows,)) > 0.5).astype(jnp.float32)
    w = jax.random.normal(rngkey(seed + 2), (n,)) * 0.1
    idx = jax.random.randint(rngkey(seed + 3), (b,), 0, rows)
    g1, l1 = model.lr_grad_ds(w, x_full, z_full, idx)
    xb = x_full[idx]
    zb = z_full[idx]
    g2, l2 = ref.lr_grad_ref(w, xb, zb)
    assert_close(g1, g2, rtol=1e-4, atol=1e-6)
    assert_close(l1, l2, rtol=1e-4, atol=1e-6)


@given(st.integers(0, 5_000))
def test_lr_hvp_ds_equals_gathered(seed):
    n, rows, bh = 16, 64, 32
    x_full = (jax.random.uniform(rngkey(seed), (rows, n)) > 0.5).astype(jnp.float32)
    wbar = jax.random.normal(rngkey(seed + 1), (n,)) * 0.1
    s = jax.random.normal(rngkey(seed + 2), (n,))
    idx = jax.random.randint(rngkey(seed + 3), (bh,), 0, rows)
    y1 = model.lr_hvp_ds(wbar, s, x_full, idx)
    y2 = ref.lr_hvp_ref(wbar, s, x_full[idx])
    assert_close(y1, y2, rtol=1e-4, atol=1e-6)


@given(st.integers(0, 5_000), st.integers(1, 5))
def test_hbuild_jnp_and_pallas_paths_agree(seed, m_count):
    """§Perf P2 swapped the AOT'd lr_hbuild to the fused jnp form; both
    lowerings must compute the same H (the Pallas path remains the L1
    reference for TPU lowering)."""
    mem, n = 5, 16
    s_mem = jax.random.normal(rngkey(seed), (mem, n)) * 0.1
    a = jax.random.normal(rngkey(seed + 1), (n, n)) * 0.1
    spd = a @ a.T + jnp.eye(n)
    y_mem = s_mem @ spd.T
    h_jnp = model.lr_hbuild(s_mem, y_mem, jnp.int32(m_count))
    h_pal = model.lr_hbuild(s_mem, y_mem, jnp.int32(m_count), use_pallas=True)
    assert_close(h_jnp, h_pal, rtol=1e-4, atol=1e-5)
    assert_close(h_jnp, ref.lr_hbuild_ref(s_mem, y_mem, m_count),
                 rtol=1e-3, atol=1e-4)


def test_resident_specs_in_default_manifest():
    from compile import aot
    specs = aot.build_specs([32], [64], [16], mv_samples=8, mv_inner=3,
                            nv_samples=8, lr_batch=8, lr_hbatch=16, lr_mem=4)
    entries = {s.entry for s in specs}
    for required in ["nv_panel", "nv_grad_panel", "lr_grad_ds", "lr_hvp_ds"]:
        assert required in entries, f"{required} missing from spec table"
    # rows convention: N = 30n
    ds = next(s for s in specs if s.entry == "lr_grad_ds")
    assert ds.params["rows"] == 30 * ds.params["n"]
