"""Shared fixtures/strategies for the kernel and model test suites.

Pallas kernels run under interpret=True, which is slow per call — the
hypothesis settings below cap example counts so the full suite stays fast
while still sweeping the shape/seed space.
"""

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Global hypothesis profile: interpret-mode kernels are expensive per example.
settings.register_profile(
    "kernels",
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    # The AOT artifacts are f32; keep the test environment identical.
    jax.config.update("jax_enable_x64", False)


def rngkey(seed):
    return jax.random.PRNGKey(seed)


def assert_close(a, b, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)
