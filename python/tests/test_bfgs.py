"""Algorithm 4 (Hessian updating) — Pallas rank-update kernel, explicit-H
build, and the two-loop ablation, all against the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import bfgs as bfgsk
from compile.kernels import ref

from .conftest import assert_close, rngkey


def _pairs(seed, mem, n, scale=0.1):
    """Correction pairs with positive curvature (sᵀy > 0), as produced by a
    convex problem."""
    s = jax.random.normal(rngkey(seed), (mem, n)) * scale
    # y = A s with A SPD ⇒ sᵀy > 0
    a = jax.random.normal(rngkey(seed + 1), (n, n)) * 0.1
    spd = a @ a.T + jnp.eye(n)
    y = s @ spd.T
    return s, y


@given(st.integers(0, 10_000), st.sampled_from([8, 16, 64]))
def test_rank_update_kernel_matches_formula(seed, n):
    s, y = _pairs(seed, 1, n)
    s, y = s[0], y[0]
    h = jnp.eye(n) * 0.7
    hy = h @ y
    rho = 1.0 / jnp.dot(y, s)
    q = jnp.dot(y, hy)
    coef = jnp.stack([rho, rho * rho * q + rho])
    got = bfgsk.bfgs_rank_update(h, s, hy, coef)
    e = jnp.eye(n)
    want = (e - rho * jnp.outer(s, y)) @ h @ (e - rho * jnp.outer(y, s)) \
        + rho * jnp.outer(s, s)
    assert_close(got, want, rtol=1e-4, atol=1e-5)


@given(st.integers(0, 10_000), st.sampled_from([1, 4, 8]))
def test_rank_update_tile_invariance(seed, tile):
    n = 16
    s, y = _pairs(seed, 1, n)
    s, y = s[0], y[0]
    h = jnp.eye(n)
    hy = h @ y
    rho = 1.0 / jnp.dot(y, s)
    coef = jnp.stack([rho, rho * rho * jnp.dot(y, hy) + rho])
    a = bfgsk.bfgs_rank_update(h, s, hy, coef, tile=tile)
    b = bfgsk.bfgs_rank_update(h, s, hy, coef, tile=n)
    assert_close(a, b, rtol=1e-5, atol=1e-6)


def test_rank_update_zero_rho_is_identity():
    """coef = [0,0] must leave H untouched — the masking mechanism that
    skips invalid correction slots."""
    n = 8
    h = jax.random.normal(rngkey(0), (n, n))
    s = jax.random.normal(rngkey(1), (n,)) * 1e3   # garbage slot contents
    hy = jax.random.normal(rngkey(2), (n,)) * 1e3
    got = bfgsk.bfgs_rank_update(h, s, hy, jnp.zeros(2))
    assert_close(got, h, rtol=0, atol=0)


@given(st.integers(0, 10_000), st.integers(0, 6))
def test_hbuild_matches_ref(seed, m_count):
    mem, n = 6, 16
    s, y = _pairs(seed, mem, n)
    got = model.lr_hbuild(s, y, jnp.int32(m_count))
    want = ref.lr_hbuild_ref(s, y, m_count)
    assert_close(got, want, rtol=1e-3, atol=1e-4)


@given(st.integers(0, 10_000))
def test_hbuild_symmetric_psd(seed):
    """H_t from BFGS with positive-curvature pairs is symmetric PSD."""
    s, y = _pairs(seed, 5, 12)
    h = np.asarray(model.lr_hbuild(s, y, jnp.int32(5)), dtype=np.float64)
    np.testing.assert_allclose(h, h.T, rtol=1e-4, atol=1e-5)
    evals = np.linalg.eigvalsh((h + h.T) / 2)
    assert evals.min() > -1e-4


def test_hbuild_secant_condition():
    """After the update with pair (s,y), H must satisfy H y = s for the most
    recent pair (the defining BFGS property)."""
    s, y = _pairs(3, 4, 10)
    h = model.lr_hbuild(s, y, jnp.int32(4))
    assert_close(h @ y[3], s[3], rtol=1e-3, atol=1e-4)


@given(st.integers(0, 10_000), st.integers(1, 6))
def test_twoloop_matches_explicit(seed, m_count):
    """Ablation A2 precondition: two-loop and explicit Algorithm 4 compute
    the same direction."""
    mem, n = 6, 16
    s, y = _pairs(seed, mem, n)
    g = jax.random.normal(rngkey(seed + 7), (n,))
    d1 = model.lr_dir_twoloop(s, y, jnp.int32(m_count), g)
    d2 = ref.lr_dir_ref(s, y, m_count, g)
    assert_close(d1, d2, rtol=1e-3, atol=1e-4)


def test_twoloop_mcount_zero_is_gradient():
    s, y = _pairs(1, 4, 8)
    g = jax.random.normal(rngkey(2), (8,))
    got = model.lr_dir_twoloop(s, y, jnp.int32(0), g)
    assert_close(got, g, rtol=1e-6, atol=1e-6)


def test_garbage_in_invalid_slots_is_ignored():
    """Slots ≥ m_count may hold arbitrary data without changing results."""
    mem, n, mc = 5, 12, 2
    s, y = _pairs(11, mem, n)
    s_dirty = s.at[mc:].set(1e6)
    y_dirty = y.at[mc:].set(-1e6)
    g = jax.random.normal(rngkey(3), (n,))
    a = model.lr_dir_twoloop(s, y, jnp.int32(mc), g)
    b = model.lr_dir_twoloop(s_dirty, y_dirty, jnp.int32(mc), g)
    assert_close(a, b, rtol=1e-5, atol=1e-6)
    ha = model.lr_hbuild(s, y, jnp.int32(mc))
    hb = model.lr_hbuild(s_dirty, y_dirty, jnp.int32(mc))
    assert_close(ha, hb, rtol=1e-5, atol=1e-6)


def test_happly_is_matvec():
    n = 8
    h = jax.random.normal(rngkey(4), (n, n))
    g = jax.random.normal(rngkey(5), (n,))
    assert_close(model.lr_happly(h, g), h @ g, rtol=0, atol=0)


def test_sqn_direction_is_descent():
    """On a quadratic with positive-curvature pairs, −H g must be a descent
    direction: gᵀHg > 0."""
    s, y = _pairs(21, 5, 16)
    g = jax.random.normal(rngkey(6), (16,))
    d = model.lr_dir_twoloop(s, y, jnp.int32(5), g)
    assert float(jnp.dot(g, d)) > 0
