"""Task 4 kernels/model vs the pure-jnp oracle (smoothed mean-CVaR,
registry extension — DESIGN.md §12)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import cvar as cvk
from compile.kernels import ref

from .conftest import assert_close, rngkey


def _panel(seed, n, d):
    r = jax.random.normal(rngkey(seed), (n, d)) * 0.5
    return r, r.mean(axis=0)


def _iterate(seed, d, t=0.1):
    w = jax.nn.softmax(jax.random.normal(rngkey(seed), (d,)))
    return jnp.concatenate([w, jnp.array([t], w.dtype)])


@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 64]),
       st.sampled_from([4, 32, 96]))
def test_cv_stats_matches_ref(seed, n, d):
    panel, _ = _panel(seed, n, d)
    w = jax.nn.softmax(jax.random.normal(rngkey(seed + 1), (d,)))
    t = jnp.array([0.2], jnp.float32)
    gacc, sp, sig = cvk.cv_stats(panel, w, t)
    gacc_r, sp_r, sig_r = ref.cv_stats_ref(panel, w, t[0], cvk.ETA)
    assert_close(gacc, gacc_r, rtol=1e-4, atol=1e-4)
    assert_close(sp[0], sp_r, rtol=1e-4, atol=1e-4)
    assert_close(sig[0], sig_r, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_cv_stats_tile_invariance(seed, tile):
    """The grid decomposition must not change the result."""
    panel, _ = _panel(seed, 16, 24)
    w = jax.nn.softmax(jax.random.normal(rngkey(seed + 1), (24,)))
    t = jnp.array([0.0], jnp.float32)
    gacc, sp, sig = cvk.cv_stats(panel, w, t, tile_n=tile)
    gacc_r, sp_r, sig_r = ref.cv_stats_ref(panel, w, t[0], cvk.ETA)
    assert_close(gacc, gacc_r, rtol=1e-4, atol=1e-4)
    assert_close(sp[0], sp_r, rtol=1e-4, atol=1e-4)


def test_cv_stats_rejects_non_dividing_tile():
    panel, _ = _panel(0, 10, 8)
    with pytest.raises(ValueError):
        cvk.cv_stats(panel, jnp.ones(8) / 8, jnp.zeros(1), tile_n=4)


@given(st.integers(0, 10_000))
def test_cv_grad_and_obj_match_ref(seed):
    panel, rbar = _panel(seed, 16, 12)
    x = _iterate(seed + 1, 12)
    assert_close(cvk.cv_grad(panel, rbar, x),
                 ref.cv_grad_ref(panel, rbar, x, cvk.ALPHA, cvk.ETA,
                                 cvk.LAMBDA),
                 rtol=1e-4, atol=1e-5)
    assert_close(cvk.cv_obj(panel, rbar, x),
                 ref.cv_obj_ref(panel, rbar, x, cvk.ALPHA, cvk.ETA,
                                cvk.LAMBDA),
                 rtol=1e-4, atol=1e-5)


def test_cv_grad_matches_autodiff():
    """The hand-derived gradient must agree with jax.grad of the objective
    oracle — the strongest correctness anchor available in-process."""
    panel, rbar = _panel(3, 32, 8)
    x = _iterate(4, 8, t=0.05)
    want = jax.grad(
        lambda xx: ref.cv_obj_ref(panel, rbar, xx, cvk.ALPHA, cvk.ETA,
                                  cvk.LAMBDA))(x)
    assert_close(cvk.cv_grad(panel, rbar, x), want, rtol=1e-3, atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([4, 16]))
def test_product_lmo_is_optimal_vertex(seed, d):
    """The LMO must attain min over Δ_capped × [−T_BOX, T_BOX], which
    separates: min(0, min_j g_j) − T_BOX·|g_t|."""
    g = jax.random.normal(rngkey(seed), (d + 1,))
    s = model.cv_product_lmo(g, d)
    s_np = np.asarray(s)
    assert (s_np[:d] >= 0).all() and s_np[:d].sum() <= 1 + 1e-6
    assert abs(s_np[d]) <= cvk.T_BOX + 1e-6
    value = float(jnp.dot(s, g))
    expected = min(0.0, float(g[:d].min())) - cvk.T_BOX * abs(float(g[d]))
    assert abs(value - expected) < 1e-5


@given(st.integers(0, 5_000))
def test_cv_epoch_keeps_iterate_feasible(seed):
    d = 12
    x = jnp.concatenate([jnp.ones(d) / d, jnp.zeros(1)])
    mu = jax.random.uniform(rngkey(seed), (d,), minval=-1, maxval=1)
    sigma = jnp.full((d,), 0.02)
    key = jnp.array([1, seed], dtype=jnp.uint32)
    x1, obj = model.cv_epoch(x, mu, sigma, key, jnp.int32(0), n_samples=8,
                             m_inner=6)
    x1 = np.asarray(x1)
    assert (x1[:d] >= -1e-6).all()
    assert x1[:d].sum() <= 1 + 1e-5
    assert abs(x1[d]) <= cvk.T_BOX + 1e-5
    assert np.isfinite(float(obj))


def test_cv_epoch_is_deterministic_in_key():
    d = 8
    x = jnp.concatenate([jnp.ones(d) / d, jnp.zeros(1)])
    mu = jnp.zeros(d)
    sigma = jnp.full((d,), 0.02)
    key = jnp.array([3, 4], dtype=jnp.uint32)
    a = model.cv_epoch(x, mu, sigma, key, jnp.int32(1), n_samples=8,
                       m_inner=3)
    b = model.cv_epoch(x, mu, sigma, key, jnp.int32(1), n_samples=8,
                       m_inner=3)
    assert_close(a[0], b[0], rtol=0, atol=0)
    assert_close(a[1], b[1], rtol=0, atol=0)


def test_cv_fw_converges_on_fixed_panel():
    """Repeated epochs on the same key (frozen panel) must descend."""
    d, n = 8, 256
    mu = jax.random.uniform(rngkey(5), (d,), minval=-0.5, maxval=1.0)
    sigma = jnp.full((d,), 0.02)
    key = jnp.array([0, 321], dtype=jnp.uint32)
    x = jnp.concatenate([jnp.ones(d) / d, jnp.zeros(1)])
    r = mu[None, :] + sigma[None, :] * jax.random.normal(key, (n, d))
    rbar = r.mean(axis=0)
    obj0 = float(ref.cv_obj_ref(r, rbar, x, cvk.ALPHA, cvk.ETA, cvk.LAMBDA))
    objs = []
    for k in range(6):
        x, obj = model.cv_epoch(x, mu, sigma, key, jnp.int32(k),
                                n_samples=n, m_inner=10)
        objs.append(float(obj))
    assert objs[-1] < obj0


def test_constants_mirror_rust():
    """The smoothing constants are duplicated in rust/src/tasks/cvar.rs —
    parse them out of the Rust source so drift fails HERE."""
    import pathlib
    import re
    src = (pathlib.Path(__file__).resolve().parents[2]
           / "rust" / "src" / "tasks" / "cvar.rs").read_text()

    def rust_const(name):
        m = re.search(rf"pub const {name}: f32 = ([0-9.]+);", src)
        assert m, f"const {name} not found in rust/src/tasks/cvar.rs"
        return float(m.group(1))

    assert rust_const("ALPHA") == cvk.ALPHA
    assert rust_const("ETA") == cvk.ETA
    assert rust_const("LAMBDA") == cvk.LAMBDA
    assert rust_const("T_BOX") == cvk.T_BOX
