"""Trajectory tool: telemetry ingestion, trend series, regression gate."""

import json

from tools import trajectory as tj


def _write(tmp_path, name, bench, commit, ci_run, cases, smoke=True,
           phases=None):
    rec = {
        "bench": bench,
        "commit": commit,
        "ci_run": str(ci_run),
        "smoke": smoke,
        "cases": [{"label": l, "reps": 1, "mean_s": m, "std_s": 0.0,
                   "min_s": m, "median_s": m,
                   "per_phase": (phases or {}).get(l, {})}
                  for l, m in cases.items()],
    }
    p = tmp_path / name
    p.write_text(json.dumps(rec))
    return p


def test_runs_ordered_by_ci_run_and_series_built(tmp_path):
    _write(tmp_path, "BENCH_b2.json", "bs", "bbb", 2, {"case": 2.0})
    _write(tmp_path, "BENCH_b1.json", "bs", "aaa", 1, {"case": 1.0})
    runs = tj.load_runs(tj.find_files([tmp_path]))
    assert [r["commit"] for r in runs] == ["aaa", "bbb"]
    series = tj.series_by_case(runs)
    assert series[("bs", "case", True)] == [("aaa", 1.0), ("bbb", 2.0)]


def test_smoke_and_real_runs_are_separate_series(tmp_path):
    _write(tmp_path, "BENCH_s.json", "bs", "aaa", 1, {"case": 1.0},
           smoke=True)
    _write(tmp_path, "BENCH_r.json", "bs", "aaa", 2, {"case": 50.0},
           smoke=False)
    series = tj.series_by_case(tj.load_runs(tj.find_files([tmp_path])))
    assert ("bs", "case", True) in series
    assert ("bs", "case", False) in series


def test_regression_fires_above_two_sigma(tmp_path):
    series = {("bs", "case", True): [("a", 1.0), ("b", 1.02), ("c", 0.98),
                                     ("d", 2.0)]}
    regs = tj.detect_regressions(series, sigma=2.0)
    assert len(regs) == 1
    assert regs[0]["label"] == "case"
    assert regs[0]["commit"] == "d"


def test_no_regression_within_band_or_short_history():
    flat = {("bs", "case", True): [("a", 1.0), ("b", 1.01), ("c", 1.0)]}
    assert tj.detect_regressions(flat) == []
    short = {("bs", "case", True): [("a", 1.0), ("b", 99.0)]}
    assert tj.detect_regressions(short) == []


def test_zero_variance_history_needs_relative_margin():
    # identical history ⇒ σ = 0; the +5% relative guard must still hold
    tiny = {("bs", "case", True): [("a", 1.0), ("b", 1.0), ("c", 1.0),
                                   ("d", 1.01)]}
    assert tj.detect_regressions(tiny) == []
    real = {("bs", "case", True): [("a", 1.0), ("b", 1.0), ("c", 1.0),
                                   ("d", 1.2)]}
    assert len(tj.detect_regressions(real)) == 1


def test_main_exit_codes(tmp_path, capsys):
    assert tj.main([str(tmp_path / "empty")]) == 2
    _write(tmp_path, "BENCH_1.json", "bs", "a", 1, {"case": 1.0})
    _write(tmp_path, "BENCH_2.json", "bs", "b", 2, {"case": 1.0})
    _write(tmp_path, "BENCH_3.json", "bs", "c", 3, {"case": 5.0})
    assert tj.main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "regression" in out
    assert tj.main([str(tmp_path), "--sigma", "1e9",
                    "--rel-margin", "1e9"]) == 0


def test_null_mean_s_case_skipped_not_fatal(tmp_path):
    # Bench::to_json emits null for non-finite stats; one bad case must
    # not take the whole gate down
    rec = {"bench": "bs", "commit": "x", "ci_run": "1", "smoke": True,
           "cases": [{"label": "bad", "mean_s": None},
                     {"label": "ok", "mean_s": 1.0}]}
    p = tmp_path / "BENCH_bad.json"
    p.write_text(json.dumps(rec))
    runs = tj.load_runs([p])
    assert runs[0]["cases"] == {"ok": 1.0}


def test_mixed_local_and_ci_records_order_by_mtime(tmp_path):
    import os
    import time
    now = time.time()
    a = _write(tmp_path, "BENCH_ci.json", "bs", "old", 16_000_000_001,
               {"case": 1.0})
    os.utime(a, (now - 1000, now - 1000))
    rec = {"bench": "bs", "commit": "new", "smoke": True,
           "cases": [{"label": "case", "mean_s": 2.0}]}
    b = tmp_path / "BENCH_local.json"
    b.write_text(json.dumps(rec))
    os.utime(b, (now, now))
    runs = tj.load_runs(tj.find_files([tmp_path]))
    # a local record (no ci_run) must not sort before a newer-by-wallclock
    # CI record just because run ids dwarf mtimes
    assert [r["commit"] for r in runs] == ["old", "new"]


def test_per_phase_series_and_trend_table(tmp_path):
    _write(tmp_path, "BENCH_1.json", "bs", "aaa", 1, {"case": 1.0},
           phases={"case": {"dispatch": 0.2, "compute": 0.8}})
    _write(tmp_path, "BENCH_2.json", "bs", "bbb", 2, {"case": 1.1},
           phases={"case": {"dispatch": 0.4, "compute": 0.7}})
    runs = tj.load_runs(tj.find_files([tmp_path]))
    series = tj.phase_series_by_case(runs)
    assert series[("bs", "case", True)] == [
        ("aaa", {"dispatch": 0.2, "compute": 0.8}),
        ("bbb", {"dispatch": 0.4, "compute": 0.7}),
    ]
    table = tj.render_phase_table(series)
    assert "| dispatch |" in table
    assert "| compute |" in table
    assert "+100.0%" in table  # dispatch doubled


def test_pre_profiler_records_render_no_phase_table(tmp_path):
    # telemetry from before DESIGN.md §15 has no per_phase key at all —
    # the mean_s gate must keep working and the phase table must vanish
    rec = {"bench": "bs", "commit": "x", "ci_run": "1", "smoke": True,
           "cases": [{"label": "case", "mean_s": 1.0}]}
    (tmp_path / "BENCH_old.json").write_text(json.dumps(rec))
    runs = tj.load_runs(tj.find_files([tmp_path]))
    assert runs[0]["cases"] == {"case": 1.0}
    assert tj.phase_series_by_case(runs) == {}
    assert tj.render_phase_table({}) == ""


def test_rerun_of_same_commit_supersedes(tmp_path):
    _write(tmp_path, "BENCH_1.json", "bs", "aaa", 1, {"case": 9.0})
    _write(tmp_path, "BENCH_2.json", "bs", "aaa", 2, {"case": 1.0})
    series = tj.series_by_case(tj.load_runs(tj.find_files([tmp_path])))
    assert series[("bs", "case", True)] == [("aaa", 1.0)]


def test_merged_history_dirs_order_by_ci_run(tmp_path):
    # The CI bench-trajectory job folds each run's artifacts into a
    # per-run-id subdirectory of one cached history tree.  Run-id dir
    # names sort lexically ("10" < "9"), so the rglob file order is NOT
    # the run order — the series must still come out ordered by ci_run.
    (tmp_path / "9").mkdir()
    (tmp_path / "10").mkdir()
    _write(tmp_path / "9", "BENCH_bs.json", "bs", "old", 9, {"case": 1.0})
    _write(tmp_path / "10", "BENCH_bs.json", "bs", "new", 10, {"case": 2.0})
    files = tj.find_files([tmp_path])
    # lexical path order really is inverted — the precondition this test
    # exists to pin
    assert [f.parent.name for f in files] == ["10", "9"]
    runs = tj.load_runs(files)
    assert [r["commit"] for r in runs] == ["old", "new"]
    series = tj.series_by_case(runs)
    assert series[("bs", "case", True)] == [("old", 1.0), ("new", 2.0)]


def test_phase_budget_violation_fires_on_share_growth():
    # reduce share 10% → 30%: +20pp breaks the default 5pp budget even
    # though the absolute compute seconds barely moved
    series = {("bs", "case", True): [
        ("a", {"compute": 0.9, "reduce": 0.1}),
        ("b", {"compute": 0.88, "reduce": 0.12}),
        ("c", {"compute": 0.7, "reduce": 0.3}),
    ]}
    out = tj.detect_phase_budget_violations(series, budget_pp=5.0,
                                            min_history=3)
    assert len(out) == 1
    v = out[0]
    assert (v["phase"], v["commit"]) == ("reduce", "c")
    assert abs(v["baseline_share"] - 0.11) < 1e-9
    assert abs(v["last_share"] - 0.3) < 1e-9
    # a wider budget absorbs the same move
    assert tj.detect_phase_budget_violations(series, budget_pp=25.0,
                                             min_history=3) == []


def test_phase_budget_passes_within_budget_or_short_history():
    flat = {("bs", "case", True): [
        ("a", {"compute": 0.9, "reduce": 0.1}),
        ("b", {"compute": 0.9, "reduce": 0.1}),
        ("c", {"compute": 0.89, "reduce": 0.11}),
    ]}
    assert tj.detect_phase_budget_violations(flat) == []
    # two points only: advisory pass regardless of the jump
    short = {("bs", "case", True): [
        ("a", {"compute": 1.0, "reduce": 0.0}),
        ("b", {"compute": 0.5, "reduce": 0.5}),
    ]}
    assert tj.detect_phase_budget_violations(short) == []


def test_phase_budget_zero_attribution_runs_contribute_no_point():
    # an all-zero per_phase object has no shares to compare; it must
    # neither divide by zero nor count toward min_history
    series = {("bs", "case", True): [
        ("a", {"compute": 0.0, "reduce": 0.0}),
        ("b", {"compute": 0.9, "reduce": 0.1}),
        ("c", {"compute": 0.5, "reduce": 0.5}),
    ]}
    assert tj.detect_phase_budget_violations(series, min_history=3) == []


def test_phase_budget_handles_phase_missing_from_baseline():
    # a phase that first appears in the newest run has baseline share 0 —
    # it must still be budget-checked, not crash on the missing key
    series = {("bs", "case", True): [
        ("a", {"compute": 1.0}),
        ("b", {"compute": 1.0}),
        ("c", {"compute": 0.8, "reduce": 0.2}),
    ]}
    out = tj.detect_phase_budget_violations(series, budget_pp=5.0,
                                            min_history=3)
    assert [v["phase"] for v in out] == ["reduce"]
    assert out[0]["baseline_share"] == 0.0


def test_main_exits_1_on_phase_budget_violation(tmp_path, capsys):
    # total mean_s is flat (the σ gate stays quiet) but the reduce share
    # creeps from 5% to 40% — exactly the merge-copy regression the
    # budget exists to catch (DESIGN.md §16)
    for run, reduce_s in ((1, 0.05), (2, 0.05), (3, 0.40)):
        _write(tmp_path, f"BENCH_{run}.json", "bs", f"c{run}", run,
               {"case": 1.0},
               phases={"case": {"compute": 1.0 - reduce_s,
                                "reduce": reduce_s}})
    assert tj.main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "phase-budget" in out
    assert "reduce" in out
    # widening the budget clears the gate
    assert tj.main([str(tmp_path), "--phase-budget-pp", "90"]) == 0


def test_lmo_only_share_regression_fires_the_gate(tmp_path, capsys):
    # The panel-LMO hold (DESIGN.md §17): BENCH_lmo_panel.json history
    # where total mean_s is flat and every phase except `lmo` holds its
    # share — a serial row loop creeping back into the panel LMO grows
    # ONLY the lmo share, and the budget gate must fire on exactly that
    # phase.
    label = "panel_R96_m16"
    for run, lmo_s in ((1, 0.10), (2, 0.11), (3, 0.45)):
        _write(tmp_path, f"BENCH_{run}.json", "lmo_panel", f"c{run}", run,
               {label: 1.0},
               phases={label: {"dispatch": 0.05,
                               "compute": 0.90 - lmo_s,
                               "lmo": lmo_s,
                               "reduce": 0.05}})
    runs = tj.load_runs(tj.find_files([tmp_path]))
    series = tj.phase_series_by_case(runs)
    out = tj.detect_phase_budget_violations(series, budget_pp=5.0,
                                            min_history=3)
    assert [v["phase"] for v in out] == ["lmo"]
    assert out[0]["bench"] == "lmo_panel"
    assert out[0]["label"] == label
    # the lmo split shows up as its own trend row…
    assert "| lmo |" in tj.render_phase_table(series)
    # …and the violation is a BLOCKING exit through main
    assert tj.main([str(tmp_path)]) == 1
    assert "lmo" in capsys.readouterr().out


def _write_metrics(tmp_path, name, mtime, runs=0, hits=0, misses=0,
                   wait_sum=0.0, wait_count=0):
    import os
    rec = {
        "counters": {
            "submits_total": runs + hits,
            "runs_executed_total": runs,
            "cache_hits_total": hits,
            "cache_misses_total": misses,
            "busy_rejections_total": 0,
            "frames_relayed_total": runs,
            "frozen_rows_total": 0,
        },
        "gauges": {"queue_depth": 0, "queue_depth_high_water": 1,
                   "cache_entries": misses},
        "histograms": {
            "queue_wait_seconds": {"bounds": [0.001, 0.01, 0.1],
                                   "counts": [wait_count, 0, 0, 0],
                                   "sum_s": wait_sum,
                                   "count": wait_count},
            "run_latency_seconds": {"bounds": [0.001], "counts": [0, 0],
                                    "sum_s": 0.0, "count": 0},
        },
        "per_phase": {},
    }
    p = tmp_path / name
    p.write_text(json.dumps(rec))
    os.utime(p, (mtime, mtime))
    return p


def test_service_snapshots_ordered_by_mtime_and_derived(tmp_path):
    # written "newest" first: mtime, not directory order, is the axis
    _write_metrics(tmp_path, "late.json", 2_000, runs=10, hits=5,
                   misses=5, wait_sum=1.0, wait_count=10)
    _write_metrics(tmp_path, "early.json", 1_000, runs=2, hits=0,
                   misses=2, wait_sum=0.1, wait_count=2)
    snaps = tj.load_service_snapshots(tj.find_metrics_files([tmp_path]))
    assert [s["name"] for s in snaps] == ["early", "late"]
    runs, wait, ratio = tj.service_derived(snaps[0])
    assert (runs, wait, ratio) == (2.0, 0.05, 0.0)
    runs, wait, ratio = tj.service_derived(snaps[1])
    assert (runs, wait, ratio) == (10.0, 0.1, 0.5)


def test_service_idle_snapshot_has_no_mean_or_ratio(tmp_path):
    p = _write_metrics(tmp_path, "idle.json", 1_000)
    snaps = tj.load_service_snapshots([p])
    runs, wait, ratio = tj.service_derived(snaps[0])
    assert (runs, wait, ratio) == (0.0, None, None)
    # renders as dashes, not a ZeroDivisionError
    table = tj.render_service_table(snaps)
    assert "| idle | 0 | – | – |" in table


def test_service_table_renders_trend_rows(tmp_path):
    _write_metrics(tmp_path, "a.json", 1_000, runs=2, hits=1, misses=3,
                   wait_sum=0.004, wait_count=2)
    _write_metrics(tmp_path, "b.json", 2_000, runs=4, hits=2, misses=2,
                   wait_sum=0.4, wait_count=4)
    snaps = tj.load_service_snapshots(tj.find_metrics_files([tmp_path]))
    table = tj.render_service_table(snaps)
    lines = table.splitlines()
    assert lines[0].startswith("| snapshot |")
    assert "| a | 2 | 2.00ms | 25.0% |" in table
    assert "| b | 4 | 100.00ms | 50.0% |" in table


def test_service_shapeless_file_skipped_not_fatal(tmp_path, capsys):
    (tmp_path / "junk.json").write_text("{\"not\": \"a snapshot\"}")
    (tmp_path / "broken.json").write_text("{")
    _write_metrics(tmp_path, "ok.json", 1_000, runs=1, misses=1)
    snaps = tj.load_service_snapshots(tj.find_metrics_files([tmp_path]))
    assert [s["name"] for s in snaps] == ["ok"]
    err = capsys.readouterr().err
    assert "junk.json" in err
    assert "broken.json" in err


def test_main_service_metrics_only_exits_0(tmp_path, capsys):
    _write_metrics(tmp_path, "snap.json", 1_000, runs=3, hits=1, misses=2,
                   wait_sum=0.03, wait_count=3)
    assert tj.main(["--service-metrics", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "| snapshot |" in out
    assert "| snap | 3 |" in out


def test_main_service_metrics_never_gate_alongside_bench(tmp_path, capsys):
    # a bench regression still exits 1 with service metrics present;
    # service rows render but cannot change the verdict either way
    bench = tmp_path / "bench"
    bench.mkdir()
    for run, mean in ((1, 1.0), (2, 1.0), (3, 5.0)):
        _write(bench, f"BENCH_{run}.json", "bs", f"c{run}", run,
               {"case": mean})
    metrics = tmp_path / "metrics"
    metrics.mkdir()
    _write_metrics(metrics, "snap.json", 1_000, runs=1, misses=1)
    assert tj.main([str(bench), "--service-metrics", str(metrics)]) == 1
    out = capsys.readouterr().out
    assert "| snapshot |" in out
    assert "regression" in out


def test_main_no_inputs_at_all_exits_2(tmp_path, capsys):
    assert tj.main([]) == 2
    empty = tmp_path / "empty"
    empty.mkdir()
    assert tj.main(["--service-metrics", str(empty)]) == 2


def test_merged_history_gates_on_the_newest_run(tmp_path):
    # End-to-end over a merged history tree: three healthy runs then a
    # regressed newest run in a lexically-early directory must exit 1.
    for run, mean in ((3, 1.0), (4, 1.02), (5, 0.98)):
        d = tmp_path / str(run)
        d.mkdir()
        _write(d, "BENCH_bs.json", "bs", f"c{run}", run, {"case": mean})
    d = tmp_path / "12"  # sorts before "3" lexically, newest by run id
    d.mkdir()
    _write(d, "BENCH_bs.json", "bs", "c12", 12, {"case": 5.0})
    assert tj.main([str(tmp_path)]) == 1
