"""Task 1 kernels/model vs the pure-jnp oracle (paper §3.1, Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import mv_grad as mvk
from compile.kernels import ref

from .conftest import assert_close, rngkey


def _panel(seed, n, d, scale=1.0):
    r = jax.random.normal(rngkey(seed), (n, d)) * scale
    rbar = r.mean(axis=0)
    return r - rbar[None, :], rbar


@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 64]),
       st.sampled_from([4, 32, 96, 128]))
def test_cov_matvec_matches_ref(seed, n, d):
    c, _ = _panel(seed, n, d)
    w = jax.random.normal(rngkey(seed + 1), (d,))
    assert_close(mvk.cov_matvec(c, w), ref.cov_matvec_ref(c, w),
                 rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]))
def test_cov_matvec_tile_invariance(seed, tile):
    """The grid decomposition must not change the result."""
    c, _ = _panel(seed, 16, 32)
    w = jax.random.normal(rngkey(seed + 1), (32,))
    assert_close(mvk.cov_matvec(c, w, tile_n=tile),
                 ref.cov_matvec_ref(c, w), rtol=1e-4, atol=1e-4)


def test_cov_matvec_rejects_non_dividing_tile():
    c, _ = _panel(0, 10, 8)
    with pytest.raises(ValueError):
        mvk.cov_matvec(c, jnp.ones(8), tile_n=4)


@given(st.integers(0, 10_000))
def test_mv_grad_and_obj_match_ref(seed):
    c, rbar = _panel(seed, 16, 48)
    w = jax.nn.softmax(jax.random.normal(rngkey(seed + 1), (48,)))
    assert_close(mvk.mv_grad(c, rbar, w), ref.mv_grad_ref(c, rbar, w),
                 rtol=1e-4, atol=1e-5)
    assert_close(mvk.mv_obj(c, rbar, w), ref.mv_obj_ref(c, rbar, w),
                 rtol=1e-4, atol=1e-5)


@given(st.integers(0, 10_000), st.sampled_from([4, 16, 64]))
def test_simplex_lmo_is_optimal_vertex(seed, d):
    """LMO output must be feasible and attain min_{s∈W} sᵀg, which over the
    capped simplex is min(0, min_j g_j)."""
    g = jax.random.normal(rngkey(seed), (d,))
    s = model.simplex_lmo(g)
    s_np = np.asarray(s)
    assert (s_np >= 0).all() and s_np.sum() <= 1 + 1e-6
    value = float(jnp.dot(s, g))
    expected = min(0.0, float(g.min()))
    assert abs(value - expected) < 1e-6


def test_simplex_lmo_all_positive_gradient_returns_origin():
    g = jnp.array([0.5, 1.0, 2.0])
    assert_close(model.simplex_lmo(g), jnp.zeros(3))


@given(st.integers(0, 5_000), st.integers(0, 30))
def test_mv_epoch_matches_ref(seed, k_epoch):
    d, n, m = 32, 16, 5
    w = jnp.ones(d) / d
    mu = jax.random.uniform(rngkey(seed), (d,), minval=-1, maxval=1)
    sigma = jax.random.uniform(rngkey(seed + 1), (d,), minval=0.001,
                               maxval=0.025)
    key = jnp.array([0, seed], dtype=jnp.uint32)
    w1, o1 = model.mv_epoch(w, mu, sigma, key, jnp.int32(k_epoch),
                            n_samples=n, m_inner=m)
    w2, o2 = ref.mv_epoch_ref(w, mu, sigma, key, k_epoch, n, m)
    assert_close(w1, w2, rtol=1e-4, atol=1e-6)
    assert_close(o1, o2, rtol=1e-3, atol=1e-5)


@given(st.integers(0, 5_000))
def test_mv_epoch_keeps_iterate_in_simplex(seed):
    d = 24
    w = jnp.ones(d) / d
    mu = jax.random.uniform(rngkey(seed), (d,), minval=-1, maxval=1)
    sigma = jnp.full((d,), 0.01)
    key = jnp.array([1, seed], dtype=jnp.uint32)
    w1, _ = model.mv_epoch(w, mu, sigma, key, jnp.int32(0),
                           n_samples=8, m_inner=10)
    w1 = np.asarray(w1)
    assert (w1 >= -1e-6).all()
    assert w1.sum() <= 1 + 1e-5


def test_mv_epoch_is_deterministic_in_key():
    d = 16
    w = jnp.ones(d) / d
    mu = jnp.zeros(d)
    sigma = jnp.full((d,), 0.02)
    key = jnp.array([3, 4], dtype=jnp.uint32)
    a = model.mv_epoch(w, mu, sigma, key, jnp.int32(1), n_samples=8,
                       m_inner=3)
    b = model.mv_epoch(w, mu, sigma, key, jnp.int32(1), n_samples=8,
                       m_inner=3)
    assert_close(a[0], b[0], rtol=0, atol=0)


def test_mv_grad_step_composes_to_epoch():
    """m_inner per-iteration dispatches on a fixed panel == the in-graph loop
    (the A1 ablation's correctness precondition)."""
    d, n, m = 32, 16, 5
    w = jnp.ones(d) / d
    mu = jax.random.uniform(rngkey(9), (d,), minval=-1, maxval=1)
    sigma = jnp.full((d,), 0.01)
    key = jnp.array([0, 77], dtype=jnp.uint32)
    r = mu[None, :] + sigma[None, :] * jax.random.normal(key, (n, d))
    rbar = r.mean(axis=0)
    c = r - rbar[None, :]
    w_steps = w
    for mm in range(m):
        w_steps, obj = model.mv_grad_step(c, rbar, w_steps, jnp.int32(2),
                                          jnp.int32(mm), m_inner=m)
    w_epoch, obj_epoch = model.mv_epoch(w, mu, sigma, key, jnp.int32(2),
                                        n_samples=n, m_inner=m)
    assert_close(w_steps, w_epoch, rtol=1e-5, atol=1e-6)
    assert_close(obj, obj_epoch, rtol=1e-4, atol=1e-6)


def test_fw_converges_on_fixed_panel():
    """On a frozen sample panel the FW objective must decrease towards the
    sample optimum (sanity for the step-size schedule)."""
    d, n = 16, 512
    mu = jax.random.uniform(rngkey(5), (d,), minval=-0.5, maxval=1.0)
    sigma = jnp.full((d,), 0.02)
    key = jnp.array([0, 123], dtype=jnp.uint32)
    w = jnp.ones(d) / d
    # objective at the starting point, on the same frozen panel
    r = mu[None, :] + sigma[None, :] * jax.random.normal(key, (n, d))
    rbar = r.mean(axis=0)
    c = r - rbar[None, :]
    obj0 = float(ref.mv_obj_ref(c, rbar, w))
    objs = []
    for k in range(8):
        w, obj = model.mv_epoch(w, mu, sigma, key, jnp.int32(k),
                                n_samples=n, m_inner=10)
        objs.append(float(obj))
    assert objs[-1] < obj0
    # and the trace is non-increasing up to MC-free tolerance (same panel)
    for a, b in zip(objs, objs[1:]):
        assert b <= a + 1e-6
