"""Task 2 kernels/model vs the oracle (paper §3.2, Algorithm 2)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import nv_grad as nvk
from compile.kernels import ref

from .conftest import assert_close, rngkey


def _instance(seed, s, d):
    k1, k2, k3 = (rngkey(seed + i) for i in range(3))
    demand = 20 + 30 * jax.random.uniform(k1, (s, d))
    x = 20 + 30 * jax.random.uniform(k2, (d,))
    kc = 1 + jax.random.uniform(k3, (d,))
    h = 0.2 + 0.3 * jax.random.uniform(k1, (d,))
    v = 3 + 2 * jax.random.uniform(k2, (d,))
    return demand, x, kc, h, v


@given(st.integers(0, 10_000),
       st.sampled_from([4, 8, 32]),
       st.sampled_from([16, 64, 96, 256]))
def test_nv_stats_matches_ref(seed, s, d):
    demand, x, *_ = _instance(seed, s, d)
    ind, over, under = nvk.nv_stats(demand, x)
    ind_r, over_r, under_r = ref.nv_stats_ref(demand, x)
    assert_close(ind, ind_r)
    assert_close(over, over_r, rtol=1e-5, atol=1e-5)
    assert_close(under, under_r, rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10_000), st.sampled_from([1, 4, 16]))
def test_nv_stats_tile_invariance(seed, tile):
    demand, x, *_ = _instance(seed, 8, 32)
    a = nvk.nv_stats(demand, x, tile_d=tile)
    b = ref.nv_stats_ref(demand, x)
    for got, want in zip(a, b):
        assert_close(got, want, rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10_000))
def test_nv_grad_obj_matches_ref(seed):
    demand, x, kc, h, v = _instance(seed, 8, 64)
    g, o = nvk.nv_grad_obj(x, demand, kc, h, v)
    assert_close(g, ref.nv_grad_ref(x, demand, kc, h, v), rtol=1e-5,
                 atol=1e-5)
    assert_close(o, ref.nv_obj_ref(x, demand, kc, h, v), rtol=1e-5,
                 atol=1e-4)


def test_nv_indicator_bounds():
    """The CDF estimate lives in [0,1], so the gradient is bracketed by
    k−v (all demand above x) and k+h (all demand below x)."""
    demand, x, kc, h, v = _instance(3, 16, 32)
    g, _ = nvk.nv_grad_obj(x, demand, kc, h, v)
    g = np.asarray(g)
    lo, hi = np.asarray(kc - v), np.asarray(kc + h)
    assert (g >= lo - 1e-5).all() and (g <= hi + 1e-5).all()


def test_nv_grad_extreme_stock_levels():
    """x below every sample ⇒ indicator 0 ⇒ grad = k−v; x above every
    sample ⇒ indicator 1 ⇒ grad = k+h."""
    demand, _, kc, h, v = _instance(4, 8, 16)
    x_lo = jnp.full((16,), -1e6)
    x_hi = jnp.full((16,), 1e6)
    g_lo, _ = nvk.nv_grad_obj(x_lo, demand, kc, h, v)
    g_hi, _ = nvk.nv_grad_obj(x_hi, demand, kc, h, v)
    assert_close(g_lo, kc - v, rtol=1e-6, atol=1e-6)
    assert_close(g_hi, kc + h, rtol=1e-6, atol=1e-6)


@given(st.integers(0, 5_000))
def test_nv_model_entry_matches_manual_sampling(seed):
    """model.nv_grad's in-graph sampling must equal manually sampling with
    the same key and calling the kernel."""
    d, s = 32, 8
    mu = 20 + 30 * jax.random.uniform(rngkey(seed), (d,))
    sigma = 10 + 10 * jax.random.uniform(rngkey(seed + 1), (d,))
    x = mu * 1.1
    kc = jnp.ones(d) * 2
    h = jnp.ones(d) * 0.5
    v = jnp.ones(d) * 5
    key = jnp.array([2, seed], dtype=jnp.uint32)
    g1, o1 = model.nv_grad(x, mu, sigma, kc, h, v, key, n_samples=s)
    demand = mu[None, :] + sigma[None, :] * jax.random.normal(key, (s, d))
    g2 = ref.nv_grad_ref(x, demand, kc, h, v)
    o2 = ref.nv_obj_ref(x, demand, kc, h, v)
    assert_close(g1, g2, rtol=1e-5, atol=1e-5)
    assert_close(o1, o2, rtol=1e-5, atol=1e-4)


def test_nv_fractile_stationarity():
    """With no resource constraints the optimum is the critical fractile
    x* = Φ⁻¹((v−k)/(v+h)); the MC gradient must vanish there as S grows."""
    d = 8
    mu = jnp.full((d,), 40.0)
    sigma = jnp.full((d,), 5.0)
    kc = jnp.full((d,), 2.0)
    h = jnp.full((d,), 1.0)
    v = jnp.full((d,), 6.0)
    # fractile (v-k)/(v+h) = 4/7
    from scipy.stats import norm
    q = float(norm.ppf(4.0 / 7.0))
    x_star = mu + q * sigma
    key = jnp.array([0, 9], dtype=jnp.uint32)
    demand = mu[None, :] + sigma[None, :] * jax.random.normal(key, (4096, d))
    g = ref.nv_grad_ref(x_star, demand, kc, h, v)
    assert float(jnp.abs(g).max()) < 0.5  # (h+v)=7 scale, MC noise ~7/√4096
