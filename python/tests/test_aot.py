"""AOT spec table + lowering contracts: every artifact lowers to valid HLO
text with the shapes the manifest promises."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

from .conftest import assert_close


def _specs_small():
    return aot.build_specs([32], [64], [16], [32], mv_samples=8, mv_inner=3,
                           nv_samples=8, lr_batch=8, lr_hbatch=16, lr_mem=4)


def test_build_specs_covers_all_entries():
    entries = {s.entry for s in _specs_small()}
    assert entries == {"mv_epoch", "mv_grad_step",
                       "nv_grad", "nv_panel", "nv_grad_panel",
                       "lr_grad", "lr_hvp", "lr_grad_ds", "lr_hvp_ds",
                       "lr_hbuild", "lr_happly", "lr_dir_twoloop",
                       "cv_epoch"}


def test_reps_adds_batched_entries():
    specs = aot.build_specs([32], [64], [16], [32], mv_samples=8,
                            mv_inner=3, nv_samples=8, lr_batch=8,
                            lr_hbatch=16, lr_mem=4, reps=3)
    entries = {s.entry for s in specs}
    for batched in ("mv_epoch_batch", "cv_epoch_batch", "nv_panel_batch",
                    "nv_grad_panel_batch", "lr_grad_batch", "lr_hvp_batch",
                    "lr_dir_batch", "lr_dir_twoloop_batch"):
        assert batched in entries, batched


def test_reps_list_adds_shard_sized_entries():
    # The shard plane (DESIGN.md §13): `--reps R --shards S` emits every
    # batched entry at BOTH the full-R panel size and the R/S shard size,
    # deduplicated and with unique names.
    specs = aot.build_specs([32], [64], [16], [32], mv_samples=8,
                            mv_inner=3, nv_samples=8, lr_batch=8,
                            lr_hbatch=16, lr_mem=4, reps=[6, 2, 6])
    for batched in ("mv_epoch_batch", "cv_epoch_batch", "nv_panel_batch",
                    "nv_grad_panel_batch", "lr_grad_batch", "lr_hvp_batch",
                    "lr_dir_batch", "lr_dir_twoloop_batch"):
        sizes = [s.params["r"] for s in specs if s.entry == batched]
        assert sizes == [2, 6], (batched, sizes)
    names = [s.name for s in specs]
    assert len(names) == len(set(names))
    # the shard-sized mv panel advances 2 rows per dispatch
    shard = next(s for s in specs
                 if s.entry == "mv_epoch_batch" and s.params["r"] == 2)
    assert shard.inputs[0][1] == (2, 32)
    shard.validate()
    # an empty list (or 0) skips the batched entries entirely
    none = aot.build_specs([32], [], [], mv_samples=8, mv_inner=3, reps=[])
    assert all(s.entry != "mv_epoch_batch" for s in none)


def test_cv_epoch_spec_has_joint_iterate():
    spec = next(s for s in _specs_small() if s.entry == "cv_epoch")
    # iterate and output are [w, t] of length d+1
    assert spec.inputs[0][1] == (33,)
    assert spec.outputs[0][1] == (33,)
    assert spec.task == "mean_cvar"


def test_spec_names_are_unique():
    specs = aot.build_specs(aot.DEFAULT_MV, aot.DEFAULT_NV, aot.DEFAULT_LR,
                            aot.DEFAULT_CV)
    names = [s.name for s in specs]
    assert len(names) == len(set(names))


def test_manifest_entry_schema():
    spec = _specs_small()[0]
    ent = spec.manifest_entry()
    assert set(ent) == {"name", "entry", "task", "file", "params",
                        "tuple_output", "inputs", "outputs"}
    for io in ent["inputs"] + ent["outputs"]:
        assert set(io) == {"name", "shape", "dtype"}
        assert io["dtype"] in ("f32", "i32", "u32")


@pytest.mark.parametrize("entry", ["mv_epoch", "nv_grad", "lr_grad",
                                   "lr_hbuild", "lr_dir_twoloop",
                                   "cv_epoch"])
def test_lowering_produces_hlo_text(entry):
    spec = next(s for s in _specs_small() if s.entry == entry)
    text = aot.to_hlo_text(spec.lower())
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lowered_mv_epoch_executes_like_model():
    """Executing the lowered/compiled module through jax gives the same
    numbers as calling the traced python function — i.e. lowering is
    semantics-preserving before it ever reaches Rust."""
    spec = next(s for s in _specs_small() if s.entry == "mv_epoch")
    compiled = spec.lower().compile()
    d = spec.params["d"]
    w = jnp.ones(d, jnp.float32) / d
    mu = jnp.linspace(-0.5, 0.5, d, dtype=jnp.float32)
    sigma = jnp.full((d,), 0.02, jnp.float32)
    key = jnp.array([0, 5], dtype=jnp.uint32)
    k = jnp.int32(1)
    got_w, got_obj = compiled(w, mu, sigma, key, k)
    want_w, want_obj = ref.mv_epoch_ref(w, mu, sigma, key, 1,
                                        spec.params["n"], spec.params["m"])
    assert_close(got_w, want_w, rtol=1e-4, atol=1e-6)
    assert_close(got_obj, want_obj, rtol=1e-3, atol=1e-6)


def test_hlo_text_parseable_roundtrip():
    """The text must be ingestible by the same xla_client the rust side's
    xla_extension wraps (text-parse path)."""
    spec = next(s for s in _specs_small() if s.entry == "lr_happly")
    text = aot.to_hlo_text(spec.lower())
    # Round-trip through the XLA text parser.
    from jax._src.lib import xla_client as xc
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(spec.lower().compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=True)
    assert comp.as_hlo_text() == text


def test_default_dims_are_tile_friendly():
    """Every default dimension must admit the kernels' power-of-two tiling."""
    for d in aot.DEFAULT_MV + aot.FULL_MV:
        assert d % 8 == 0
    for d in aot.DEFAULT_NV + aot.FULL_NV:
        assert d % 16 == 0
    for n in aot.DEFAULT_LR + aot.FULL_LR:
        assert n % 8 == 0
    for d in aot.DEFAULT_CV + aot.FULL_CV:
        assert d % 8 == 0
