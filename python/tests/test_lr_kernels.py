"""Task 3 kernels vs the oracle (paper §3.3, eqs. (10)-(13))."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import logreg as lrk
from compile.kernels import ref

from .conftest import assert_close, rngkey


def _dataset(seed, b, n):
    x = (jax.random.uniform(rngkey(seed), (b, n)) > 0.5).astype(jnp.float32)
    w_true = jax.random.normal(rngkey(seed + 1), (n,))
    z = (x @ w_true > 0).astype(jnp.float32)
    w = jax.random.normal(rngkey(seed + 2), (n,)) * 0.1
    return x, z, w


@given(st.integers(0, 10_000),
       st.sampled_from([8, 16, 64]),
       st.sampled_from([16, 48, 128]))
def test_lr_grad_matches_ref(seed, b, n):
    x, z, w = _dataset(seed, b, n)
    g, loss = lrk.lr_grad(w, x, z)
    g_r, loss_r = ref.lr_grad_ref(w, x, z)
    assert_close(g, g_r, rtol=1e-4, atol=1e-6)
    assert_close(loss, loss_r, rtol=1e-4, atol=1e-6)


@given(st.integers(0, 10_000), st.sampled_from([1, 2, 8]))
def test_lr_grad_tile_invariance(seed, tile):
    x, z, w = _dataset(seed, 16, 32)
    g, loss = lrk.lr_grad(w, x, z, tile_b=tile)
    g_r, loss_r = ref.lr_grad_ref(w, x, z)
    assert_close(g, g_r, rtol=1e-4, atol=1e-6)
    assert_close(loss, loss_r, rtol=1e-4, atol=1e-6)


@given(st.integers(0, 10_000))
def test_lr_grad_matches_autodiff(seed):
    """The fused kernel must agree with jax.grad of the loss itself."""
    x, z, w = _dataset(seed, 16, 24)

    def loss_fn(w):
        u = x @ w
        return jnp.mean(jnp.maximum(u, 0) - u * z
                        + jnp.log1p(jnp.exp(-jnp.abs(u))))

    g_auto = jax.grad(loss_fn)(w)
    g, _ = lrk.lr_grad(w, x, z)
    assert_close(g, g_auto, rtol=1e-4, atol=1e-5)


def test_lr_grad_extreme_logits_stable():
    """Loss must stay finite for |u| large (the stable-BCE form)."""
    n = 8
    x = jnp.ones((4, n), jnp.float32)
    z = jnp.array([0.0, 1.0, 0.0, 1.0])
    w = jnp.full((n,), 50.0)  # u = 400
    g, loss = lrk.lr_grad(w, x, z)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(g)).all()


@given(st.integers(0, 10_000))
def test_lr_hvp_matches_ref(seed):
    x, _, w = _dataset(seed, 32, 24)
    s = jax.random.normal(rngkey(seed + 3), (24,))
    assert_close(lrk.lr_hvp(w, s, x), ref.lr_hvp_ref(w, s, x),
                 rtol=1e-4, atol=1e-6)


@given(st.integers(0, 10_000))
def test_lr_hvp_matches_autodiff_hessian(seed):
    """∇²F s from the kernel == full autodiff Hessian times s (logistic loss
    has exactly the Gauss-Newton Hessian — no residual term)."""
    b, n = 16, 12
    x, z, w = _dataset(seed, b, n)
    s = jax.random.normal(rngkey(seed + 4), (n,))

    def loss_fn(w):
        u = x @ w
        return jnp.mean(jnp.maximum(u, 0) - u * z
                        + jnp.log1p(jnp.exp(-jnp.abs(u))))

    hess = jax.hessian(loss_fn)(w)
    assert_close(lrk.lr_hvp(w, s, x), hess @ s, rtol=1e-3, atol=1e-5)


@given(st.integers(0, 10_000))
def test_lr_hvp_psd(seed):
    """The logistic Hessian is PSD: sᵀ(∇²F)s ≥ 0 for any direction."""
    x, _, w = _dataset(seed, 32, 16)
    s = jax.random.normal(rngkey(seed + 5), (16,))
    y = lrk.lr_hvp(w, s, x)
    assert float(jnp.dot(s, y)) >= -1e-6


def test_lr_model_entries_delegate():
    x, z, w = _dataset(0, 16, 24)
    s = jax.random.normal(rngkey(6), (24,))
    g1, l1 = model.lr_grad(w, x, z)
    g2, l2 = lrk.lr_grad(w, x, z)
    assert_close(g1, g2, rtol=0, atol=0)
    assert_close(model.lr_hvp(w, s, x), lrk.lr_hvp(w, s, x), rtol=0, atol=0)
