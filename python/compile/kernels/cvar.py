"""L1 Pallas kernel for Task 4 (mean-CVaR portfolio): fused per-sample
smoothed-CVaR statistics over the RAW return panel in a single pass.

One pass over the (n, d) return panel R produces, for the joint iterate
x = [w, t] (Rockafellar-Uryasev 2000 with width-η softplus smoothing):

  gacc_j  = Σ_s σ_η(ℓ_s − t) · R_sj      (the tail-gradient matvec Rᵀσ)
  sp_sum  = Σ_s softplus_η(ℓ_s − t)      (the smoothed tail sum)
  sig_sum = Σ_s σ_η(ℓ_s − t)             (∂/∂t of the tail sum, negated)

with per-sample losses ℓ_s = −R_s·w.  TPU mapping (see
/opt/skills/guides/pallas_guide.md): the grid streams row tiles of R
through VMEM; each step does the MXU matvec R_tile @ w, the VPU
sigmoid/softplus on the (tile_n,) loss slice, and accumulates into the
d-length gradient vector and the two scalar sums that stay resident in
VMEM across the whole grid — the same accumulate-across-grid-steps shape
as mv_grad's covariance matvec.

The smoothing constants are mirrored by rust/src/tasks/cvar.rs — keep the
two in sync or the native and XLA arms optimize different objectives.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Mirrored by rust/src/tasks/cvar.rs (ALPHA/ETA/LAMBDA/T_BOX).
ALPHA = 0.9
ETA = 0.05
LAMBDA = 1.0
T_BOX = 2.0


def _cv_stats_kernel(r_ref, w_ref, t_ref, gacc_ref, sp_ref, sig_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)
        sp_ref[...] = jnp.zeros_like(sp_ref)
        sig_ref[...] = jnp.zeros_like(sig_ref)

    r = r_ref[...]                      # (tile_n, d) panel tile
    losses = -(r @ w_ref[...])          # (tile_n,)  MXU matvec
    z = (losses - t_ref[...]) / ETA     # (1,) t broadcasts over the tile
    sig = jax.nn.sigmoid(z)
    # stable softplus: η·(max(z,0) + log1p(e^{−|z|}))
    sp = ETA * (jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z))))
    gacc_ref[...] += sig @ r            # (d,) accumulate Rᵀσ
    sp_ref[...] += jnp.sum(sp)
    sig_ref[...] += jnp.sum(sig)


def pick_tile_n(n, d, budget_bytes=1 << 20):
    """Largest power-of-two row tile that divides n and keeps the panel tile
    within the VMEM budget (same rule as mv_grad.pick_tile_n)."""
    tile = 1
    while tile * 2 <= n and n % (tile * 2) == 0 \
            and tile * 2 * d * 4 <= budget_bytes:
        tile *= 2
    return tile


def cv_stats(panel, w, t, tile_n=None):
    """Fused (Rᵀσ, Σ softplus, Σ σ) for panel (n, d), w (d,), t (1,)."""
    n, d = panel.shape
    tn = tile_n or pick_tile_n(n, d)
    if n % tn != 0:
        raise ValueError(f"tile_n={tn} must divide n={n}")
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _cv_stats_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            scalar,
        ],
        out_specs=(pl.BlockSpec((d,), lambda i: (0,)), scalar, scalar),
        out_shape=(
            jax.ShapeDtypeStruct((d,), panel.dtype),
            jax.ShapeDtypeStruct((1,), panel.dtype),
            jax.ShapeDtypeStruct((1,), panel.dtype),
        ),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(panel, w, t)


def cv_grad(panel, rbar, x):
    """∇f(w, t) over the joint iterate (length d+1; last entry ∂f/∂t)."""
    n, d = panel.shape
    w, t = x[:d], x[d]
    gacc, _, sig_sum = cv_stats(panel, w, jnp.reshape(t, (1,)))
    c = 1.0 / ((1.0 - ALPHA) * n)
    g_w = -rbar - LAMBDA * c * gacc
    g_t = LAMBDA * (1.0 - c * sig_sum[0])
    return jnp.concatenate([g_w, jnp.reshape(g_t, (1,))])


def cv_obj(panel, rbar, x):
    """f(w, t) = −wᵀR̄ + λ·[t + c·Σ_s softplus_η(ℓ_s − t)]."""
    n, d = panel.shape
    w, t = x[:d], x[d]
    _, sp_sum, _ = cv_stats(panel, w, jnp.reshape(t, (1,)))
    c = 1.0 / ((1.0 - ALPHA) * n)
    return -jnp.dot(w, rbar) + LAMBDA * (t + c * sp_sum[0])
