"""L1 Pallas kernel for Task 2 (multi-product newsvendor): fused per-product
Monte-Carlo statistics over the demand panel.

One pass over the (s, d) demand panel produces, per product j:
  ind_j   = mean_s 1{D_sj ≤ x_j}     (the CDF estimate in paper eq. (9))
  over_j  = mean_s max(x_j − D_sj, 0) (overage / holding term of eq. (6))
  under_j = mean_s max(D_sj − x_j, 0) (underage / lost-sales term)

TPU mapping: the grid tiles the *product* axis; each step holds an
(s, tile_d) panel slab in VMEM and does VPU compare/max/mean reductions down
the sample axis — the analogue of the paper's one-thread-per-sample indicator
counting, but vectorized down 128-wide lanes.  No accumulation across grid
steps: each product column belongs to exactly one tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nv_stats_kernel(d_ref, x_ref, ind_ref, over_ref, under_ref):
    dm = d_ref[...]                      # (s, tile_d)
    x = x_ref[...]                       # (tile_d,)
    le = (dm <= x[None, :]).astype(x.dtype)
    diff = x[None, :] - dm
    ind_ref[...] = le.mean(axis=0)
    over_ref[...] = jnp.maximum(diff, 0.0).mean(axis=0)
    under_ref[...] = jnp.maximum(-diff, 0.0).mean(axis=0)


def pick_tile_d(d, s, budget_bytes=1 << 20):
    """Largest power-of-two product tile dividing d with the slab in budget."""
    tile = 1
    while tile * 2 <= d and d % (tile * 2) == 0 \
            and tile * 2 * s * 4 <= budget_bytes:
        tile *= 2
    return tile


def nv_stats(demand, x, tile_d=None):
    """Fused (ind, over, under) per-product means for demand (s, d), x (d,)."""
    s, d = demand.shape
    td = tile_d or pick_tile_d(d, s)
    if d % td != 0:
        raise ValueError(f"tile_d={td} must divide d={d}")
    vec = pl.BlockSpec((td,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((d,), x.dtype)
    return pl.pallas_call(
        _nv_stats_kernel,
        grid=(d // td,),
        in_specs=[
            pl.BlockSpec((s, td), lambda i: (0, i)),
            vec,
        ],
        out_specs=(vec, vec, vec),
        out_shape=(out, out, out),
        interpret=True,
    )(demand, x)


def nv_grad_obj(x, demand, kc, h, v):
    """Gradient (9) and sample-average cost (6) from one fused kernel pass."""
    ind, over, under = nv_stats(demand, x)
    grad = kc - v + (h + v) * ind
    obj = jnp.dot(kc, x) + jnp.dot(h, over) + jnp.dot(v, under)
    return grad, obj
