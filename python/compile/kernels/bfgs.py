"""L1 Pallas kernel for Algorithm 4 (Hessian updating): the symmetric BFGS
rank update applied tile-by-tile over the n×n inverse-Hessian approximation.

Expanding the paper's update with hy = H y (H symmetric) and q = yᵀ H y:

  H′ = (I − ρ s yᵀ) H (I − ρ y sᵀ) + ρ s sᵀ
     = H − ρ s (hy)ᵀ − ρ (hy) sᵀ + (ρ² q + ρ) s sᵀ

so each (i, j) tile of H′ needs only the (i, j) tile of H plus the i- and
j-tiles of s and hy and two scalars — a perfectly parallel 2-D grid with no
cross-tile reduction: the "large-scale matrix operations" showcase of the
paper's second-order method.  The matvec hy = H y and the scalar q are
computed by XLA outside the kernel (they fuse into the surrounding graph).

A masked update (ρ = 0 ⇒ coef = [0, 0]) leaves H unchanged, which is how the
fori_loop in model.lr_hbuild skips invalid correction-memory slots.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bfgs_update_kernel(h_ref, si_ref, sj_ref, hyi_ref, hyj_ref, coef_ref,
                        o_ref):
    rho = coef_ref[0]
    c2 = coef_ref[1]                      # ρ²q + ρ
    si = si_ref[...]                      # (tile,) rows
    sj = sj_ref[...]                      # (tile,) cols
    hyi = hyi_ref[...]
    hyj = hyj_ref[...]
    o_ref[...] = (h_ref[...]
                  - rho * (si[:, None] * hyj[None, :])
                  - rho * (hyi[:, None] * sj[None, :])
                  + c2 * (si[:, None] * sj[None, :]))


def pick_tile(n, budget_bytes=1 << 20):
    """Power-of-two tile edge dividing n with two f32 tiles within budget."""
    tile = 1
    while tile * 2 <= n and n % (tile * 2) == 0 \
            and 2 * (tile * 2) ** 2 * 4 <= budget_bytes:
        tile *= 2
    return tile


def bfgs_rank_update(h, s, hy, coef, tile=None):
    """One Algorithm-4 update H′ from H (n, n), s, hy (n,), coef = [ρ, ρ²q+ρ]."""
    n = h.shape[0]
    t = tile or pick_tile(n)
    if n % t != 0:
        raise ValueError(f"tile={t} must divide n={n}")
    row = pl.BlockSpec((t,), lambda i, j: (i,))
    col = pl.BlockSpec((t,), lambda i, j: (j,))
    return pl.pallas_call(
        _bfgs_update_kernel,
        grid=(n // t, n // t),
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j: (i, j)),
            row, col, row, col,
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), h.dtype),
        interpret=True,
    )(h, s, s, hy, hy, coef)
