"""L1 Pallas kernels for Task 3 (binary classification): fused logistic
minibatch gradient/loss and the Gauss-Newton Hessian-vector product.

Both kernels stream row tiles of the design-matrix batch through VMEM and
accumulate the n-length output across grid steps:

  grad:  u = X_t w;  c = σ(u);  g += (c − z_t) Xᵀ_t;  loss += Σ bce(u, z_t)
  hvp:   u = X_t w;  a = σ(u)(1−σ(u));  y += (a ⊙ (X_t s)) Xᵀ_t

The fusion (matvec + nonlinearity + rank-reduction in one pass) is the
TPU-shaped version of the paper's per-sample CUDA threads: two MXU matvecs
and a VPU sigmoid per tile, the d×1 accumulator resident in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .mv_grad import pick_tile_n


def _lr_grad_kernel(x_ref, z_ref, w_ref, g_ref, l_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    xb = x_ref[...]                       # (tile_b, n)
    z = z_ref[...]                        # (tile_b,)
    u = xb @ w_ref[...]                   # (tile_b,)
    c = jax.nn.sigmoid(u)
    g_ref[...] += (c - z) @ xb            # (n,)
    # stable BCE: max(u,0) − u·z + log1p(e^{−|u|}), summed (mean taken outside)
    l_ref[...] += jnp.sum(
        jnp.maximum(u, 0.0) - u * z + jnp.log1p(jnp.exp(-jnp.abs(u)))
    )[None]


def lr_grad(w, xb, zb, tile_b=None):
    """Minibatch logistic gradient (paper eq. (12)) and mean BCE loss."""
    b, n = xb.shape
    tb = tile_b or pick_tile_n(b, n)
    if b % tb != 0:
        raise ValueError(f"tile_b={tb} must divide b={b}")
    g, l = pl.pallas_call(
        _lr_grad_kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((1,), w.dtype),
        ),
        interpret=True,
    )(xb, zb, w)
    return g / b, l[0] / b


def _lr_hvp_kernel(x_ref, w_ref, s_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]                       # (tile_b, n)
    u = xb @ w_ref[...]
    c = jax.nn.sigmoid(u)
    a = c * (1.0 - c)
    o_ref[...] += (a * (xb @ s_ref[...])) @ xb


def lr_hvp(wbar, s, xh, tile_b=None):
    """Sub-sampled Hessian-vector product (paper eq. (13)) for the correction
    pair y_t = ∇²F(ω̄_t)·s_t of Algorithm 3 line 18."""
    bh, n = xh.shape
    tb = tile_b or pick_tile_n(bh, n)
    if bh % tb != 0:
        raise ValueError(f"tile_b={tb} must divide b_H={bh}")
    vec = pl.BlockSpec((n,), lambda i: (0,))
    out = pl.pallas_call(
        _lr_hvp_kernel,
        grid=(bh // tb,),
        in_specs=[pl.BlockSpec((tb, n), lambda i: (i, 0)), vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((n,), wbar.dtype),
        interpret=True,
    )(xh, wbar, s)
    return out / bh
