"""Pure-jnp correctness oracles for every Pallas kernel and L2 entry point.

These are the ground truth the pytest suite checks the Pallas kernels and the
AOT-lowered programs against.  They are deliberately written in the most
direct form (materializing intermediates, no tiling) so that a mismatch
always points at the kernel, not the oracle.
"""

import jax
import jax.numpy as jnp

EPS = 1e-10


# ---------------------------------------------------------------------------
# Task 1 — mean-variance portfolio (paper §3.1)
# ---------------------------------------------------------------------------

def cov_matvec_ref(c, w):
    """(CᵀC)w for the centered sample panel C (n, d) — no 1/(n-1) scaling."""
    return c.T @ (c @ w)


def mv_grad_ref(c, rbar, w):
    """∇f̂(w) = Ĉw − R̄ with Ĉ the empirical covariance of the samples."""
    n = c.shape[0]
    return cov_matvec_ref(c, w) / (n - 1) - rbar


def mv_obj_ref(c, rbar, w):
    """f̂(w) = ½ wᵀĈw − wᵀR̄  (paper eq. (4))."""
    n = c.shape[0]
    return 0.5 * jnp.dot(w, cov_matvec_ref(c, w)) / (n - 1) - jnp.dot(w, rbar)


def simplex_lmo_ref(g):
    """argmin_{s ∈ W} sᵀg over W = {s ≥ 0, 1ᵀs ≤ 1}: a vertex of the simplex.

    The minimum is attained at e_j for j = argmin g when min g < 0, and at the
    origin otherwise.
    """
    j = jnp.argmin(g)
    d = g.shape[0]
    return jnp.where(g[j] < 0, jax.nn.one_hot(j, d, dtype=g.dtype),
                     jnp.zeros(d, g.dtype))


def mv_epoch_ref(w, mu, sigma, key, k_epoch, n_samples, m_inner):
    """Reference for one Frank-Wolfe epoch (Alg. 1 lines 5-12): resample once,
    run m_inner FW steps with step size 2/(kM+m+2)."""
    d = w.shape[0]
    r = mu[None, :] + sigma[None, :] * jax.random.normal(
        key, (n_samples, d), dtype=w.dtype)
    rbar = r.mean(axis=0)
    c = r - rbar[None, :]
    for m in range(m_inner):
        g = mv_grad_ref(c, rbar, w)
        s = simplex_lmo_ref(g)
        gamma = 2.0 / (k_epoch * m_inner + m + 2.0)
        w = w + gamma * (s - w)
    return w, mv_obj_ref(c, rbar, w)


# ---------------------------------------------------------------------------
# Task 4 — smoothed mean-CVaR portfolio (registry extension, DESIGN.md §12)
# ---------------------------------------------------------------------------

def cv_stats_ref(panel, w, t, eta):
    """Direct-form (Rᵀσ, Σ softplus_η, Σ σ_η) over losses ℓ = −R·w."""
    losses = -(panel @ w)
    z = (losses - t) / eta
    sig = jax.nn.sigmoid(z)
    sp = eta * (jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z))))
    return sig @ panel, jnp.sum(sp), jnp.sum(sig)


def cv_grad_ref(panel, rbar, x, alpha, eta, lam):
    """∇f of the Rockafellar-Uryasev smoothed mean-CVaR objective over the
    joint iterate x = [w, t]."""
    n, d = panel.shape
    gacc, _, sig_sum = cv_stats_ref(panel, x[:d], x[d], eta)
    c = 1.0 / ((1.0 - alpha) * n)
    g_w = -rbar - lam * c * gacc
    g_t = lam * (1.0 - c * sig_sum)
    return jnp.concatenate([g_w, jnp.reshape(g_t, (1,))])


def cv_obj_ref(panel, rbar, x, alpha, eta, lam):
    """f(w, t) = −wᵀR̄ + λ·[t + c·Σ softplus_η(ℓ − t)]."""
    n, d = panel.shape
    _, sp_sum, _ = cv_stats_ref(panel, x[:d], x[d], eta)
    c = 1.0 / ((1.0 - alpha) * n)
    return -jnp.dot(x[:d], rbar) + lam * (x[d] + c * sp_sum)


# ---------------------------------------------------------------------------
# Task 2 — multi-product newsvendor (paper §3.2)
# ---------------------------------------------------------------------------

def nv_stats_ref(demand, x):
    """Per-product Monte-Carlo statistics over the demand panel (s, d):
    indicator mean  mean_s 1{D ≤ x},
    overage mean    mean_s max(x − D, 0),
    underage mean   mean_s max(D − x, 0).
    """
    le = (demand <= x[None, :]).astype(x.dtype)
    diff = x[None, :] - demand
    return le.mean(axis=0), jnp.maximum(diff, 0).mean(axis=0), \
        jnp.maximum(-diff, 0).mean(axis=0)


def nv_grad_ref(x, demand, kc, h, v):
    """MC gradient (paper eq. (9)): f̂ⱼ′ = kⱼ − vⱼ + (hⱼ+vⱼ)·mean 1{d ≤ xⱼ}."""
    ind, _, _ = nv_stats_ref(demand, x)
    return kc - v + (h + v) * ind


def nv_obj_ref(x, demand, kc, h, v):
    """Empirical expected cost (paper eq. (6), sample-average form)."""
    _, over, under = nv_stats_ref(demand, x)
    return jnp.dot(kc, x) + jnp.dot(h, over) + jnp.dot(v, under)


# ---------------------------------------------------------------------------
# Task 3 — logistic binary classification (paper §3.3)
# ---------------------------------------------------------------------------

def lr_grad_ref(w, xb, zb):
    """Minibatch gradient (12) and mean BCE loss of the logistic model."""
    u = xb @ w
    c = jax.nn.sigmoid(u)
    b = xb.shape[0]
    g = xb.T @ (c - zb) / b
    # numerically stable BCE: max(u,0) − u·z + log(1 + e^{−|u|})
    loss = jnp.mean(jnp.maximum(u, 0) - u * zb + jnp.log1p(jnp.exp(-jnp.abs(u))))
    return g, loss


def lr_hvp_ref(wbar, s, xh):
    """Sub-sampled Hessian-vector product (13): ∇²F(ω̄)s = Xᵀdiag(a)Xs / b_H
    with a = c(1−c)."""
    u = xh @ wbar
    c = jax.nn.sigmoid(u)
    a = c * (1.0 - c)
    return xh.T @ (a * (xh @ s)) / xh.shape[0]


def lr_hbuild_ref(s_mem, y_mem, m_count):
    """Algorithm 4 (explicit H): H ← (I−ρsyᵀ)H(I−ρysᵀ)+ρssᵀ over the valid
    correction pairs, H₀ = (sᵀy)/(yᵀy)·I from the newest pair.

    s_mem, y_mem: (mem, n) with rows [0, m_count) valid, oldest first.
    """
    mem, n = s_mem.shape
    m_count = int(m_count)
    if m_count <= 0:
        return jnp.eye(n, dtype=s_mem.dtype)
    s_l, y_l = s_mem[m_count - 1], y_mem[m_count - 1]
    gamma = jnp.dot(s_l, y_l) / jnp.maximum(jnp.dot(y_l, y_l), EPS)
    h = gamma * jnp.eye(n, dtype=s_mem.dtype)
    for j in range(m_count):
        s, y = s_mem[j], y_mem[j]
        rho = 1.0 / jnp.maximum(jnp.dot(y, s), EPS)
        e = jnp.eye(n, dtype=s_mem.dtype)
        h = (e - rho * jnp.outer(s, y)) @ h @ (e - rho * jnp.outer(y, s)) \
            + rho * jnp.outer(s, s)
    return h


def lr_dir_ref(s_mem, y_mem, m_count, g):
    """H·g via the explicit Algorithm-4 matrix (oracle for both lr_hdir paths)."""
    return lr_hbuild_ref(s_mem, y_mem, m_count) @ g


def lr_twoloop_ref(s_mem, y_mem, m_count, g):
    """Classic L-BFGS two-loop recursion over the valid pairs (oldest first in
    memory); mathematically identical to lr_dir_ref."""
    m_count = int(m_count)
    if m_count <= 0:
        return g
    alphas = []
    q = g
    for j in range(m_count - 1, -1, -1):
        s, y = s_mem[j], y_mem[j]
        rho = 1.0 / jnp.maximum(jnp.dot(y, s), EPS)
        a = rho * jnp.dot(s, q)
        q = q - a * y
        alphas.append((j, a, rho))
    s_l, y_l = s_mem[m_count - 1], y_mem[m_count - 1]
    gamma = jnp.dot(s_l, y_l) / jnp.maximum(jnp.dot(y_l, y_l), EPS)
    r = gamma * q
    for j, a, rho in reversed(alphas):
        s, y = s_mem[j], y_mem[j]
        b = rho * jnp.dot(y, r)
        r = r + s * (a - b)
    return r
