"""L1 Pallas kernel for Task 1 (mean-variance portfolio): the centered
covariance matvec  (CᵀC)·w  computed in a single pass over the sample panel,
never materializing the d×d covariance matrix.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA story tiles
the N×d sample panel across threadblocks; here each grid step streams one
row-tile of C through VMEM, does the two MXU matvecs (C_tile @ w, then
u @ C_tile) and accumulates into the d-length output that stays resident in
VMEM across the whole grid.

VMEM budget per grid step (f32): tile_n·d (panel tile) + 2·d (w, out).
With tile_n = 8 lanes of 128·k columns this sits well under the ~16 MiB VMEM
of a TPU core for d ≤ 2¹⁸; the AOT spec keeps tile_n·d ≤ 1 MiB by default.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cov_matvec_kernel(c_ref, w_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    c = c_ref[...]                 # (tile_n, d) panel tile
    u = c @ w_ref[...]             # (tile_n,)  MXU matvec #1
    o_ref[...] += u @ c            # (d,)       MXU matvec #2, accumulate


def pick_tile_n(n, d, budget_bytes=1 << 20):
    """Largest power-of-two row tile that divides n and keeps the panel tile
    within the VMEM budget."""
    tile = 1
    while tile * 2 <= n and n % (tile * 2) == 0 \
            and tile * 2 * d * 4 <= budget_bytes:
        tile *= 2
    return tile


def cov_matvec(c, w, tile_n=None):
    """(CᵀC) w for C (n, d), w (d,) — unscaled; callers divide by (n−1)."""
    n, d = c.shape
    tn = tile_n or pick_tile_n(n, d)
    if n % tn != 0:
        raise ValueError(f"tile_n={tn} must divide n={n}")
    return pl.pallas_call(
        _cov_matvec_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), c.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(c, w)


def mv_grad(c, rbar, w):
    """∇f̂(w) = Ĉw − R̄ using the kernel; Ĉ = CᵀC/(n−1)."""
    n = c.shape[0]
    return cov_matvec(c, w) / (n - 1) - rbar


def mv_obj(c, rbar, w):
    """f̂(w) = ½ wᵀĈw − wᵀR̄ using the kernel."""
    n = c.shape[0]
    return 0.5 * jnp.dot(w, cov_matvec(c, w)) / (n - 1) - jnp.dot(w, rbar)
