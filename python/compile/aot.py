"""AOT lowering: every (entry × size) in the spec table → one HLO-text
artifact + a manifest the Rust runtime validates shapes against.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out ../artifacts [--full]
                       [--entries mv_epoch,nv_grad] [--paper-batches]
                       [--reps R]   # + replication-batched artifacts (§11)
                       [--shards S] # + shard-sized [R/S × …] batch
                                    #   artifacts for `--exec batch
                                    #   --shards S` runs (DESIGN.md §13;
                                    #   S must divide R)
                       [--list]     # dry-run: print the spec table only
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32, I32, U32 = "f32", "i32", "u32"
_DTYPES = {F32: jnp.float32, I32: jnp.int32, U32: jnp.uint32}


def to_hlo_text(lowered, return_tuple=True) -> str:
    """`return_tuple=False` is used for single-output programs whose output
    the Rust runtime wants to keep as a *device buffer* and feed into the
    next program via `execute_b` (PJRT cannot feed a tuple buffer back as an
    array input)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def _arg(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


class Spec:
    """One artifact: entry point, static params, and typed I/O signature.

    `tuple_output=False` marks single-output programs lowered without the
    result tuple so the Rust runtime can keep the output device-resident.
    """

    def __init__(self, entry, fn, params, inputs, outputs, task,
                 tuple_output=True):
        self.entry = entry
        self.fn = fn
        self.params = params                       # static (baked-in) params
        self.inputs = inputs                       # [(name, shape, dtype)]
        self.outputs = outputs                     # [(name, shape, dtype)]
        self.task = task
        self.tuple_output = tuple_output
        if not tuple_output:
            assert len(outputs) == 1, "untupled artifacts are single-output"
        ptag = "_".join(f"{k}{v}" for k, v in params.items())
        self.name = f"{entry}_{ptag}" if ptag else entry

    def lower(self):
        args = [_arg(s, t) for _, s, t in self.inputs]
        return jax.jit(self.fn).lower(*args)

    def validate(self):
        """Trace-validate BOTH sides of the signature: the inputs (an
        arity/shape mismatch with the model entry point fails the trace)
        and the outputs (the traced avals must match the declared
        `outputs` table the manifest — and therefore the Rust runtime's
        shape checks — are built from)."""
        args = [_arg(s, t) for _, s, t in self.inputs]
        traced = jax.tree_util.tree_leaves(jax.eval_shape(self.fn, *args))
        if len(traced) != len(self.outputs):
            raise ValueError(
                f"{self.name}: model returns {len(traced)} outputs, "
                f"spec declares {len(self.outputs)}")
        for got, (name, shape, dt) in zip(traced, self.outputs):
            if tuple(got.shape) != tuple(shape) or got.dtype != _DTYPES[dt]:
                raise ValueError(
                    f"{self.name} output '{name}': traced "
                    f"{got.dtype}{list(got.shape)} != declared "
                    f"{dt}{list(shape)}")

    def hlo_text(self):
        return to_hlo_text(self.lower(), return_tuple=self.tuple_output)

    def manifest_entry(self):
        return {
            "name": self.name,
            "entry": self.entry,
            "task": self.task,
            "file": f"{self.name}.hlo.txt",
            "params": self.params,
            "tuple_output": self.tuple_output,
            "inputs": [{"name": n, "shape": list(s), "dtype": t}
                       for n, s, t in self.inputs],
            "outputs": [{"name": n, "shape": list(s), "dtype": t}
                        for n, s, t in self.outputs],
        }


def build_specs(mv_dims, nv_dims, lr_dims, cv_dims=(), *, mv_samples=64,
                mv_inner=25, nv_samples=32, lr_batch=64, lr_hbatch=256,
                lr_mem=25, reps=0):
    """The full artifact table.  Dimension lists come from the CLI; batch
    and inner-loop parameters mirror the paper's §4.1 settings (modulo the
    tile-friendly rounding documented in DESIGN.md §10).  `reps` adds the
    replication-batched entries (DESIGN.md §11): vmap lowerings that
    advance that many replications in one dispatch — an int for one batch
    size, or a sequence of ints for several (the shard plane, DESIGN.md
    §13, wants both the full-R panel and the `R/S` shard size; 0 = skip).
    `cv_dims` adds the mean-CVaR task registered through the task-registry
    plane (DESIGN.md §12); it shares the mv panel shape knobs (same asset
    universe)."""
    if isinstance(reps, int):
        reps = [reps]
    rep_counts = sorted({int(r) for r in reps if int(r) > 0})
    specs = []

    for d in mv_dims:
        n, m = mv_samples, mv_inner
        specs.append(Spec(
            "mv_epoch",
            functools.partial(model.mv_epoch, n_samples=n, m_inner=m),
            {"d": d, "n": n, "m": m},
            [("w", (d,), F32), ("mu", (d,), F32), ("sigma", (d,), F32),
             ("key", (2,), U32), ("k_epoch", (), I32)],
            [("w_out", (d,), F32), ("obj", (), F32)],
            "mean_variance"))
        for rr in rep_counts:
            specs.append(Spec(
                "mv_epoch_batch",
                functools.partial(model.mv_epoch_batch, n_samples=n,
                                  m_inner=m),
                {"d": d, "n": n, "m": m, "r": rr},
                [("w", (rr, d), F32), ("mu", (d,), F32),
                 ("sigma", (d,), F32), ("keys", (rr, 2), U32),
                 ("k_epoch", (), I32)],
                [("w_out", (rr, d), F32), ("obj", (rr,), F32)],
                "mean_variance"))

    # per-iteration dispatch ablation (A1): one mid-size variant
    if mv_dims:
        d, n, m = mv_dims[len(mv_dims) // 2], mv_samples, mv_inner
        specs.append(Spec(
            "mv_grad_step",
            functools.partial(model.mv_grad_step, m_inner=m),
            {"d": d, "n": n, "m": m},
            [("c", (n, d), F32), ("rbar", (d,), F32), ("w", (d,), F32),
             ("k_epoch", (), I32), ("m_iter", (), I32)],
            [("w_out", (d,), F32), ("obj", (), F32)],
            "mean_variance"))

    for d in cv_dims:
        # Task 4 (mean-CVaR): the joint iterate is [w, t] of length d+1;
        # the panel shape mirrors mv (same asset universe).
        n, m = mv_samples, mv_inner
        specs.append(Spec(
            "cv_epoch",
            functools.partial(model.cv_epoch, n_samples=n, m_inner=m),
            {"d": d, "n": n, "m": m},
            [("x", (d + 1,), F32), ("mu", (d,), F32), ("sigma", (d,), F32),
             ("key", (2,), U32), ("k_epoch", (), I32)],
            [("x_out", (d + 1,), F32), ("obj", (), F32)],
            "mean_cvar"))
        for rr in rep_counts:
            specs.append(Spec(
                "cv_epoch_batch",
                functools.partial(model.cv_epoch_batch, n_samples=n,
                                  m_inner=m),
                {"d": d, "n": n, "m": m, "r": rr},
                [("x", (rr, d + 1), F32), ("mu", (d,), F32),
                 ("sigma", (d,), F32), ("keys", (rr, 2), U32),
                 ("k_epoch", (), I32)],
                [("x_out", (rr, d + 1), F32), ("obj", (rr,), F32)],
                "mean_cvar"))

    for d in nv_dims:
        s = nv_samples
        specs.append(Spec(
            "nv_grad",
            functools.partial(model.nv_grad, n_samples=s),
            {"d": d, "s": s},
            [("x", (d,), F32), ("mu", (d,), F32), ("sigma", (d,), F32),
             ("kc", (d,), F32), ("h", (d,), F32), ("v", (d,), F32),
             ("key", (2,), U32)],
            [("grad", (d,), F32), ("obj", (), F32)],
            "newsvendor"))
        for rr in rep_counts:
            # device-resident batched epoch path: one panel dispatch per
            # epoch, one resident-gradient dispatch per inner iteration
            specs.append(Spec(
                "nv_panel_batch",
                functools.partial(model.nv_panel_batch, n_samples=s),
                {"d": d, "s": s, "r": rr},
                [("mu", (d,), F32), ("sigma", (d,), F32),
                 ("keys", (rr, 2), U32)],
                [("panel", (rr, s, d), F32)],
                "newsvendor"))
            specs.append(Spec(
                "nv_grad_panel_batch", model.nv_grad_panel_batch,
                {"d": d, "s": s, "r": rr},
                [("x", (rr, d), F32), ("panel", (rr, s, d), F32),
                 ("kc", (d,), F32), ("h", (d,), F32), ("v", (d,), F32)],
                [("grad", (rr, d), F32), ("obj", (rr,), F32)],
                "newsvendor"))
        # device-resident epoch path (§Perf): sample the panel once per
        # epoch, keep it on device, evaluate gradients against the buffer
        specs.append(Spec(
            "nv_panel",
            functools.partial(model.nv_panel, n_samples=s),
            {"d": d, "s": s},
            [("mu", (d,), F32), ("sigma", (d,), F32), ("key", (2,), U32)],
            [("panel", (s, d), F32)],
            "newsvendor"))
        specs.append(Spec(
            "nv_grad_panel", model.nv_grad_panel, {"d": d, "s": s},
            [("x", (d,), F32), ("panel", (s, d), F32), ("kc", (d,), F32),
             ("h", (d,), F32), ("v", (d,), F32)],
            [("grad", (d,), F32), ("obj", (), F32)],
            "newsvendor"))

    for n in lr_dims:
        b, bh, mem = lr_batch, lr_hbatch, lr_mem
        rows = 30 * n  # paper's N = 30n dataset convention
        specs.append(Spec(
            "lr_grad", model.lr_grad, {"n": n, "b": b},
            [("w", (n,), F32), ("xb", (b, n), F32), ("zb", (b,), F32)],
            [("grad", (n,), F32), ("loss", (), F32)],
            "classification"))
        specs.append(Spec(
            "lr_hvp", model.lr_hvp, {"n": n, "bh": bh},
            [("wbar", (n,), F32), ("s", (n,), F32), ("xh", (bh, n), F32)],
            [("y", (n,), F32)],
            "classification"))
        # device-resident dataset path (§Perf): the full design matrix is
        # uploaded once; per-iteration inputs shrink to (w, idx)
        specs.append(Spec(
            "lr_grad_ds", model.lr_grad_ds, {"n": n, "b": b, "rows": rows},
            [("w", (n,), F32), ("x_full", (rows, n), F32),
             ("z_full", (rows,), F32), ("idx", (b,), I32)],
            [("grad", (n,), F32), ("loss", (), F32)],
            "classification"))
        specs.append(Spec(
            "lr_hvp_ds", model.lr_hvp_ds, {"n": n, "bh": bh, "rows": rows},
            [("wbar", (n,), F32), ("s", (n,), F32), ("x_full", (rows, n), F32),
             ("idx", (bh,), I32)],
            [("y", (n,), F32)],
            "classification"))
        for rr in rep_counts:
            specs.append(Spec(
                "lr_grad_batch", model.lr_grad_batch,
                {"n": n, "b": b, "rows": rows, "r": rr},
                [("w", (rr, n), F32), ("x_full", (rows, n), F32),
                 ("z_full", (rows,), F32), ("idx", (rr, b), I32)],
                [("grad", (rr, n), F32), ("loss", (rr,), F32)],
                "classification"))
            specs.append(Spec(
                "lr_hvp_batch", model.lr_hvp_batch,
                {"n": n, "bh": bh, "rows": rows, "r": rr},
                [("wbar", (rr, n), F32), ("s", (rr, n), F32),
                 ("x_full", (rows, n), F32), ("idx", (rr, bh), I32)],
                [("y", (rr, n), F32)],
                "classification"))
            # padded batched Algorithm-4 directions (DESIGN.md §11): the
            # driver's dense [R × mem × n] correction panels + per-row
            # valid counts in, all R directions out — ONE dispatch closes
            # the last per-replication call of the batched SQN spine
            specs.append(Spec(
                "lr_dir_batch", model.lr_dir_batch,
                {"n": n, "mem": mem, "r": rr},
                [("s_mem", (rr, mem, n), F32),
                 ("y_mem", (rr, mem, n), F32),
                 ("m_count", (rr,), I32), ("g", (rr, n), F32)],
                [("d", (rr, n), F32)],
                "classification"))
            specs.append(Spec(
                "lr_dir_twoloop_batch", model.lr_dir_twoloop_batch,
                {"n": n, "mem": mem, "r": rr},
                [("s_mem", (rr, mem, n), F32),
                 ("y_mem", (rr, mem, n), F32),
                 ("m_count", (rr,), I32), ("g", (rr, n), F32)],
                [("d", (rr, n), F32)],
                "classification"))
        specs.append(Spec(
            "lr_hbuild", model.lr_hbuild, {"n": n, "mem": mem},
            [("s_mem", (mem, n), F32), ("y_mem", (mem, n), F32),
             ("m_count", (), I32)],
            [("h", (n, n), F32)],
            "classification"))
        specs.append(Spec(
            "lr_happly", model.lr_happly, {"n": n},
            [("h", (n, n), F32), ("g", (n,), F32)],
            [("d", (n,), F32)],
            "classification"))
        specs.append(Spec(
            "lr_dir_twoloop", model.lr_dir_twoloop, {"n": n, "mem": mem},
            [("s_mem", (mem, n), F32), ("y_mem", (mem, n), F32),
             ("m_count", (), I32), ("g", (n,), F32)],
            [("d", (n,), F32)],
            "classification"))

    return specs


DEFAULT_MV = [128, 512, 2048]
DEFAULT_NV = [256, 2048, 16384]
DEFAULT_LR = [64, 256, 1024]
DEFAULT_CV = [128, 512, 2048]
FULL_MV = DEFAULT_MV + [8192]
FULL_NV = DEFAULT_NV + [65536]
FULL_LR = DEFAULT_LR + [2048]
FULL_CV = DEFAULT_CV + [8192]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--entries", default="",
                    help="comma-separated entry filter (default: all)")
    ap.add_argument("--full", action="store_true",
                    help="add the larger paper-scale size variants")
    ap.add_argument("--paper-batches", action="store_true",
                    help="use the paper's b=50, b_H=300 instead of the "
                         "tile-friendly 64/256")
    ap.add_argument("--mv-dims", default="", help="override, e.g. 128,512")
    ap.add_argument("--nv-dims", default="")
    ap.add_argument("--lr-dims", default="")
    ap.add_argument("--cv-dims", default="",
                    help="mean-CVaR sizes (task 4, DESIGN.md §12)")
    ap.add_argument("--reps", type=int, default=0,
                    help="also emit replication-batched artifacts that "
                         "advance this many replications per dispatch "
                         "(DESIGN.md §11; 0 = skip)")
    ap.add_argument("--shards", type=int, default=1,
                    help="also emit shard-sized batch artifacts with "
                         "reps/shards rows per dispatch, for `--exec "
                         "batch --shards S` runs on the XLA arm "
                         "(DESIGN.md §13; requires --reps and must "
                         "divide it)")
    ap.add_argument("--list", action="store_true",
                    help="dry-run: trace-validate every spec against its "
                         "model entry point (jax tracing only — no XLA "
                         "build, nothing written), print the signatures, "
                         "and exit.  The CI python job uses this to catch "
                         "AOT-layer breakage cheaply")
    args = ap.parse_args()

    def dims(flag, default, full):
        if flag:
            return [int(x) for x in flag.split(",") if x]
        return full if args.full else default

    rep_counts = [args.reps] if args.reps > 0 else []
    if args.shards < 1:
        ap.error(f"--shards must be >= 1 (got {args.shards})")
    if args.shards > 1:
        if args.reps <= 0:
            ap.error("--shards requires --reps")
        if args.reps % args.shards:
            ap.error(f"--shards ({args.shards}) must divide --reps "
                     f"({args.reps}) — the shard plane splits R into "
                     f"equal [R/S × …] dispatches")
        per_shard = args.reps // args.shards
        if per_shard not in rep_counts:
            rep_counts.append(per_shard)
    kw = {"reps": rep_counts}
    if args.paper_batches:
        kw.update(lr_batch=50, lr_hbatch=300)
    specs = build_specs(dims(args.mv_dims, DEFAULT_MV, FULL_MV),
                        dims(args.nv_dims, DEFAULT_NV, FULL_NV),
                        dims(args.lr_dims, DEFAULT_LR, FULL_LR),
                        dims(args.cv_dims, DEFAULT_CV, FULL_CV), **kw)
    if args.entries:
        keep = set(args.entries.split(","))
        specs = [s for s in specs if s.entry in keep]

    if args.list:
        for spec in specs:
            # trace-validate inputs AND outputs: drift between the spec
            # table and the model entry point fails HERE, not at
            # artifact-build time on somebody else's machine
            spec.validate()
            sig = ", ".join(f"{name}:{dt}{list(shape)}"
                            for name, shape, dt in spec.inputs)
            outs = ", ".join(f"{name}:{dt}{list(shape)}"
                             for name, shape, dt in spec.outputs)
            print(f"  {spec.name}: ({sig}) -> ({outs})")
        print(f"{len(specs)} artifacts validated (dry run, nothing written)")
        return

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    for spec in specs:
        path = os.path.join(args.out, f"{spec.name}.hlo.txt")
        text = spec.hlo_text()
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(spec.manifest_entry())
        print(f"  {spec.name}: {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(specs)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
