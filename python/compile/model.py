"""L2 — the paper's compute graphs, one jittable entry point per program the
Rust coordinator executes.  Sampling happens *inside* the graph (threefry
keys are inputs), so a whole resample-epoch is a single device dispatch and
Python never appears on the request path.

Entry points (all f32; key is uint32[2]; counters are int32 scalars):

  mv_epoch       (w, mu, sigma, key, k_epoch) -> (w', f̂)       Alg. 1 epoch
  mv_grad_step   (c, rbar, w, k_epoch, m)     -> (w', f̂)       1 FW step (A1)
  nv_grad        (x, mu, sigma, kc, h, v, key)-> (∇f̂, f̂)       Alg. 2 line 7
  lr_grad        (w, xb, zb)                  -> (∇F̂, loss)    eq. (12)
  lr_hvp         (wbar, s, xh)                -> y              eq. (13)
  lr_hbuild      (s_mem, y_mem, m_count)      -> H              Alg. 4
  lr_happly      (h, g)                       -> H·g
  lr_dir_twoloop (s_mem, y_mem, m_count, g)   -> H·g            (ablation A2)
  cv_epoch       (x, mu, sigma, key, k_epoch) -> (x', f̂)       Task-4 epoch

All are shape-monomorphic: python/compile/aot.py lowers one artifact per
(entry × size) listed in its spec table.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import bfgs as bfgs_k
from .kernels import cvar as cv_k
from .kernels import logreg as logreg_k
from .kernels import mv_grad as mv_k
from .kernels import nv_grad as nv_k

EPS = 1e-10


# ---------------------------------------------------------------------------
# Task 1 — mean-variance Frank-Wolfe (Algorithm 1)
# ---------------------------------------------------------------------------

def simplex_lmo(g):
    """Analytic LMO over W = {w ≥ 0, 1ᵀw ≤ 1}: e_{argmin g} if min g < 0,
    else the origin (Algorithm 1 line 8)."""
    j = jnp.argmin(g)
    d = g.shape[0]
    return jnp.where(g[j] < 0, jax.nn.one_hot(j, d, dtype=g.dtype),
                     jnp.zeros(d, g.dtype))


def _fw_simplex_step(c, rbar, w, k_epoch, m, m_inner):
    """One FW step on the current sample panel: gradient via the L1 kernel,
    analytic LMO, step size γ = 2/(kM+m+2) (Algorithm 1 lines 7-10)."""
    g = mv_k.mv_grad(c, rbar, w)
    s = simplex_lmo(g)
    gamma = 2.0 / (k_epoch.astype(w.dtype) * m_inner
                   + m.astype(w.dtype) + 2.0)
    return w + gamma * (s - w)


def mv_epoch(w, mu, sigma, key, k_epoch, *, n_samples, m_inner):
    """One full epoch of Algorithm 1: resample the return panel once, run
    m_inner Frank-Wolfe steps, report the final empirical objective."""
    d = w.shape[0]
    r = mu[None, :] + sigma[None, :] * jax.random.normal(
        key, (n_samples, d), dtype=w.dtype)
    rbar = jnp.mean(r, axis=0)
    c = r - rbar[None, :]

    def body(m, w):
        return _fw_simplex_step(c, rbar, w, k_epoch, m, m_inner)

    w = lax.fori_loop(0, m_inner, body, w)
    return w, mv_k.mv_obj(c, rbar, w)


def mv_grad_step(c, rbar, w, k_epoch, m, *, m_inner):
    """Per-iteration variant for ablation A1: the host keeps the sample panel
    and dispatches one FW step at a time (paying the host↔device boundary on
    every step, like a naive per-op GPU offload)."""
    w = _fw_simplex_step(c, rbar, w, k_epoch, m, m_inner)
    return w, mv_k.mv_obj(c, rbar, w)


# ---------------------------------------------------------------------------
# Task 4 — mean-CVaR portfolio epoch (registry extension, DESIGN.md §12)
# ---------------------------------------------------------------------------

def cv_product_lmo(g, d):
    """LMO over the product set Δ_capped × [−T_BOX, T_BOX]: the w block
    reuses the Task-1 analytic simplex LMO, the t coordinate picks the
    interval endpoint minimizing g_t·t (mirrors tasks::cvar::product_lmo)."""
    s_w = simplex_lmo(g[:d])
    s_t = jnp.where(g[d] < 0,
                    jnp.asarray(cv_k.T_BOX, g.dtype),
                    jnp.asarray(-cv_k.T_BOX, g.dtype))
    return jnp.concatenate([s_w, jnp.reshape(s_t, (1,))])


def cv_epoch(x, mu, sigma, key, k_epoch, *, n_samples, m_inner):
    """One fused epoch of smoothed mean-CVaR Frank-Wolfe on the joint
    iterate x = [w, t] (length d+1): resample the RAW return panel once
    (no centering — the tail term works on the losses themselves), run
    m_inner FW steps over the product set, report the final empirical
    objective.  Same fused-epoch dispatch discipline as mv_epoch."""
    d = mu.shape[0]
    r = mu[None, :] + sigma[None, :] * jax.random.normal(
        key, (n_samples, d), dtype=x.dtype)
    rbar = jnp.mean(r, axis=0)

    def body(m, x):
        g = cv_k.cv_grad(r, rbar, x)
        s = cv_product_lmo(g, d)
        gamma = 2.0 / (k_epoch.astype(x.dtype) * m_inner
                       + m.astype(x.dtype) + 2.0)
        return x + gamma * (s - x)

    x = lax.fori_loop(0, m_inner, body, x)
    return x, cv_k.cv_obj(r, rbar, x)


# ---------------------------------------------------------------------------
# Task 2 — newsvendor gradient program (Algorithm 2 line 7)
# ---------------------------------------------------------------------------

def nv_grad(x, mu, sigma, kc, h, v, key, *, n_samples):
    """Sample the demand panel in-graph, return the MC gradient (9) and the
    sample-average cost (6).  The LP LMO (line 8) runs on the Rust side."""
    d = x.shape[0]
    demand = mu[None, :] + sigma[None, :] * jax.random.normal(
        key, (n_samples, d), dtype=x.dtype)
    return nv_k.nv_grad_obj(x, demand, kc, h, v)


def nv_panel(mu, sigma, key, *, n_samples):
    """Device-resident epoch path (§Perf): sample the epoch's demand panel
    once.  The Rust runtime keeps the output as a PJRT buffer and feeds it
    to `nv_grad_panel` for all M inner iterations — Algorithm 2 line 5 with
    zero host↔device panel traffic."""
    d = mu.shape[0]
    return mu[None, :] + sigma[None, :] * jax.random.normal(
        key, (n_samples, d), dtype=mu.dtype)


def nv_grad_panel(x, panel, kc, h, v):
    """Gradient (9) + cost (6) against an existing demand panel."""
    return nv_k.nv_grad_obj(x, panel, kc, h, v)


# ---------------------------------------------------------------------------
# Task 3 — SQN programs (Algorithms 3 and 4)
# ---------------------------------------------------------------------------

def lr_grad(w, xb, zb):
    """Minibatch stochastic gradient (12) + mean BCE loss."""
    return logreg_k.lr_grad(w, xb, zb)


def lr_hvp(wbar, s, xh):
    """Correction-pair product y_t = ∇̂²F(ω̄_t)·s_t (Algorithm 3 line 18)."""
    return logreg_k.lr_hvp(wbar, s, xh)


def lr_grad_ds(w, x_full, z_full, idx):
    """Device-resident dataset path (§Perf): the full (N×n) design matrix is
    uploaded once and stays a PJRT buffer; the per-iteration inputs are just
    (w, minibatch indices).  The in-graph gather replaces the host-side
    row copy."""
    xb = jnp.take(x_full, idx, axis=0)
    zb = jnp.take(z_full, idx, axis=0)
    return logreg_k.lr_grad(w, xb, zb)


def lr_hvp_ds(wbar, s, x_full, idx):
    """Device-resident variant of the Hessian batch (Algorithm 3 line 17)."""
    xh = jnp.take(x_full, idx, axis=0)
    return logreg_k.lr_hvp(wbar, s, xh)


def lr_hbuild(s_mem, y_mem, m_count, *, use_pallas=False):
    """Algorithm 4: build the explicit inverse-Hessian approximation H_t from
    the correction memory (rows [0, m_count) valid, oldest first).

    Invalid slots are skipped by zeroing ρ, which turns the rank update into
    the identity.

    `use_pallas` selects the L1 tiled kernel.  The AOT'd artifact uses the
    fused jnp form: under interpret=True the Pallas grid lowers to a long
    chain of dynamic-slice ops that costs ~360 ms per rebuild at n=1024 on
    CPU-PJRT (EXPERIMENTS.md §Perf L2-1); on a real TPU the Mosaic-compiled
    kernel is the right choice and the flag flips back.
    """
    mem, n = s_mem.shape
    idx = jnp.maximum(m_count - 1, 0)
    s_l = jnp.take(s_mem, idx, axis=0)
    y_l = jnp.take(y_mem, idx, axis=0)
    gamma = jnp.where(
        m_count > 0,
        jnp.dot(s_l, y_l) / jnp.maximum(jnp.dot(y_l, y_l), EPS),
        jnp.asarray(1.0, s_mem.dtype))
    h0 = gamma * jnp.eye(n, dtype=s_mem.dtype)

    def body(j, h):
        s = s_mem[j]
        y = y_mem[j]
        denom = jnp.dot(y, s)
        valid = jnp.logical_and(j < m_count, denom > EPS)
        rho = jnp.where(valid, 1.0 / jnp.maximum(denom, EPS),
                        jnp.asarray(0.0, s_mem.dtype))
        hy = h @ y
        q = jnp.dot(y, hy)
        c2 = rho * rho * q + rho
        if use_pallas:
            coef = jnp.stack([rho, c2])
            return bfgs_k.bfgs_rank_update(h, s, hy, coef)
        # fused jnp form: H − ρ·s hyᵀ − ρ·hy sᵀ + (ρ²q+ρ)·s sᵀ
        return (h
                - rho * jnp.outer(s, hy)
                - rho * jnp.outer(hy, s)
                + c2 * jnp.outer(s, s))

    return lax.fori_loop(0, mem, body, h0)


def lr_happly(h, g):
    """Direction d = H_t·g (Algorithm 3 line 11).  Plain MXU matvec; XLA
    fuses it — no Pallas needed."""
    return h @ g


def lr_dir_twoloop(s_mem, y_mem, m_count, g):
    """O(mem·n) two-loop recursion computing the same H_t·g as
    lr_hbuild∘lr_happly — ablation A2 against the paper's explicit-matrix
    Algorithm 4."""
    mem, n = s_mem.shape
    dots = jnp.sum(y_mem * s_mem, axis=1)                      # (mem,)
    valid = jnp.logical_and(jnp.arange(mem) < m_count, dots > EPS)
    rho = jnp.where(valid, 1.0 / jnp.maximum(dots, EPS), 0.0).astype(g.dtype)

    def bwd(i, carry):
        q, alpha = carry
        j = mem - 1 - i
        a = rho[j] * jnp.dot(s_mem[j], q)
        return q - a * y_mem[j], alpha.at[j].set(a)

    q, alpha = lax.fori_loop(0, mem, bwd, (g, jnp.zeros(mem, g.dtype)))

    idx = jnp.maximum(m_count - 1, 0)
    s_l = jnp.take(s_mem, idx, axis=0)
    y_l = jnp.take(y_mem, idx, axis=0)
    gamma = jnp.where(
        m_count > 0,
        jnp.dot(s_l, y_l) / jnp.maximum(jnp.dot(y_l, y_l), EPS),
        jnp.asarray(1.0, g.dtype))
    r = gamma * q

    def fwd(j, r):
        b = rho[j] * jnp.dot(y_mem[j], r)
        return r + s_mem[j] * (alpha[j] - b)

    return lax.fori_loop(0, mem, fwd, r)


# ---------------------------------------------------------------------------
# Replication-batched entry points (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# One dispatch advances ALL R replications of an experiment — the fusion
# Zhou, Lange & Suchard apply to independent chains.  Each entry is a
# jax.vmap of the per-replication graph over the replication axis, so row r
# computes the unbatched math on its own threefry key; shared problem data
# (mu/sigma/costs/dataset) is broadcast, not replicated.


def mv_epoch_batch(w, mu, sigma, keys, k_epoch, *, n_samples, m_inner):
    """Batched Algorithm-1 epoch: w is (R, d), keys is (R, 2) uint32.
    Returns (w', f̂) stacked over the replication axis."""
    return jax.vmap(
        lambda wr, kr: mv_epoch(wr, mu, sigma, kr, k_epoch,
                                n_samples=n_samples, m_inner=m_inner)
    )(w, keys)


def cv_epoch_batch(x, mu, sigma, keys, k_epoch, *, n_samples, m_inner):
    """Batched Task-4 epoch: x is (R, d+1) joint iterates, keys is (R, 2)
    uint32 — one dispatch advances every replication, same vmap lowering
    discipline as mv_epoch_batch."""
    return jax.vmap(
        lambda xr, kr: cv_epoch(xr, mu, sigma, kr, k_epoch,
                                n_samples=n_samples, m_inner=m_inner)
    )(x, keys)


def nv_grad_batch(x, mu, sigma, kc, h, v, keys, *, n_samples):
    """Batched Algorithm-2 gradient with in-graph resampling — the naive
    variant (resamples every call; costs shipped per dispatch).  The
    runtime uses the device-resident pair below instead; this one is kept
    as the batched analogue of the `nv_grad` per-call ablation."""
    return jax.vmap(
        lambda xr, kr: nv_grad(xr, mu, sigma, kc, h, v, kr,
                               n_samples=n_samples)
    )(x, keys)


def nv_panel_batch(mu, sigma, keys, *, n_samples):
    """Batched device-resident epoch path (§Perf): sample every
    replication's demand panel once per epoch — output (R, S, d) stays a
    PJRT buffer for all M inner iterations."""
    return jax.vmap(
        lambda kr: nv_panel(mu, sigma, kr, n_samples=n_samples)
    )(keys)


def nv_grad_panel_batch(x, panel, kc, h, v):
    """Batched gradient (9) + cost (6) against resident panels: x is
    (R, d), panel is (R, S, d); cost vectors are shared (uploaded once)."""
    return jax.vmap(
        lambda xr, pr: nv_grad_panel(xr, pr, kc, h, v)
    )(x, panel)


def lr_grad_batch(w, x_full, z_full, idx):
    """Batched device-resident minibatch gradient: w is (R, n), idx is
    (R, b) — every replication gathers its own minibatch in-graph against
    the ONE resident dataset."""
    return jax.vmap(
        lambda wr, ir: lr_grad_ds(wr, x_full, z_full, ir)
    )(w, idx)


def lr_hvp_batch(wbar, s, x_full, idx):
    """Batched device-resident Hessian-vector product: wbar/s are (R, n),
    idx is (R, b_H)."""
    return jax.vmap(
        lambda wr, sr, ir: lr_hvp_ds(wr, sr, x_full, ir)
    )(wbar, s, idx)


def lr_dir_batch(s_mem, y_mem, m_count, g):
    """Batched Algorithm-4 direction (DESIGN.md §11): build every
    replication's explicit H_t from its padded correction panel and apply
    it to its gradient row in ONE program — s_mem/y_mem are (R, mem, n)
    dense zero-padded panels, m_count is (R,) int32 valid counts, g is
    (R, n).  Invalid slots are masked in-graph by zeroing ρ (see
    lr_hbuild), so rows with empty or partially filled memories are
    handled without host-side raggedness; an m_count of 0 reduces row r
    to the identity, d = g — the driver's plain-gradient fallback.

    Lowered with lax.map, NOT jax.vmap: vmapping this graph reassociates
    the rank-update contractions and drifts ~1 ulp from the
    per-replication artifact (measured row-by-row, counts ≥ 2 — the same
    drift that retired nv_grad_batch, §11).  lax.map keeps the unbatched
    per-row graph intact inside one dispatch; the replication axis
    becomes a short in-graph loop while the heavy (mem, n, n) panel math
    of each row still vectorizes, so the dispatch-amortization win is
    preserved and rows stay bitwise equal to the ragged path."""
    return lax.map(
        lambda args: lr_happly(lr_hbuild(args[0], args[1], args[2]),
                               args[3]),
        (s_mem, y_mem, m_count, g))


def lr_dir_twoloop_batch(s_mem, y_mem, m_count, g):
    """Batched two-loop recursion over the same padded panels (ablation
    A2's batched analogue): same signature, masking, and bitwise-safe
    lax.map lowering as lr_dir_batch, O(R·mem·n) instead of
    O(R·mem·n²)."""
    return lax.map(lambda args: lr_dir_twoloop(*args),
                   (s_mem, y_mem, m_count, g))
