//! Figure 2 (bottom panel): Task 3 SQN computation time vs feature count.
//! Paper protocol: K=2000 iterations, n in {50,500,1000,5000}, b=50,
//! b_H=300.  Scaled defaults; see DESIGN.md §2.

mod common;

fn main() {
    common::run_figure2(simopt::config::TaskKind::Classification, 200);
}
