//! Zero-copy native panel kernels vs the legacy owned-rows pattern
//! (DESIGN.md §16): one epoch of every native batch arm, per-phase.
//!
//! Each task pairs two cells at the same R×n shape:
//! * `*_zero_copy` — the shipped spine: `Native*Batch` hands every worker
//!   disjoint `&mut` windows of the output panel, per-row scratch lives in
//!   backend arenas, and nothing is copied after the kernels return.  The
//!   whole wall books as `compute`; there is no reduce phase to book.
//! * `*_legacy_merge` — the pre-§16 shape, reconstructed: every row
//!   builds an owned `Vec` result through the allocating per-replication
//!   entry points, then a merge pass copies the rows back into the panel.
//!   The merge copy books as `reduce`, so the reduce-share drop of the
//!   zero-copy arm is directly visible in `BENCH_panel_kernels.json`.
//!
//! Both arms run the bit-identical per-row arithmetic (asserted on the
//! final panels), and both run single-threaded so the comparison isolates
//! allocation + copy-back cost, not scheduling.
//!
//! Knobs: SIMOPT_BENCH_EPOCHS (epochs per cell, default 8).

mod common;

use simopt::backend::native::{
    NativeCvar, NativeCvarBatch, NativeLr, NativeLrBatch, NativeMode,
    NativeMv, NativeMvBatch, NativeNv, NativeNvBatch,
};
use simopt::backend::{
    HessianMode, LrBackend, LrBatchBackend, MvBackend, MvBatchBackend,
    NvBackend, NvBatchBackend,
};
use simopt::backend::plane::tile_rows;
use simopt::bench::Bench;
use simopt::coordinator::rep_subtrees;
use simopt::rng::StreamTree;
use simopt::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use simopt::tasks::cvar;
use simopt::util::profile::{Phase, Profiler};
use simopt::util::timer::Timer;

/// Reduce share of a drained profile, for the end-of-run summary.
fn reduce_share(prof: &Profiler) -> f64 {
    let total = prof.sum();
    if total > 0.0 {
        prof.get(Phase::Reduce) / total
    } else {
        0.0
    }
}

fn main() {
    let smoke = common::smoke();
    let epochs =
        if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 8) };
    // (d, R) cells: small and medium replication panels
    let shapes: Vec<(usize, usize)> =
        if smoke { vec![(16, 4)] } else { vec![(16, 4), (96, 8)] };
    let (n_samples, m_inner) = (64usize, 10usize);

    println!("panel_kernels: {} epochs per cell, single-threaded, \
              shapes {:?}\n", epochs, shapes);
    // every cell records its own per-epoch samples via record_profiled,
    // so the harness-level warmup/reps protocol is unused here
    let mut bench = Bench::new("panel_kernels");
    // (label, legacy reduce share, zero-copy reduce share)
    let mut summary: Vec<(String, f64, f64)> = Vec::new();

    for &(d, r) in &shapes {
        // ---- Task 1: mean-variance epochs --------------------------------
        let tree = StreamTree::new(71);
        let trees = rep_subtrees(&tree, r);
        let u = AssetUniverse::generate(&tree, d);
        let w0 = vec![1.0f32 / d as f32; d];

        let mut panel = tile_rows(&w0, r);
        let mut objs = vec![0.0f64; r];
        let mut batch = NativeMvBatch::new(&u, n_samples, m_inner, r, 1);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            batch.epoch_batch(&mut panel, k, &keys, &mut objs).unwrap();
            samples.push(t.elapsed_s());
            if let Some(p) = batch.take_profile() {
                prof.merge(&p);
            }
        }
        let zc_share = reduce_share(&prof);
        bench.record_profiled(&format!("mv_zero_copy_d{}_R{}", d, r),
                              &samples, prof);

        let mut rows: Vec<NativeMv> = (0..r)
            .map(|_| NativeMv::new(u.clone(), n_samples, m_inner,
                                   NativeMode::Sequential))
            .collect();
        let mut lpanel = tile_rows(&w0, r);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            let t_c = Timer::start();
            let out: Vec<(Vec<f32>, f64)> = rows
                .iter_mut()
                .enumerate()
                .map(|(i, rep)| {
                    rep.epoch(&lpanel[i * d..(i + 1) * d], k, keys[i])
                        .unwrap()
                })
                .collect();
            prof.add(Phase::Compute, t_c.elapsed_s());
            let t_m = Timer::start();
            for (i, (row, _)) in out.iter().enumerate() {
                lpanel[i * d..(i + 1) * d].copy_from_slice(row);
            }
            prof.add(Phase::Reduce, t_m.elapsed_s());
            samples.push(t.elapsed_s());
        }
        let legacy_share = reduce_share(&prof);
        bench.record_profiled(&format!("mv_legacy_merge_d{}_R{}", d, r),
                              &samples, prof);
        assert_eq!(panel, lpanel, "mv d={} R={}: zero-copy != legacy", d, r);
        summary.push((format!("mv_d{}_R{}", d, r), legacy_share, zc_share));

        // ---- Task 4: mean-CVaR epochs (joint [w, t] rows) ----------------
        let row_len = d + 1;
        let x0 = cvar::start_iterate(d);
        let mut panel = tile_rows(&x0, r);
        let mut batch = NativeCvarBatch::new(&u, n_samples, m_inner, r, 1);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            batch.epoch_batch(&mut panel, k, &keys, &mut objs).unwrap();
            samples.push(t.elapsed_s());
            if let Some(p) = batch.take_profile() {
                prof.merge(&p);
            }
        }
        let zc_share = reduce_share(&prof);
        bench.record_profiled(&format!("cvar_zero_copy_d{}_R{}", d, r),
                              &samples, prof);

        let mut rows: Vec<NativeCvar> = (0..r)
            .map(|_| NativeCvar::new(u.clone(), n_samples, m_inner,
                                     NativeMode::Sequential))
            .collect();
        let mut lpanel = tile_rows(&x0, r);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            let t_c = Timer::start();
            let out: Vec<(Vec<f32>, f64)> = rows
                .iter_mut()
                .enumerate()
                .map(|(i, rep)| {
                    rep.epoch(&lpanel[i * row_len..(i + 1) * row_len], k,
                              keys[i])
                        .unwrap()
                })
                .collect();
            prof.add(Phase::Compute, t_c.elapsed_s());
            let t_m = Timer::start();
            for (i, (row, _)) in out.iter().enumerate() {
                lpanel[i * row_len..(i + 1) * row_len]
                    .copy_from_slice(row);
            }
            prof.add(Phase::Reduce, t_m.elapsed_s());
            samples.push(t.elapsed_s());
        }
        let legacy_share = reduce_share(&prof);
        bench.record_profiled(&format!("cvar_legacy_merge_d{}_R{}", d, r),
                              &samples, prof);
        assert_eq!(panel, lpanel, "cvar d={} R={}: zero-copy != legacy",
                   d, r);
        summary.push((format!("cvar_d{}_R{}", d, r), legacy_share,
                      zc_share));

        // ---- Task 2: newsvendor gradient panels --------------------------
        let inst = NewsvendorInstance::generate(&tree, d, 2, 0.6);
        let nd = inst.dim();
        let x0 = inst.feasible_start();
        let x_panel = tile_rows(&x0, r);
        let mut g = vec![0.0f32; r * nd];
        let mut batch = NativeNvBatch::new(&inst, n_samples, r, 1);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            batch.grad_obj_batch(&x_panel, &keys, &mut g, &mut objs)
                .unwrap();
            samples.push(t.elapsed_s());
            if let Some(p) = batch.take_profile() {
                prof.merge(&p);
            }
        }
        let zc_share = reduce_share(&prof);
        bench.record_profiled(&format!("nv_zero_copy_d{}_R{}", nd, r),
                              &samples, prof);

        let mut rows: Vec<NativeNv> = (0..r)
            .map(|_| NativeNv::new(inst.clone(), n_samples,
                                   NativeMode::Sequential))
            .collect();
        let mut lg = vec![0.0f32; r * nd];
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let keys: Vec<[u32; 2]> =
                trees.iter().map(|t| t.jax_key(&[k as u64])).collect();
            let t = Timer::start();
            let t_c = Timer::start();
            let out: Vec<(Vec<f32>, f64)> = rows
                .iter_mut()
                .enumerate()
                .map(|(i, rep)| {
                    rep.grad_obj(&x_panel[i * nd..(i + 1) * nd], keys[i])
                        .unwrap()
                })
                .collect();
            prof.add(Phase::Compute, t_c.elapsed_s());
            let t_m = Timer::start();
            for (i, (row, _)) in out.iter().enumerate() {
                lg[i * nd..(i + 1) * nd].copy_from_slice(row);
            }
            prof.add(Phase::Reduce, t_m.elapsed_s());
            samples.push(t.elapsed_s());
        }
        let legacy_share = reduce_share(&prof);
        bench.record_profiled(&format!("nv_legacy_merge_d{}_R{}", nd, r),
                              &samples, prof);
        assert_eq!(g, lg, "nv d={} R={}: zero-copy != legacy", nd, r);
        summary.push((format!("nv_d{}_R{}", nd, r), legacy_share,
                      zc_share));

        // ---- Task 3: SQN minibatch-gradient panels -----------------------
        let data = ClassifyData::generate(&tree, d);
        let w_panel = vec![0.0f32; r * d];
        let mut g = vec![0.0f32; r * d];
        let mut losses = vec![0.0f64; r];
        let mut batch =
            NativeLrBatch::new(&data, r, 1, HessianMode::Explicit);
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            // minibatch draws stay outside the timed region, as in the
            // SQN driver
            let idx: Vec<Vec<usize>> = trees
                .iter()
                .map(|tr| {
                    let mut rng = tr.stream(&[1, (k + 1) as u64]);
                    rng.sample_indices(data.n_samples,
                                       32.min(data.n_samples))
                })
                .collect();
            let t = Timer::start();
            batch.grad_batch(&w_panel, &data, &idx, &mut g, &mut losses)
                .unwrap();
            samples.push(t.elapsed_s());
            if let Some(p) = batch.take_profile() {
                prof.merge(&p);
            }
        }
        let zc_share = reduce_share(&prof);
        bench.record_profiled(&format!("lr_zero_copy_n{}_R{}", d, r),
                              &samples, prof);

        let mut rows: Vec<NativeLr> = (0..r)
            .map(|_| NativeLr::new(&data, NativeMode::Sequential,
                                   HessianMode::Explicit))
            .collect();
        let mut lg = vec![0.0f32; r * d];
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let idx: Vec<Vec<usize>> = trees
                .iter()
                .map(|tr| {
                    let mut rng = tr.stream(&[1, (k + 1) as u64]);
                    rng.sample_indices(data.n_samples,
                                       32.min(data.n_samples))
                })
                .collect();
            let t = Timer::start();
            let t_c = Timer::start();
            let out: Vec<(Vec<f32>, f64)> = rows
                .iter_mut()
                .enumerate()
                .map(|(i, rep)| {
                    rep.grad(&w_panel[i * d..(i + 1) * d], &data, &idx[i])
                        .unwrap()
                })
                .collect();
            prof.add(Phase::Compute, t_c.elapsed_s());
            let t_m = Timer::start();
            for (i, (row, _)) in out.iter().enumerate() {
                lg[i * d..(i + 1) * d].copy_from_slice(row);
            }
            prof.add(Phase::Reduce, t_m.elapsed_s());
            samples.push(t.elapsed_s());
        }
        let legacy_share = reduce_share(&prof);
        bench.record_profiled(&format!("lr_legacy_merge_n{}_R{}", d, r),
                              &samples, prof);
        assert_eq!(g, lg, "lr n={} R={}: zero-copy != legacy", d, r);
        summary.push((format!("lr_n{}_R{}", d, r), legacy_share,
                      zc_share));
    }

    bench.finish();
    println!("\nreduce-phase share (merge copy-back cost):");
    println!("| arm | legacy | zero-copy |");
    println!("|---|---|---|");
    for (label, legacy, zc) in &summary {
        println!("| {} | {:.2}% | {:.2}% |", label, legacy * 100.0,
                 zc * 100.0);
    }
    println!("\n(The zero-copy arm writes every row in place through the \
              backends' `_into` entry points — its reduce share is \
              structurally zero; the legacy arm pays an owned-row \
              allocation per replication per epoch plus the merge copy, \
              DESIGN.md §16.)");
}
