//! Figure 2 (top-right panel): Task 2 newsvendor computation time vs size.
//! The LP LMO runs on the host in both arms; the Monte-Carlo gradient is the
//! backend-differentiated piece.

mod common;

fn main() {
    common::run_figure2(simopt::config::TaskKind::Newsvendor, 8);
}
