//! Figure 2 (top-left panel): Task 1 mean-variance computation time vs
//! problem size, native (sequential CPU) vs xla (vectorized), mean ± 2σ.
//!
//! Paper protocol: K=1500 epochs, sizes 5e2..1e5, 7 reps.  Defaults here are
//! scaled for the 1-core box (see DESIGN.md §2); raise with
//! SIMOPT_BENCH_EPOCHS / SIMOPT_BENCH_SIZES / SIMOPT_BENCH_REPS.

mod common;

fn main() {
    common::run_figure2(simopt::config::TaskKind::MeanVariance, 10);
}
