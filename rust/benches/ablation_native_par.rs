//! Ablation A3: sequential native vs thread-pooled native.
//!
//! Separates "more CPU threads" from "vectorized execution" in the speedup
//! attribution: on the paper's thesis, CPU parallelism alone should not
//! close the gap to the fused XLA arm (and on a 1-core box it cannot).

mod common;

use simopt::bench::Bench;
use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec};

fn main() {
    let smoke = common::smoke();
    let epochs = if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 8) };
    let reps = if smoke { 1 } else { common::env_usize("SIMOPT_BENCH_REPS", 3) };
    let sizes = if smoke {
        vec![64]
    } else {
        common::env_sizes(vec![512, 2048])
    };
    let mut coord = Coordinator::new("artifacts", "results").unwrap();
    let mut bench = Bench::new("ablation_native_par");

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("available parallelism: {} threads", threads);

    for &d in &sizes {
        for backend in [BackendKind::Native, BackendKind::NativePar] {
            let spec = ExperimentSpec::new(TaskKind::MeanVariance, backend)
                .size(d)
                .epochs(epochs)
                .replications(reps)
                .seed(42);
            let res = coord.run(&spec).expect("run");
            let samples: Vec<f64> = res.reps.iter().map(|r| r.total_s).collect();
            bench.record(&format!("{}_d{}", backend, d), &samples);
        }
        if common::artifacts_built()
            && !sizes.iter().any(|_| false)
        {
            // include the xla arm as the reference point when available
            let spec = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla)
                .size(d)
                .epochs(epochs)
                .replications(reps)
                .seed(42);
            if let Ok(res) = coord.run(&spec) {
                let samples: Vec<f64> =
                    res.reps.iter().map(|r| r.total_s).collect();
                bench.record(&format!("xla_d{}", d), &samples);
            }
        }
    }
    bench.finish();
    for &d in &sizes {
        let seq = bench.find(&format!("native_d{}", d));
        let par = bench.find(&format!("native_par_d{}", d));
        if let (Some(s), Some(p)) = (seq, par) {
            println!("d={}: thread-pool speedup {:.2}× over sequential",
                     d, s.mean_s / p.mean_s.max(1e-12));
        }
    }
}
