//! Shard-aware panel plane throughput (DESIGN.md §13): the batched
//! replication engine's `[R × n]` spine split into S contiguous shards.
//!
//! For each shard count S ∈ {1, 2, R} (S = 2 is an uneven split whenever
//! R is odd), R replications of the mean-variance task and of the
//! classification (SQN) task advance through `ShardedBatch` — the same
//! drivers, the same per-row arithmetic, only dispatch granularity moves.
//! Every cell's final panel is asserted bit-identical to the unsharded
//! S = 1 run, so the numbers are pure scheduling: shard-level pool
//! workers vs one monolithic panel.
//!
//! Knobs: SIMOPT_BENCH_SIZES, SIMOPT_BENCH_REPS (= R),
//! SIMOPT_BENCH_EPOCHS, SIMOPT_BENCH_LR_SIZE, SIMOPT_BENCH_SQN_ITERS.

mod common;

use simopt::backend::native::{NativeLrBatch, NativeMvBatch};
use simopt::backend::plane::{self, ShardedBatch};
use simopt::bench::Bench;
use simopt::coordinator::rep_subtrees;
use simopt::opt::{run_mv_batch, run_sqn_batch, SqnConfig};
use simopt::rng::StreamTree;
use simopt::sim::{AssetUniverse, ClassifyData};

fn main() {
    let smoke = common::smoke();
    let sizes = if smoke {
        vec![48]
    } else {
        common::env_sizes(vec![256, 1024])
    };
    let r_reps =
        if smoke { 5 } else { common::env_usize("SIMOPT_BENCH_REPS", 8) };
    let epochs =
        if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 6) };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let shard_counts: Vec<usize> = {
        let mut s = vec![1usize];
        if r_reps >= 2 {
            s.push(2);
        }
        if r_reps > 2 {
            s.push(r_reps);
        }
        s
    };
    let (n_samples, m_inner) = (64usize, 10usize);

    println!(
        "shard_sweep: R={} replications, S ∈ {:?}, {} epochs, {} threads\n",
        r_reps, shard_counts, epochs, threads
    );
    let mut bench = Bench::new("shard_sweep")
        .warmup(if smoke { 0 } else { 1 })
        .reps(if smoke { 1 } else { 3 });

    // ---- mean-variance through the sharded plane ------------------------
    for &d in &sizes {
        let tree = StreamTree::new(42);
        let trees: Vec<StreamTree> = rep_subtrees(&tree, r_reps);
        let universe = AssetUniverse::generate(&tree, d);
        let w0 = vec![1.0f32 / d as f32; d];

        let mut baseline: Option<Vec<f32>> = None;
        for &shards in &shard_counts {
            let mut panel: Vec<f32> = Vec::new();
            bench.case(&format!("mv_d{}_R{}_S{}", d, r_reps, shards), || {
                let mut backend = ShardedBatch::pooled(
                    r_reps, shards, d, threads, |rows| {
                        Ok(NativeMvBatch::new(
                            &universe, n_samples, m_inner, rows.len(),
                            plane::inner_threads(threads, shards)))
                    })
                    .unwrap();
                let (w, _) =
                    run_mv_batch(&mut backend, &w0, epochs, &trees).unwrap();
                panel = w;
            });
            if let Some(b) = &baseline {
                assert_eq!(&panel, b,
                           "mv d={} S={}: sharded != unsharded", d, shards);
            } else {
                baseline = Some(panel);
            }
        }
        println!("mv d={}: all shard counts bit-identical", d);
    }

    // ---- classification SQN through the sharded plane -------------------
    let n = if smoke { 24 } else { common::env_usize("SIMOPT_BENCH_LR_SIZE", 64) };
    let sqn_cfg = SqnConfig {
        iters: if smoke {
            12
        } else {
            common::env_usize("SIMOPT_BENCH_SQN_ITERS", 60)
        },
        batch: 32,
        hbatch: 64,
        l_every: 5,
        memory: 8,
        beta: 2.0,
        track_every: 0, // timing cells: no tracked-loss evaluations
        track_rows: 0,
    };
    let tree = StreamTree::new(43);
    let trees: Vec<StreamTree> = rep_subtrees(&tree, r_reps);
    let data = ClassifyData::generate(&tree, n);
    let mut baseline: Option<Vec<f32>> = None;
    for &shards in &shard_counts {
        let mut panel: Vec<f32> = Vec::new();
        bench.case(&format!("sqn_n{}_R{}_S{}", n, r_reps, shards), || {
            let mut backend = ShardedBatch::pooled(
                r_reps, shards, n, threads, |rows| {
                    Ok(NativeLrBatch::new(
                        &data, rows.len(),
                        plane::inner_threads(threads, shards),
                        simopt::backend::HessianMode::Explicit))
                })
                .unwrap();
            let (w, _) =
                run_sqn_batch(&mut backend, &data, &sqn_cfg, &trees).unwrap();
            panel = w;
        });
        if let Some(b) = &baseline {
            assert_eq!(&panel, b,
                       "sqn n={} S={}: sharded != unsharded", n, shards);
        } else {
            baseline = Some(panel);
        }
    }
    println!("sqn n={}: all shard counts bit-identical\n", n);

    bench.finish();
    println!(
        "\n(Sharding moves dispatch granularity only: S shard workers × \
         {} inner rows each replace one monolithic panel.  On the XLA arm \
         the same seam becomes one [R/S × …] artifact dispatch per shard — \
         the multi-device mapping point, DESIGN.md §13.)",
        r_reps.div_ceil(shard_counts.last().copied().unwrap_or(1))
    );
}
