//! Figure-2-shaped panel for the registry's fourth scenario: mean-CVaR
//! portfolio computation time vs problem size, native (sequential CPU) vs
//! xla (vectorized), mean ± 2σ.
//!
//! The task registered through the task-registry plane (DESIGN.md §12), so
//! this bench is the same three lines as every other fig2 panel — the
//! sweep, reporting, and telemetry come from the shared scaffolding.
//! Knobs: SIMOPT_BENCH_EPOCHS / SIMOPT_BENCH_SIZES / SIMOPT_BENCH_REPS.

mod common;

fn main() {
    common::run_figure2(simopt::config::TaskKind::MeanCvar, 10);
}
