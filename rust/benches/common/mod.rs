//! Shared bench scaffolding: environment knobs + the standard Figure-2
//! sweep runner used by the per-task bench binaries.
//!
//! Knobs (environment variables, so `cargo bench` stays argument-free):
//!   SIMOPT_BENCH_REPS    replications per cell           (default 5)
//!   SIMOPT_BENCH_EPOCHS  FW epochs / SQN iters per rep   (task default)
//!   SIMOPT_BENCH_SIZES   comma list overriding the size axis
//!   SIMOPT_BENCH_FULL    =1 → include the largest AOT'd sizes
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use simopt::bench::Bench;
use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{report, Coordinator, RunResult, SweepSpec};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// True in the CI bench-smoke job (`cargo bench --bench X -- --test`, the
/// flag criterion benches also accept, or SIMOPT_BENCH_SMOKE=1): benches
/// shrink to tiny workloads that only verify the target still runs —
/// bit-rot detection without timing claims.  Delegates to
/// `bench::smoke_mode` so the workload shrink and the `smoke` marker in
/// `BENCH_*.json` can never disagree.
pub fn smoke() -> bool {
    simopt::bench::smoke_mode()
}

pub fn env_sizes(default: Vec<usize>) -> Vec<usize> {
    match std::env::var("SIMOPT_BENCH_SIZES") {
        Ok(v) => v.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => default,
    }
}

pub fn artifacts_built() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Run the Figure-2 protocol for one task and print/persist the table.
pub fn run_figure2(task: TaskKind, default_epochs: usize) {
    if !artifacts_built() {
        eprintln!("[bench] artifacts/ missing — run `make artifacts` first");
        return;
    }
    let mut sweep = SweepSpec::figure2(task);
    sweep.sizes = env_sizes(sweep.sizes);
    sweep.reps = env_usize("SIMOPT_BENCH_REPS", 5);
    sweep.epochs = env_usize("SIMOPT_BENCH_EPOCHS", default_epochs);
    sweep.backends = vec![BackendKind::Native, BackendKind::Xla];
    if smoke() {
        sweep.sizes.truncate(1);
        sweep.reps = 1;
        sweep.epochs = sweep.epochs.min(2);
    }

    let mut coord = Coordinator::new("artifacts", "results").unwrap();
    let results = coord.sweep(&sweep).expect("sweep");
    emit(task, &format!("fig2_{}", task), &results);
}

/// Print per-cell rows through the bench harness + the paper-shaped table.
pub fn emit(task: TaskKind, name: &str, results: &[RunResult]) {
    let mut bench = Bench::new(name);
    for r in results {
        let samples: Vec<f64> = r.reps.iter().map(|rep| rep.total_s).collect();
        bench.record_profiled(
            &format!("{}_{}_d{}", task, r.spec.backend, r.spec.size),
            &samples,
            r.profile,
        );
    }
    bench.finish();
    println!("{}", report::figure2_markdown(results));
    report::write_report("results", name, results, &[0.1, 0.25, 0.5, 1.0])
        .expect("write report");
    println!("[bench] full report under results/{}_*", name);
}
