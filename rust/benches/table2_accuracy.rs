//! Table 2: RSE (± 2σ over replications) at matched iteration checkpoints,
//! native vs xla, for all three tasks — the paper's "same algorithm, same
//! accuracy regardless of hardware" claim.
//!
//! Paper protocol: checkpoints at iterations 50/100/500/1000 of 10 000,
//! 7 replications.  We run shorter traces (defaults below) and report RSE at
//! the same *fractional* positions, printing the paper's rows alongside.

mod common;

use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{report, Coordinator, ExperimentSpec};

fn main() {
    if !common::artifacts_built() {
        eprintln!("[bench] artifacts/ missing — run `make artifacts` first");
        return;
    }
    let smoke = common::smoke();
    let reps = if smoke { 1 } else { common::env_usize("SIMOPT_BENCH_REPS", 7) };
    let fracs = [0.005, 0.01, 0.05, 0.1, 1.0];
    let mut coord = Coordinator::new("artifacts", "results").unwrap();

    for (task, size, epochs) in [
        // paper: asset 5k, inventory 10k, classification 1k — middle sizes
        // of the AOT'd axis here (largest still CI-friendly)
        (TaskKind::MeanVariance, 512, common::env_usize("SIMOPT_BENCH_EPOCHS", 40)),
        (TaskKind::Newsvendor, 2048, common::env_usize("SIMOPT_BENCH_EPOCHS", 40)),
        (TaskKind::Classification, 256, common::env_usize("SIMOPT_BENCH_EPOCHS", 400)),
    ] {
        let epochs = if smoke { epochs.min(5) } else { epochs };
        let mut results = Vec::new();
        for backend in [BackendKind::Xla, BackendKind::Native] {
            let spec = ExperimentSpec::new(task, backend)
                .size(size)
                .epochs(epochs)
                .replications(reps)
                .seed(42);
            eprintln!("[table2] {} {} d={} reps={}", task, backend, size, reps);
            match coord.run(&spec) {
                Ok(res) => results.push(res),
                Err(e) => eprintln!(
                    "[table2] skipping {} {}: {:#}", task, backend, e),
            }
        }
        if results.len() < 2 {
            eprintln!("[table2] {}: not enough arms ran — skipping table",
                      task);
            continue;
        }
        println!("{}", report::table2_markdown(&results, &fracs));
        report::write_report("results", &format!("table2_{}", task), &results,
                             &fracs)
            .expect("write report");

        // the claim under test: overlapping ±2σ RSE bands at every shared
        // checkpoint
        let a = results[0].rse_checkpoints(&fracs);
        let b = results[1].rse_checkpoints(&fracs);
        for (ca, cb) in a.iter().zip(&b) {
            let (m1, s1, m2, s2) = (ca.2, ca.3, cb.2, cb.3);
            let overlap = (m1 - 2.0 * s1) <= (m2 + 2.0 * s2)
                && (m2 - 2.0 * s2) <= (m1 + 2.0 * s1);
            println!(
                "  checkpoint {:.1}%: xla {:.2}%±{:.2}% vs native {:.2}%±{:.2}% → {}",
                ca.0 * 100.0, m1, 2.0 * s1, m2, 2.0 * s2,
                if overlap { "OVERLAP (paper-consistent)" } else { "DISJOINT" }
            );
        }
    }
}
