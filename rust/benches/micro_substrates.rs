//! Microbenchmarks over the substrates: the profile that drives the L3 perf
//! pass (EXPERIMENTS.md §Perf).  Covers the native hot-path kernels, the LP
//! LMO, RNG throughput, and the raw PJRT dispatch floor.

mod common;

use simopt::bench::Bench;
use simopt::linalg::{blocked, Mat};
use simopt::lp::{self, LpProblem};
use simopt::rng::{NormalSampler, Philox, StreamTree};
use simopt::sim::NewsvendorInstance;
use simopt::tasks::newsvendor::NvLmo;

fn main() {
    let smoke = common::smoke();
    let reps =
        if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_REPS", 20) };
    let draws = if smoke { 100_000 } else { 1_000_000 };
    let mut bench = Bench::new("micro_substrates").warmup(2).reps(reps);

    // RNG throughput: 1M uniforms / 1M normals (scaled down under --test)
    let mut rng = Philox::new(1);
    bench.case("philox_1M_u32", || {
        let mut acc = 0u32;
        for _ in 0..draws {
            acc = acc.wrapping_add(rng.next_u32());
        }
        std::hint::black_box(acc);
    });
    let mut norm = NormalSampler::from_seed(2);
    bench.case("boxmuller_1M_normals", || {
        let mut acc = 0.0f32;
        for _ in 0..draws {
            acc += norm.next();
        }
        std::hint::black_box(acc);
    });

    // matvec kernels at the Fig-2 panel shape (N=64, d=2048)
    let mut p = Philox::new(3);
    let c = Mat::from_vec(64, 2048,
                          (0..64 * 2048).map(|_| p.uniform_f32(-1.0, 1.0)).collect());
    let w: Vec<f32> = (0..2048).map(|_| p.uniform_f32(0.0, 1.0)).collect();
    let mut u = vec![0.0f32; 64];
    let mut g = vec![0.0f32; 2048];
    bench.case("matvec_seq_64x2048", || {
        c.matvec(&w, &mut u);
        c.matvec_t(&u, &mut g);
        std::hint::black_box(&g);
    });
    bench.case("matvec_blocked_64x2048", || {
        blocked::matvec_blocked(&c, &w, &mut u);
        blocked::matvec_t_blocked(&c, &u, &mut g);
        std::hint::black_box(&g);
    });

    // LP LMO at the newsvendor bench shape (d=2048, m=8)
    let inst = NewsvendorInstance::generate(&StreamTree::new(4), 2048, 8, 0.6);
    let mut lmo = NvLmo::new(&inst);
    let grad: Vec<f32> = (0..2048).map(|j| if j % 3 == 0 { -1.0 } else { 0.5 }).collect();
    bench.case("lp_lmo_d2048_m8", || {
        std::hint::black_box(lmo.solve(&grad).unwrap());
    });

    // generic dense LP (50 vars × 20 constraints)
    let mut p2 = Philox::new(5);
    let lp_prob = LpProblem::new(
        (0..50).map(|_| p2.uniform_f32(-2.0, 2.0) as f64).collect(),
        (0..20 * 50).map(|_| p2.uniform_f32(0.1, 1.0) as f64).collect(),
        (0..20).map(|_| p2.uniform_f32(1.0, 5.0) as f64).collect(),
    );
    bench.case("lp_dense_50x20", || {
        std::hint::black_box(lp::solve(&lp_prob));
    });

    // PJRT dispatch floor: smallest artifact end-to-end
    if common::artifacts_built() {
        if let Ok(engine) = simopt::runtime::Engine::new("artifacts") {
            if let Ok(exec) = engine.load_by_params("lr_happly", &[("n", 64)]) {
                let h = vec![0.0f32; 64 * 64];
                let gv = vec![1.0f32; 64];
                bench.case("pjrt_dispatch_floor_happly64", || {
                    std::hint::black_box(
                        exec.call(&[
                            simopt::runtime::Arg::F32(&h),
                            simopt::runtime::Arg::F32(&gv),
                        ])
                        .unwrap(),
                    );
                });
            }
        }
    }

    bench.finish();
}
