//! Ablation A2: Algorithm 4's explicit O(Mn²) inverse-Hessian build vs the
//! O(Mn) two-loop recursion, on both backends.
//!
//! The paper showcases the explicit form as GPU-friendly matrix work; the
//! two-loop form is what a CPU implementation would normally choose.  This
//! bench shows the crossover.

mod common;

use simopt::backend::HessianMode;
use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec};
use simopt::bench::Bench;

fn main() {
    if !common::artifacts_built() {
        eprintln!("[bench] artifacts/ missing — run `make artifacts` first");
        return;
    }
    let smoke = common::smoke();
    let iters =
        if smoke { 10 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 150) };
    let reps =
        if smoke { 1 } else { common::env_usize("SIMOPT_BENCH_REPS", 3) };
    let sizes = if smoke {
        vec![64]
    } else {
        common::env_sizes(vec![64, 256, 1024])
    };
    let mut coord = Coordinator::new("artifacts", "results").unwrap();
    let mut bench = Bench::new("ablation_hessian");

    for &n in &sizes {
        for backend in [BackendKind::Native, BackendKind::Xla] {
            for (mode, tag) in [(HessianMode::Explicit, "explicitH"),
                                (HessianMode::TwoLoop, "twoloop")] {
                let spec = ExperimentSpec::new(TaskKind::Classification, backend)
                    .size(n)
                    .epochs(iters)
                    .replications(reps)
                    .seed(42)
                    .hessian(mode);
                eprintln!("[ablation_hessian] {} {} n={}", backend, tag, n);
                let res = match coord.run(&spec) {
                    Ok(res) => res,
                    Err(e) => {
                        // e.g. the xla arm against the in-tree PJRT stub
                        eprintln!("[ablation_hessian] skipping {} {}: {:#}",
                                  backend, tag, e);
                        continue;
                    }
                };
                let samples: Vec<f64> =
                    res.reps.iter().map(|r| r.total_s).collect();
                bench.record(&format!("{}_{}_n{}", backend, tag, n), &samples);
            }
        }
    }
    bench.finish();

    // headline: explicit/twoloop ratio per backend at the largest size
    let n = sizes.last().unwrap();
    for backend in ["native", "xla"] {
        let e = bench.find(&format!("{}_explicitH_n{}", backend, n));
        let t = bench.find(&format!("{}_twoloop_n{}", backend, n));
        if let (Some(e), Some(t)) = (e, t) {
            println!(
                "{} @ n={}: explicit-H costs {:.2}× the two-loop recursion",
                backend, n,
                e.mean_s / t.mean_s.max(1e-12)
            );
        }
    }
}
