//! Batched replication engine throughput (DESIGN.md §11): the paper's
//! scaling thesis applied to the replication axis.
//!
//! For each problem size, R replications of the mean-variance, newsvendor,
//! and classification (SQN) tasks run (a) strictly sequentially — R
//! per-replication driver runs one after another, the
//! many-small-dispatches pattern — and (b) through the batched engine,
//! which advances all R replications per call with replication-major
//! thread parallelism; the SQN cells exercise the padded batched
//! direction engine (one `direction_batch` over the `[R × mem × n]`
//! correction panels per step, DESIGN.md §11).  Both paths produce
//! bit-identical iterates (asserted below), so the ratio is pure
//! dispatch/parallelism win.
//!
//! Knobs: SIMOPT_BENCH_SIZES, SIMOPT_BENCH_REPS (= R), SIMOPT_BENCH_EPOCHS,
//! SIMOPT_BENCH_LR_SIZES, SIMOPT_BENCH_SQN_ITERS.

mod common;

use simopt::backend::native::{NativeLr, NativeLrBatch, NativeMode, NativeMv,
                              NativeMvBatch, NativeNv, NativeNvBatch};
use simopt::backend::HessianMode;
use simopt::bench::{speedup, Bench};
use simopt::coordinator::rep_subtrees;
use simopt::opt::{run_mv, run_mv_batch, run_nv, run_nv_batch, run_sqn,
                  run_sqn_batch, SqnConfig};
use simopt::rng::StreamTree;
use simopt::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use simopt::tasks::NvLmo;

fn main() {
    let smoke = common::smoke();
    let sizes = if smoke {
        vec![64]
    } else {
        common::env_sizes(vec![256, 1024, 2048])
    };
    let r_reps = if smoke { 4 } else { common::env_usize("SIMOPT_BENCH_REPS", 8) };
    let epochs = if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 6) };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let (n_samples, m_inner) = (64usize, 10usize);

    println!(
        "batch_sweep: R={} replications, {} epochs, {} threads\n",
        r_reps, epochs, threads
    );
    let mut bench = Bench::new("batch_sweep")
        .warmup(if smoke { 0 } else { 1 })
        .reps(if smoke { 1 } else { 3 });

    for &d in &sizes {
        let tree = StreamTree::new(42);
        // the exact replication streams the coordinator derives
        let trees: Vec<StreamTree> = rep_subtrees(&tree, r_reps);

        // ---- Task 1: mean-variance --------------------------------------
        let universe = AssetUniverse::generate(&tree, d);
        let w0 = vec![1.0f32 / d as f32; d];

        let mut seq_final: Vec<Vec<f32>> = Vec::new();
        let seq_m = bench
            .case(&format!("mv_sequential_d{}_R{}", d, r_reps), || {
                seq_final.clear();
                for sub in &trees {
                    let mut backend = NativeMv::new(
                        universe.clone(), n_samples, m_inner,
                        NativeMode::Sequential);
                    let (w, _) =
                        run_mv(&mut backend, w0.clone(), epochs, sub).unwrap();
                    seq_final.push(w);
                }
            })
            .clone();

        let mut batch_final: Vec<f32> = Vec::new();
        let batch_m = bench
            .case(&format!("mv_batched_d{}_R{}", d, r_reps), || {
                let mut backend = NativeMvBatch::new(
                    &universe, n_samples, m_inner, r_reps, threads);
                let (w, _) =
                    run_mv_batch(&mut backend, &w0, epochs, &trees).unwrap();
                batch_final = w;
            })
            .clone();

        // batched must be a different schedule, not a different answer
        for (r, w_seq) in seq_final.iter().enumerate() {
            assert_eq!(&batch_final[r * d..(r + 1) * d], w_seq.as_slice(),
                       "mv d={} rep {}: batched != sequential", d, r);
        }
        println!("mv d={}: batched throughput {:.2}× sequential", d,
                 speedup(&seq_m, &batch_m));

        // ---- Task 2: newsvendor ------------------------------------------
        let inst = NewsvendorInstance::generate(&tree, d, 8, 0.6);
        let x0 = inst.feasible_start();

        let nv_seq = bench
            .case(&format!("nv_sequential_d{}_R{}", d, r_reps), || {
                for sub in &trees {
                    let mut backend = NativeNv::new(
                        inst.clone(), 32, NativeMode::Sequential);
                    let mut lmo = NvLmo::new(&inst);
                    run_nv(&mut backend, &mut lmo, x0.clone(), epochs,
                           m_inner, sub)
                        .unwrap();
                }
            })
            .clone();
        let nv_batch = bench
            .case(&format!("nv_batched_d{}_R{}", d, r_reps), || {
                let mut backend =
                    NativeNvBatch::new(&inst, 32, r_reps, threads);
                let mut lmos: Vec<NvLmo> =
                    (0..r_reps).map(|_| NvLmo::new(&inst)).collect();
                run_nv_batch(&mut backend, &mut lmos, &x0, epochs, m_inner,
                             &trees, threads)
                    .unwrap();
            })
            .clone();
        println!("nv d={}: batched throughput {:.2}× sequential\n", d,
                 speedup(&nv_seq, &nv_batch));
    }

    // ---- Task 3: classification SQN + padded direction engine -----------
    // Feature dims get their own (smaller) axis: the dataset is 30n × n,
    // so the mv/nv size list would blow the design matrix up to hundreds
    // of MB.
    let lr_sizes: Vec<usize> = if smoke {
        vec![24]
    } else {
        match std::env::var("SIMOPT_BENCH_LR_SIZES") {
            Ok(v) => v.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            Err(_) => vec![64, 256],
        }
    };
    let sqn_cfg = SqnConfig {
        iters: if smoke {
            12
        } else {
            common::env_usize("SIMOPT_BENCH_SQN_ITERS", 60)
        },
        batch: 32,
        hbatch: 64,
        l_every: 5,
        memory: 8,
        beta: 2.0,
        track_every: 0, // timing cells: no tracked-loss evaluations
        track_rows: 0,
    };
    for &n in &lr_sizes {
        let tree = StreamTree::new(43);
        let trees: Vec<StreamTree> = rep_subtrees(&tree, r_reps);
        let data = ClassifyData::generate(&tree, n);

        let mut seq_final: Vec<Vec<f32>> = Vec::new();
        let lr_seq = bench
            .case(&format!("sqn_sequential_n{}_R{}", n, r_reps), || {
                seq_final.clear();
                for sub in &trees {
                    let mut backend = NativeLr::new(
                        &data, NativeMode::Sequential, HessianMode::Explicit);
                    let (w, _) =
                        run_sqn(&mut backend, &data, &sqn_cfg, sub).unwrap();
                    seq_final.push(w);
                }
            })
            .clone();

        let mut batch_final: Vec<f32> = Vec::new();
        let lr_batch = bench
            .case(&format!("sqn_batched_n{}_R{}", n, r_reps), || {
                let mut backend = NativeLrBatch::new(
                    &data, r_reps, threads, HessianMode::Explicit);
                let (w, _) =
                    run_sqn_batch(&mut backend, &data, &sqn_cfg, &trees)
                        .unwrap();
                batch_final = w;
            })
            .clone();

        // the padded direction engine must be a different schedule, not a
        // different answer
        for (r, w_seq) in seq_final.iter().enumerate() {
            assert_eq!(&batch_final[r * n..(r + 1) * n], w_seq.as_slice(),
                       "sqn n={} rep {}: batched != sequential", n, r);
        }
        println!("sqn n={}: batched throughput {:.2}× sequential (incl. \
                  padded Algorithm-4 directions)\n", n,
                 speedup(&lr_seq, &lr_batch));
    }

    bench.finish();
    println!(
        "\n(The batched arm amortizes the replication axis over {} threads; \
         on a single-core box the ratio degenerates to ~1× — the scaling \
         claim is about dispatch structure, not magic.)",
        threads
    );
}
