//! Ablation A4: sample-batch scaling — how the per-epoch cost of each arm
//! scales with the Monte-Carlo panel size N (the paper resamples N draws per
//! gradient estimate; §4.1 uses 25-50).
//!
//! The vectorized arm amortizes panel growth (one fused dispatch), while the
//! sequential arm's cost grows linearly from the start — the per-sample loop
//! the paper's §2.2 describes.  Native-only axis here; the XLA artifact's N
//! is baked at AOT time (N=64 default), so its single point is included when
//! available.

mod common;

use simopt::backend::native::{NativeMode, NativeMv};
use simopt::bench::Bench;
use simopt::opt::run_mv;
use simopt::rng::StreamTree;
use simopt::sim::AssetUniverse;

fn main() {
    let smoke = common::smoke();
    let epochs = if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 8) };
    let reps = if smoke { 1 } else { common::env_usize("SIMOPT_BENCH_REPS", 3) };
    let d = if smoke { 128 } else { common::env_usize("SIMOPT_BENCH_D", 2048) };
    let batches: &[usize] =
        if smoke { &[16, 256] } else { &[16, 32, 64, 128, 256] };

    let tree = StreamTree::new(42);
    let universe = AssetUniverse::generate(&tree, d);
    let w0 = vec![1.0f32 / d as f32; d];
    let mut bench = Bench::new("ablation_batch").warmup(1).reps(reps);

    for &n in batches {
        let mut backend =
            NativeMv::new(universe.clone(), n, 25, NativeMode::Sequential);
        bench.case(&format!("native_d{}_N{}", d, n), || {
            run_mv(&mut backend, w0.clone(), epochs, &tree.subtree(&[7]))
                .unwrap();
        });
    }

    if common::artifacts_built() {
        if let Ok(engine) = simopt::runtime::Engine::new("artifacts") {
            for n in engine.manifest.available_params("mv_epoch", "n") {
                if let Ok(mut xla) = simopt::backend::xla::XlaMv::new(
                    &engine, &universe, n as usize, 25) {
                    bench.case(&format!("xla_d{}_N{}", d, n), || {
                        run_mv(&mut xla, w0.clone(), epochs,
                               &tree.subtree(&[7])).unwrap();
                    });
                }
            }
        }
    }
    bench.finish();

    // linear-scaling check on the native arm
    let t16 = bench.find(&format!("native_d{}_N16", d)).map(|m| m.mean_s);
    let t256 = bench.find(&format!("native_d{}_N256", d)).map(|m| m.mean_s);
    if let (Some(a), Some(b)) = (t16, t256) {
        println!("native cost ratio N=256/N=16: {:.1}× (linear would be 16×)",
                 b / a.max(1e-12));
    }
}
