//! Ablation A1: fused-epoch dispatch vs per-iteration dispatch.
//!
//! The paper attributes part of the GPU win to executing the whole
//! sampling+iteration loop on-device.  This bench quantifies the host↔device
//! boundary: `mv_epoch` (one dispatch per epoch, sampling in-graph) against
//! `mv_grad_step` (M dispatches per epoch, panel shipped on every call).

mod common;

use simopt::backend::xla::{XlaMv, XlaMvStepwise};
use simopt::bench::{speedup, Bench};
use simopt::opt::run_mv;
use simopt::rng::StreamTree;
use simopt::runtime::Engine;
use simopt::sim::AssetUniverse;

fn main() {
    if !common::artifacts_built() {
        eprintln!("[bench] artifacts/ missing — run `make artifacts` first");
        return;
    }
    let engine = match Engine::new("artifacts") {
        Ok(e) => e,
        Err(e) => {
            // e.g. built against the in-tree `xla` stub (no PJRT runtime)
            eprintln!("[bench] PJRT engine unavailable — skipping: {:#}", e);
            return;
        }
    };
    // the step artifact is AOT'd at one (mid-size) configuration
    let Some(meta) = engine
        .manifest
        .artifacts
        .iter()
        .find(|a| a.entry == "mv_grad_step")
    else {
        eprintln!("[bench] no mv_grad_step artifact — skipping");
        return;
    };
    let d = meta.params["d"] as usize;
    let n = meta.params["n"] as usize;
    let m = meta.params["m"] as usize;
    let smoke = common::smoke();
    let epochs =
        if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 10) };
    let reps =
        if smoke { 1 } else { common::env_usize("SIMOPT_BENCH_REPS", 5) };

    let tree = StreamTree::new(42);
    let universe = AssetUniverse::generate(&tree, d);
    let w0 = vec![1.0f32 / d as f32; d];

    let mut bench = Bench::new("ablation_dispatch").warmup(1).reps(reps);

    let mut fused = XlaMv::new(&engine, &universe, n, m).expect("fused");
    let fused_m = bench
        .case(&format!("fused_epoch_d{}", d), || {
            run_mv(&mut fused, w0.clone(), epochs, &tree.subtree(&[1])).unwrap();
        })
        .clone();

    let mut step = XlaMvStepwise::new(&engine, &universe, n, m).expect("step");
    let step_m = bench
        .case(&format!("per_iteration_d{}", d), || {
            run_mv(&mut step, w0.clone(), epochs, &tree.subtree(&[1])).unwrap();
        })
        .clone();

    bench.finish();
    println!(
        "fused-epoch speedup over per-iteration dispatch: {:.2}×\n\
         (M = {} dispatches + {}×{} panel transfers per epoch avoided)",
        speedup(&step_m, &fused_m),
        m,
        n,
        d
    );
}
