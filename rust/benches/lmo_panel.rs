//! Panel LMO vs the reconstructed serial per-replication loop
//! (DESIGN.md §17): the Algorithm-2 inner step — batched gradient, R LP
//! LMO solves, R FW updates — at two replication scales.
//!
//! Each (R, m) cell pairs two arms over identical gradients and keys:
//! * `seq_loop` — the pre-§17 shape, reconstructed: every inner step
//!   walks `lmos` one row at a time through `NvLmo::solve_into`, each
//!   solve paying its own two-phase simplex from scratch on the driver
//!   thread.  The row loop books as `lmo`, the update loop as `reduce`.
//! * `panel` — the shipped spine: ONE `NvLmo::solve_panel_into` call per
//!   inner step; the shared `(A, cap)` seed is factored once and
//!   warm-reused across steps, and the rows fan out over the worker pool
//!   with disjoint `&mut` vertex chunks.  Same phase bookings, so the
//!   lmo-share drop is directly visible in `BENCH_lmo_panel.json` and
//!   ridden by the trajectory gate (`python/tools/trajectory.py`).
//!
//! Both arms run the bit-identical per-row arithmetic: every inner
//! step's vertex panel and the final iterate panels are asserted equal
//! bit for bit (the `lp::panel` contract).
//!
//! Knobs: SIMOPT_BENCH_EPOCHS (outer steps per cell, default 6),
//! SIMOPT_BENCH_THREADS (panel-arm pool width, default: hardware).

mod common;

use simopt::backend::native::NativeNvBatch;
use simopt::backend::plane::tile_rows;
use simopt::backend::NvBatchBackend;
use simopt::bench::Bench;
use simopt::coordinator::rep_subtrees;
use simopt::linalg::vector::fw_update;
use simopt::lp::PanelWorkspace;
use simopt::opt::schedule::fw_gamma;
use simopt::rng::StreamTree;
use simopt::sim::NewsvendorInstance;
use simopt::tasks::NvLmo;
use simopt::util::profile::{Phase, Profiler};
use simopt::util::timer::Timer;

/// Lmo share of a drained profile, for the end-of-run summary.
fn lmo_share(prof: &Profiler) -> f64 {
    let total = prof.sum();
    if total > 0.0 {
        prof.get(Phase::Lmo) / total
    } else {
        0.0
    }
}

fn main() {
    let smoke = common::smoke();
    let epochs =
        if smoke { 2 } else { common::env_usize("SIMOPT_BENCH_EPOCHS", 6) };
    let m_inner = if smoke { 2 } else { 5 };
    // (R, m) cells: replication count × resource rows; d = 4m products
    let shapes: Vec<(usize, usize)> =
        if smoke { vec![(4, 2)] } else { vec![(16, 8), (96, 16)] };
    let n_samples = 32usize;
    let hw = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    let threads = common::env_usize("SIMOPT_BENCH_THREADS", hw);

    println!("lmo_panel: {} epochs × {} inner steps per cell, panel arm \
              at {} threads, (R, m) shapes {:?}\n",
             epochs, m_inner, threads, shapes);
    // every cell records its own per-epoch samples via record_profiled,
    // so the harness-level warmup/reps protocol is unused here
    let mut bench = Bench::new("lmo_panel");
    // (label, serial-loop lmo share, panel lmo share)
    let mut summary: Vec<(String, f64, f64)> = Vec::new();

    for &(r, m) in &shapes {
        let d = 4 * m;
        let tree = StreamTree::new(417);
        let trees = rep_subtrees(&tree, r);
        let inst = NewsvendorInstance::generate(&tree, d, m, 0.6);
        let x0 = inst.feasible_start();
        let keys_by_epoch: Vec<Vec<[u32; 2]>> = (0..epochs)
            .map(|k| trees.iter().map(|t| t.jax_key(&[k as u64])).collect())
            .collect();

        // ---- arm 1: reconstructed serial row loop ------------------------
        let mut backend = NativeNvBatch::new(&inst, n_samples, r, 1);
        let mut lmos: Vec<NvLmo> =
            (0..r).map(|_| NvLmo::new(&inst)).collect();
        let mut panel_seq = tile_rows(&x0, r);
        let mut g = vec![0.0f32; r * d];
        let mut verts = vec![0.0f32; r * d];
        let mut objs = vec![0.0f64; r];
        // per-inner-step vertex panels, kept for the cross-arm bit-assert
        let mut vert_log: Vec<Vec<f32>> = Vec::new();
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let t = Timer::start();
            for mi in 0..m_inner {
                backend
                    .grad_obj_batch(&panel_seq, &keys_by_epoch[k], &mut g,
                                    &mut objs)
                    .unwrap();
                let gamma = fw_gamma(k, mi, m_inner);
                let t_l = Timer::start();
                for (i, lmo) in lmos.iter_mut().enumerate() {
                    lmo.solve_into(&g[i * d..(i + 1) * d],
                                   &mut verts[i * d..(i + 1) * d])
                        .unwrap();
                }
                prof.add(Phase::Lmo, t_l.elapsed_s());
                let t_u = Timer::start();
                for (xi, vi) in panel_seq.chunks_mut(d).zip(verts.chunks(d))
                {
                    fw_update(xi, vi, gamma);
                }
                prof.add(Phase::Reduce, t_u.elapsed_s());
                vert_log.push(verts.clone());
            }
            samples.push(t.elapsed_s());
            if let Some(p) = backend.take_profile() {
                prof.merge(&p);
            }
        }
        let seq_share = lmo_share(&prof);
        bench.record_profiled(&format!("seq_loop_R{}_m{}", r, m), &samples,
                              prof);

        // ---- arm 2: panel LMO --------------------------------------------
        let mut backend = NativeNvBatch::new(&inst, n_samples, r, 1);
        let mut lmos: Vec<NvLmo> =
            (0..r).map(|_| NvLmo::new(&inst)).collect();
        let mut seed = PanelWorkspace::new();
        let mut panel_par = tile_rows(&x0, r);
        let mut step = 0usize;
        let mut samples = Vec::with_capacity(epochs);
        let mut prof = Profiler::new();
        for k in 0..epochs {
            let t = Timer::start();
            for mi in 0..m_inner {
                backend
                    .grad_obj_batch(&panel_par, &keys_by_epoch[k], &mut g,
                                    &mut objs)
                    .unwrap();
                let gamma = fw_gamma(k, mi, m_inner);
                let t_l = Timer::start();
                NvLmo::solve_panel_into(&mut lmos, &mut seed, &g, &mut verts,
                                        threads)
                    .unwrap();
                prof.add(Phase::Lmo, t_l.elapsed_s());
                let t_u = Timer::start();
                for (xi, vi) in panel_par.chunks_mut(d).zip(verts.chunks(d))
                {
                    fw_update(xi, vi, gamma);
                }
                prof.add(Phase::Reduce, t_u.elapsed_s());
                // the lp::panel contract, asserted inner step by inner
                // step: same gradients ⇒ bitwise-identical vertices
                assert_eq!(verts, vert_log[step],
                           "R={} m={} step {}: panel verts != serial verts",
                           r, m, step);
                step += 1;
            }
            samples.push(t.elapsed_s());
            if let Some(p) = backend.take_profile() {
                prof.merge(&p);
            }
        }
        let panel_share = lmo_share(&prof);
        bench.record_profiled(&format!("panel_R{}_m{}", r, m), &samples,
                              prof);
        assert_eq!(panel_seq, panel_par,
                   "R={} m={}: panel iterates != serial iterates", r, m);
        summary.push((format!("R{}_m{}", r, m), seq_share, panel_share));
    }

    bench.finish();
    println!("\nlmo-phase share (LP wall / total step wall):");
    println!("| cell | serial loop | panel |");
    println!("|---|---|---|");
    for (label, seq, panel) in &summary {
        println!("| {} | {:.2}% | {:.2}% |", label, seq * 100.0,
                 panel * 100.0);
    }
    println!("\n(The panel arm factors the shared (A, cap) seed once, \
              warm-reuses it across steps, and fans the per-row phase-2 \
              solves out over the worker pool — the serial arm pays a \
              from-scratch two-phase simplex per row per inner step on \
              the driver thread, so its lmo share grows with R, \
              DESIGN.md §17.)");
}
