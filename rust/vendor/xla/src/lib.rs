//! Stub of the `xla` PJRT bindings (see Cargo.toml for why).
//!
//! Exposes exactly the types and signatures `simopt::runtime` and
//! `simopt::backend::xla` call.  Construction entry points
//! ([`PjRtClient::cpu`], [`Literal::create_from_shape_and_untyped_data`],
//! [`HloModuleProto::from_text_file`]) fail with an actionable message, so
//! no device value ever exists at runtime and every downstream method is
//! unreachable in practice — but everything type-checks, builds and lints
//! offline.

use std::fmt;

/// Error type matching the real crate's `Result<_, xla::Error>` shape.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{}: PJRT runtime unavailable — this build links the in-tree `xla` \
         stub crate; patch in a real xla_extension build (DESIGN.md §6) to \
         execute AOT artifacts",
        what
    )))
}

/// Element dtypes the simopt artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Host-side literal (tensor) handle.
pub struct Literal {
    _p: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle.
#[derive(Clone)]
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation handle fed to [`PjRtClient::compile`].
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_fail_actionably() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("stub"), "{}", e);
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[2], &[0u8; 8]
        )
        .is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
