//! Vendored `anyhow` subset (see Cargo.toml for why).
//!
//! Semantics mirror the real crate for the API simopt uses:
//!
//! * `Error` is an opaque chain of context frames, outermost first.
//! * `{}` prints the outermost message, `{:#}` the full chain joined by
//!   `": "`, `{:?}` the anyhow-style "Caused by:" listing.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` (the
//!   source chain is captured eagerly as strings).
//! * `.context(..)` / `.with_context(..)` work on both `Result` and
//!   `Option`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of human-readable frames, outermost first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Create from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (what `.context(..)` uses).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost frame.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line, anyhow convention
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(self.frames.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{}", head)?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, frame) in rest.iter().enumerate() {
                        if rest.len() > 1 {
                            write!(f, "\n    {}: {}", i, frame)?;
                        } else {
                            write!(f, "\n    {}", frame)?;
                        }
                    }
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` — that
// is what makes the blanket impls below coherent (the same trick the real
// anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

mod ext {
    use super::Error;

    /// Anything `.context(..)` can upgrade into an [`Error`].
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_modes() {
        let e = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{}", e), "reading manifest");
        assert_eq!(format!("{:#}", e), "reading manifest: gone");
        assert!(format!("{:?}", e).contains("Caused by:"));
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{:#}", e), "outer: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {}", x);
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        assert_eq!(anyhow!("literal").to_string(), "literal");
        assert_eq!(anyhow!("x={}", 2).to_string(), "x=2");
    }

    #[test]
    fn chain_order_outermost_first() {
        let e = Error::msg("root").context("mid").context("top");
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames, vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
