//! Workload simulators — the stochastic systems the paper optimizes over.
//!
//! Each generator reproduces the corresponding §4.1 experimental setup:
//! * [`assets`] — asset-return universe, μᵢ ~ U(−1,1), σᵢ ~ U(0,0.025);
//! * [`demand`] — multi-product demand + cost structure + technology matrix
//!   (μ ~ U(20,50), σ ~ U(10,20), resource constraints per Niederhoff 2007);
//! * [`classify`] — synthetic binary-feature dataset with 10% label noise
//!   (Mukherjee et al. 2013 / Byrd et al. 2016 construction, N = 30n).

pub mod assets;
pub mod classify;
pub mod demand;

pub use assets::AssetUniverse;
pub use classify::ClassifyData;
pub use demand::NewsvendorInstance;
