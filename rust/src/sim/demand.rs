//! Multi-product newsvendor instance generator for Task 2 (paper §3.2/§4.1).
//!
//! Demands: independent N(μⱼ, σⱼ²), μⱼ ~ U(20,50), σⱼ ~ U(10,20) (paper).
//! Cost structure (paper leaves it unspecified; Niederhoff 2007 economics):
//! unit cost kⱼ, holding hⱼ, selling value vⱼ with vⱼ > kⱼ so products are
//! profitable and the critical fractile (vⱼ−kⱼ)/(vⱼ+hⱼ) sits in (0,1).
//! Resources: an M×N technology matrix with positive requirements and
//! capacities set to a fraction of the unconstrained optimum's usage so the
//! budget constraints genuinely bind (otherwise the LP LMO is trivial).

use crate::linalg::Mat;
use crate::rng::{NormalSampler, StreamTree};

#[derive(Debug, Clone)]
pub struct NewsvendorInstance {
    pub mu: Vec<f32>,
    pub sigma: Vec<f32>,
    /// Unit procurement cost kⱼ.
    pub k: Vec<f32>,
    /// Unit holding cost hⱼ.
    pub h: Vec<f32>,
    /// Unit selling value vⱼ (lost-sales penalty).
    pub v: Vec<f32>,
    /// M×N technology matrix (resource i usage per unit of product j).
    pub a: Mat,
    /// Capacity per resource.
    pub cap: Vec<f32>,
}

/// Inverse standard-normal CDF (Acklam's rational approximation, |ε|<1.15e-9)
/// — used for the critical-fractile reference solution.
pub fn norm_ppf(p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "p in (0,1)");
    const A: [f64; 6] = [-3.969683028665376e+01, 2.209460984245205e+02,
        -2.759285104469687e+02, 1.383577518672690e+02,
        -3.066479806614716e+01, 2.506628277459239e+00];
    const B: [f64; 5] = [-5.447609879822406e+01, 1.615858368580409e+02,
        -1.556989798598866e+02, 6.680131188771972e+01,
        -1.328068155288572e+01];
    const C: [f64; 6] = [-7.784894002430293e-03, -3.223964580411365e-01,
        -2.400758277161838e+00, -2.549732539343734e+00,
        4.374664141464968e+00, 2.938163982698783e+00];
    const D: [f64; 4] = [7.784695709041462e-03, 3.224671290700398e-01,
        2.445134137142996e+00, 3.754408661907416e+00];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

impl NewsvendorInstance {
    /// Generate an instance with `d` products and `m_resources` constraints.
    /// `tightness` ∈ (0,1]: capacity as a fraction of the unconstrained
    /// optimum's resource usage (lower = more binding).
    pub fn generate(tree: &StreamTree, d: usize, m_resources: usize,
                    tightness: f32) -> Self {
        let mut rng = tree.stream(&[0xDE3A2D]);
        let mu: Vec<f32> = (0..d).map(|_| rng.uniform_f32(20.0, 50.0)).collect();
        let sigma: Vec<f32> = (0..d).map(|_| rng.uniform_f32(10.0, 20.0)).collect();
        let k: Vec<f32> = (0..d).map(|_| rng.uniform_f32(1.0, 3.0)).collect();
        let h: Vec<f32> = (0..d).map(|_| rng.uniform_f32(0.1, 0.5)).collect();
        // v > k: margin above cost
        let v: Vec<f32> = k.iter().map(|&kj| kj + rng.uniform_f32(1.0, 5.0)).collect();
        let mut a = Mat::zeros(m_resources, d);
        for i in 0..m_resources {
            for j in 0..d {
                a.set(i, j, rng.uniform_f32(0.2, 1.2));
            }
        }
        // capacity from the unconstrained fractile solution
        let x_star = Self::fractile_solution(&mu, &sigma, &k, &h, &v);
        let mut cap = vec![0.0f32; m_resources];
        for i in 0..m_resources {
            let usage: f64 = (0..d)
                .map(|j| a.get(i, j) as f64 * x_star[j] as f64)
                .sum();
            cap[i] = (usage as f32) * tightness;
        }
        NewsvendorInstance { mu, sigma, k, h, v, a, cap }
    }

    pub fn dim(&self) -> usize {
        self.mu.len()
    }

    pub fn resources(&self) -> usize {
        self.cap.len()
    }

    /// The unconstrained optimum: xⱼ* = μⱼ + σⱼ·Φ⁻¹((vⱼ−kⱼ)/(vⱼ+hⱼ))
    /// (critical fractile of eq. (8) set to zero).
    pub fn fractile_solution(mu: &[f32], sigma: &[f32], k: &[f32], h: &[f32],
                             v: &[f32]) -> Vec<f32> {
        mu.iter()
            .zip(sigma)
            .zip(k.iter().zip(h.iter().zip(v)))
            .map(|((&m, &s), (&kj, (&hj, &vj)))| {
                let frac = ((vj - kj) / (vj + hj)) as f64;
                let frac = frac.clamp(1e-6, 1.0 - 1e-6);
                (m as f64 + s as f64 * norm_ppf(frac)).max(0.0) as f32
            })
            .collect()
    }

    pub fn unconstrained_optimum(&self) -> Vec<f32> {
        Self::fractile_solution(&self.mu, &self.sigma, &self.k, &self.h, &self.v)
    }

    /// Sample an (s × d) demand panel row-major into `out`.
    pub fn sample_panel(&self, sampler: &mut NormalSampler, s: usize,
                        out: &mut [f32]) {
        sampler.fill_panel(&self.mu, &self.sigma, s, out);
    }

    /// A feasible starting point: the origin scaled toward the fractile
    /// solution until every resource constraint holds.
    pub fn feasible_start(&self) -> Vec<f32> {
        let mut x = self.unconstrained_optimum();
        let mut shrink = 1.0f32;
        for i in 0..self.resources() {
            let usage: f32 = (0..self.dim())
                .map(|j| self.a.get(i, j) * x[j])
                .sum();
            if usage > self.cap[i] && usage > 0.0 {
                shrink = shrink.min(self.cap[i] / usage);
            }
        }
        let shrink = shrink * 0.9; // strictly interior
        for v in x.iter_mut() {
            *v *= shrink;
        }
        x
    }

    /// Check Ax ≤ cap, x ≥ 0 within `tol`.
    pub fn is_feasible(&self, x: &[f32], tol: f32) -> bool {
        if x.iter().any(|&v| v < -tol) {
            return false;
        }
        for i in 0..self.resources() {
            let usage: f32 = (0..self.dim())
                .map(|j| self.a.get(i, j) * x[j])
                .sum();
            if usage > self.cap[i] + tol {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_ppf_known_values() {
        assert!((norm_ppf(0.5)).abs() < 1e-9);
        assert!((norm_ppf(0.975) - 1.959964).abs() < 1e-5);
        assert!((norm_ppf(0.025) + 1.959964).abs() < 1e-5);
        assert!((norm_ppf(0.841344746) - 1.0).abs() < 1e-6);
        // tails
        assert!((norm_ppf(1e-6) + 4.753424).abs() < 1e-4);
    }

    #[test]
    fn generate_ranges_and_determinism() {
        let t = StreamTree::new(5);
        let inst = NewsvendorInstance::generate(&t, 100, 4, 0.6);
        assert_eq!(inst.dim(), 100);
        assert_eq!(inst.resources(), 4);
        assert!(inst.mu.iter().all(|&m| (20.0..=50.0).contains(&m)));
        assert!(inst.sigma.iter().all(|&s| (10.0..=20.0).contains(&s)));
        assert!(inst.v.iter().zip(&inst.k).all(|(&vj, &kj)| vj > kj));
        let inst2 = NewsvendorInstance::generate(&t, 100, 4, 0.6);
        assert_eq!(inst.mu, inst2.mu);
        assert_eq!(inst.cap, inst2.cap);
    }

    #[test]
    fn fractile_is_stationary_point() {
        // At x*, k - v + (h+v)Φ(x*) = 0 by construction.
        let inst = NewsvendorInstance::generate(&StreamTree::new(7), 16, 2, 0.6);
        let x = inst.unconstrained_optimum();
        for j in 0..16 {
            let zq = (x[j] - inst.mu[j]) / inst.sigma[j];
            let phi = 0.5 * (1.0 + erf_approx(zq as f64 / std::f64::consts::SQRT_2));
            let grad = inst.k[j] as f64 - inst.v[j] as f64
                + (inst.h[j] as f64 + inst.v[j] as f64) * phi;
            assert!(grad.abs() < 1e-3, "j={} grad={}", j, grad);
        }
    }

    fn erf_approx(x: f64) -> f64 {
        // Abramowitz-Stegun 7.1.26
        let s = if x < 0.0 { -1.0 } else { 1.0 };
        let x = x.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741)
            * t - 0.284496736) * t + 0.254829592) * t * (-x * x).exp();
        s * y
    }

    #[test]
    fn capacity_binds() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(3), 32, 3, 0.6);
        // the unconstrained optimum must violate at least one constraint
        assert!(!inst.is_feasible(&inst.unconstrained_optimum(), 1e-4));
        // and the feasible start must satisfy all
        assert!(inst.is_feasible(&inst.feasible_start(), 1e-4));
    }

    #[test]
    fn panel_mean_matches_mu() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(11), 8, 2, 0.6);
        let mut s = StreamTree::new(11).normal(&[2]);
        let n = 4000;
        let mut panel = vec![0.0f32; n * 8];
        inst.sample_panel(&mut s, n, &mut panel);
        for j in 0..8 {
            let m: f64 = (0..n).map(|i| panel[i * 8 + j] as f64).sum::<f64>() / n as f64;
            assert!((m - inst.mu[j] as f64).abs() < 1.0);
        }
    }
}
