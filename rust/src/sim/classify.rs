//! Synthetic binary-classification dataset for Task 3 (paper §4.1, after
//! Mukherjee et al. 2013 and Byrd et al. 2016): N = 30n samples of n binary
//! features; labels from a random linear rule with 10% label noise.

use crate::rng::StreamTree;

#[derive(Debug, Clone)]
pub struct ClassifyData {
    /// Row-major N×n design matrix (binary features stored as f32 0/1).
    pub x: Vec<f32>,
    /// Labels in {0, 1}.
    pub z: Vec<f32>,
    pub n_features: usize,
    pub n_samples: usize,
    /// The generating hyperplane (for diagnostics only — the optimizer never
    /// sees it).
    pub w_true: Vec<f32>,
}

impl ClassifyData {
    /// Paper construction: `n_samples = 30 * n_features`, features ~
    /// Bernoulli(0.5), labels `1{x·w_true > 0}` flipped with prob. 10%.
    pub fn generate(tree: &StreamTree, n_features: usize) -> Self {
        Self::generate_with(tree, n_features, 30 * n_features, 0.10)
    }

    pub fn generate_with(tree: &StreamTree, n_features: usize,
                         n_samples: usize, noise: f32) -> Self {
        let mut rng = tree.stream(&[0xC1A55]);
        let mut norm = tree.normal(&[0xC1A55, 1]);
        let w_true: Vec<f32> = (0..n_features).map(|_| norm.next()).collect();
        // E[x·w] over Bernoulli(0.5) features is Σw/2; center the threshold
        // so classes stay balanced.
        let threshold: f32 = w_true.iter().sum::<f32>() * 0.5;
        let mut x = vec![0.0f32; n_samples * n_features];
        let mut z = vec![0.0f32; n_samples];
        for i in 0..n_samples {
            let row = &mut x[i * n_features..(i + 1) * n_features];
            let mut score = 0.0f32;
            for (j, cell) in row.iter_mut().enumerate() {
                let bit = (rng.next_u32() & 1) as f32;
                *cell = bit;
                score += bit * w_true[j];
            }
            let mut label = if score > threshold { 1.0 } else { 0.0 };
            if rng.next_f32() < noise {
                label = 1.0 - label;
            }
            z[i] = label;
        }
        ClassifyData { x, z, n_features, n_samples, w_true }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Gather rows `idx` into a dense (|idx| × n) minibatch buffer — the
    /// shared data path both backends consume (CRN-pairable).
    pub fn gather(&self, idx: &[usize], xb: &mut Vec<f32>, zb: &mut Vec<f32>) {
        xb.clear();
        zb.clear();
        xb.reserve(idx.len() * self.n_features);
        zb.reserve(idx.len());
        for &i in idx {
            xb.extend_from_slice(self.row(i));
            zb.push(self.z[i]);
        }
    }

    /// Fraction of positive labels (class balance diagnostic).
    pub fn positive_rate(&self) -> f64 {
        self.z.iter().map(|&v| v as f64).sum::<f64>() / self.n_samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_follow_paper_convention() {
        let d = ClassifyData::generate(&StreamTree::new(1), 50);
        assert_eq!(d.n_features, 50);
        assert_eq!(d.n_samples, 1500);
        assert_eq!(d.x.len(), 1500 * 50);
        assert_eq!(d.z.len(), 1500);
    }

    #[test]
    fn features_are_binary() {
        let d = ClassifyData::generate(&StreamTree::new(2), 16);
        assert!(d.x.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(d.z.iter().all(|&v| v == 0.0 || v == 1.0));
        // features roughly balanced
        let ones: f64 = d.x.iter().map(|&v| v as f64).sum();
        let frac = ones / d.x.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "feature rate {}", frac);
    }

    #[test]
    fn classes_roughly_balanced() {
        let d = ClassifyData::generate(&StreamTree::new(3), 64);
        let p = d.positive_rate();
        assert!((0.3..0.7).contains(&p), "positive rate {}", p);
    }

    #[test]
    fn noise_rate_close_to_requested() {
        // With zero noise, labels are exactly the linear rule.
        let d0 = ClassifyData::generate_with(&StreamTree::new(4), 32, 2000, 0.0);
        let threshold: f32 = d0.w_true.iter().sum::<f32>() * 0.5;
        let mismatches = (0..d0.n_samples)
            .filter(|&i| {
                let score: f32 = d0
                    .row(i)
                    .iter()
                    .zip(&d0.w_true)
                    .map(|(x, w)| x * w)
                    .sum();
                let want = if score > threshold { 1.0 } else { 0.0 };
                d0.z[i] != want
            })
            .count();
        assert_eq!(mismatches, 0);
        // With 10% noise the mismatch rate is near 10%.
        let d1 = ClassifyData::generate_with(&StreamTree::new(4), 32, 2000, 0.10);
        let mism = (0..d1.n_samples)
            .filter(|&i| {
                let score: f32 = d1
                    .row(i)
                    .iter()
                    .zip(&d1.w_true)
                    .map(|(x, w)| x * w)
                    .sum();
                let want = if score > threshold { 1.0 } else { 0.0 };
                d1.z[i] != want
            })
            .count() as f64
            / d1.n_samples as f64;
        assert!((mism - 0.10).abs() < 0.03, "noise rate {}", mism);
    }

    #[test]
    fn gather_minibatch() {
        let d = ClassifyData::generate(&StreamTree::new(5), 8);
        let mut xb = Vec::new();
        let mut zb = Vec::new();
        d.gather(&[0, 5, 2], &mut xb, &mut zb);
        assert_eq!(xb.len(), 3 * 8);
        assert_eq!(zb, vec![d.z[0], d.z[5], d.z[2]]);
        assert_eq!(&xb[8..16], d.row(5));
    }

    #[test]
    fn deterministic() {
        let a = ClassifyData::generate(&StreamTree::new(6), 16);
        let b = ClassifyData::generate(&StreamTree::new(6), 16);
        assert_eq!(a.x, b.x);
        assert_eq!(a.z, b.z);
    }
}
