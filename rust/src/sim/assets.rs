//! Asset-return universe for Task 1 (paper §4.1): independent normal
//! returns with μᵢ ~ U(−1, 1) and σᵢ ~ U(0, 0.025).

use crate::rng::{NormalSampler, StreamTree};

/// The return distribution R ~ N(μ, diag(σ²)).
#[derive(Debug, Clone)]
pub struct AssetUniverse {
    pub mu: Vec<f32>,
    pub sigma: Vec<f32>,
}

impl AssetUniverse {
    /// Generate a universe of `d` assets from the experiment stream tree.
    pub fn generate(tree: &StreamTree, d: usize) -> Self {
        let mut rng = tree.stream(&[0xA55E7]);
        let mu = (0..d).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let sigma = (0..d).map(|_| rng.uniform_f32(0.0, 0.025)).collect();
        AssetUniverse { mu, sigma }
    }

    pub fn dim(&self) -> usize {
        self.mu.len()
    }

    /// Sample an (n × d) return panel row-major into `out` — the native
    /// backend's sequential analogue of the artifact's in-graph sampling.
    pub fn sample_panel(&self, sampler: &mut NormalSampler, n: usize,
                        out: &mut [f32]) {
        sampler.fill_panel(&self.mu, &self.sigma, n, out);
    }

    /// The exact population objective ½wᵀΣw − wᵀμ (diagonal Σ) — available
    /// because the generator knows the distribution; used for sanity checks
    /// and optimality-gap reporting.
    pub fn exact_objective(&self, w: &[f32]) -> f64 {
        assert_eq!(w.len(), self.dim());
        let mut quad = 0.0f64;
        let mut lin = 0.0f64;
        for j in 0..w.len() {
            quad += (w[j] as f64).powi(2) * (self.sigma[j] as f64).powi(2);
            lin += w[j] as f64 * self.mu[j] as f64;
        }
        0.5 * quad - lin
    }

    /// Greedy lower bound: all weight on the best single asset (a vertex of
    /// the simplex) — a useful reference point for the FW trace.
    pub fn best_single_asset(&self) -> (usize, f64) {
        let mut best = (0usize, f64::INFINITY);
        for j in 0..self.dim() {
            let v = 0.5 * (self.sigma[j] as f64).powi(2) - self.mu[j] as f64;
            if v < best.1 {
                best = (j, v);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StreamTree;

    #[test]
    fn generation_ranges() {
        let u = AssetUniverse::generate(&StreamTree::new(1), 500);
        assert_eq!(u.dim(), 500);
        assert!(u.mu.iter().all(|&m| (-1.0..=1.0).contains(&m)));
        assert!(u.sigma.iter().all(|&s| (0.0..=0.025).contains(&s)));
        // spread sanity: not all identical
        let first = u.mu[0];
        assert!(u.mu.iter().any(|&m| (m - first).abs() > 1e-3));
    }

    #[test]
    fn generation_deterministic_per_seed() {
        let a = AssetUniverse::generate(&StreamTree::new(9), 64);
        let b = AssetUniverse::generate(&StreamTree::new(9), 64);
        assert_eq!(a.mu, b.mu);
        assert_eq!(a.sigma, b.sigma);
        let c = AssetUniverse::generate(&StreamTree::new(10), 64);
        assert_ne!(a.mu, c.mu);
    }

    #[test]
    fn panel_statistics() {
        let u = AssetUniverse::generate(&StreamTree::new(2), 16);
        let mut s = StreamTree::new(2).normal(&[1]);
        let n = 4000;
        let mut panel = vec![0.0f32; n * 16];
        u.sample_panel(&mut s, n, &mut panel);
        for j in 0..16 {
            let col_mean: f64 =
                (0..n).map(|i| panel[i * 16 + j] as f64).sum::<f64>() / n as f64;
            assert!((col_mean - u.mu[j] as f64).abs() < 0.01,
                    "col {} mean {} vs mu {}", j, col_mean, u.mu[j]);
        }
    }

    #[test]
    fn exact_objective_prefers_high_return() {
        let u = AssetUniverse {
            mu: vec![0.9, -0.9],
            sigma: vec![0.01, 0.01],
        };
        let all_good = u.exact_objective(&[1.0, 0.0]);
        let all_bad = u.exact_objective(&[0.0, 1.0]);
        assert!(all_good < all_bad);
        let (j, v) = u.best_single_asset();
        assert_eq!(j, 0);
        assert!((v - all_good).abs() < 1e-9);
    }
}
