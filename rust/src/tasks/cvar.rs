//! Task 4 math (registry extension, DESIGN.md §12): smoothed mean-CVaR
//! portfolio selection over the same asset universe as Task 1.
//!
//! Rockafellar–Uryasev (2000) turn CVaR minimization into a joint convex
//! program over (w, t) — t is the VaR estimate — and the hinge (·)₊ is
//! smoothed with a width-η softplus so the objective is differentiable
//! (the standard smoothing used for gradient-based CVaR optimization):
//!
//!   f(w, t) = −wᵀR̄ + λ·[ t + 1/((1−α)·n) Σₛ softplus_η(ℓₛ − t) ],
//!   ℓₛ = −Rₛ·w   (portfolio loss of sample s).
//!
//! The feasible set is the product Δ_capped × [−T_BOX, T_BOX]; Frank-Wolfe
//! separates over products, so the LMO is the Task-1 analytic simplex LMO
//! on the w block plus an interval-endpoint pick on the t coordinate.  The
//! iterate is the length-(d+1) vector `x = [w, t]`, which lets the CVaR
//! task ride the Task-1 epoch machinery (`MvBackend`, `run_mv`,
//! `NativeEpochBatch`) unchanged.
//!
//! The constants below are mirrored by `python/compile/kernels/cvar.py` —
//! keep the two in sync or the native and XLA arms optimize different
//! objectives.

use crate::linalg::matrix::Mat;
use crate::linalg::vector::dot;

use super::mean_variance;

/// CVaR confidence level α (the tail has mass 1−α).
pub const ALPHA: f32 = 0.9;
/// Softplus smoothing width η.
pub const ETA: f32 = 0.05;
/// Risk-aversion weight λ on the CVaR term.
pub const LAMBDA: f32 = 1.0;
/// Box bound for the VaR coordinate: t ∈ [−T_BOX, T_BOX].
pub const T_BOX: f32 = 2.0;

/// softplus_η(x) = η·ln(1 + e^{x/η}), branch-stable in f32.
pub fn softplus_eta(x: f32) -> f32 {
    let z = x / ETA;
    if z > 0.0 {
        x + ETA * (-z).exp().ln_1p()
    } else {
        ETA * z.exp().ln_1p()
    }
}

/// σ(x/η) — the derivative of [`softplus_eta`] — branch-stable in f32.
pub fn sigmoid_eta(x: f32) -> f32 {
    let z = x / ETA;
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// 1/((1−α)·n) — the tail-average scale of the RU functional.
pub fn tail_scale(n_samples: usize) -> f32 {
    1.0 / ((1.0 - ALPHA) * n_samples as f32)
}

/// Scratch buffers reused across iterations (no allocation in the hot loop).
#[derive(Debug, Clone)]
pub struct CvScratch {
    /// Per-sample portfolio losses ℓₛ = −Rₛ·w, length n.
    pub losses: Vec<f32>,
    /// σ_η(ℓₛ − t), length n.
    pub sig: Vec<f32>,
    /// Gradient over the joint iterate, length d+1.
    pub g: Vec<f32>,
}

impl CvScratch {
    pub fn new(n_samples: usize, d: usize) -> Self {
        CvScratch {
            losses: vec![0.0; n_samples],
            sig: vec![0.0; n_samples],
            g: vec![0.0; d + 1],
        }
    }
}

/// ℓ = −R·w into `losses` (sequential row-by-row matvec, the paper's CPU
/// idiom).
pub fn losses(panel: &Mat, w: &[f32], losses: &mut [f32]) {
    panel.matvec(w, losses);
    for v in losses.iter_mut() {
        *v = -*v;
    }
}

/// ∇f(w, t) into `scratch.g` (length d+1; last entry is ∂f/∂t).
pub fn grad(panel: &Mat, rbar: &[f32], x: &[f32], scratch: &mut CvScratch) {
    let n = panel.rows;
    let d = panel.cols;
    debug_assert_eq!(x.len(), d + 1);
    let t = x[d];
    losses(panel, &x[..d], &mut scratch.losses);
    let mut sig_sum = 0.0f32;
    for s in 0..n {
        let sg = sigmoid_eta(scratch.losses[s] - t);
        scratch.sig[s] = sg;
        sig_sum += sg;
    }
    let c = tail_scale(n);
    // (Rᵀσ)_j, then  g_w = −R̄ − λ·c·(Rᵀσ)  (∂ℓₛ/∂w_j = −R_sj)
    panel.matvec_t(&scratch.sig, &mut scratch.g[..d]);
    for j in 0..d {
        scratch.g[j] = -rbar[j] - LAMBDA * c * scratch.g[j];
    }
    scratch.g[d] = LAMBDA * (1.0 - c * sig_sum);
}

/// f(w, t) = −wᵀR̄ + λ·[t + c·Σₛ softplus_η(ℓₛ − t)].
pub fn objective(panel: &Mat, rbar: &[f32], x: &[f32],
                 scratch: &mut CvScratch) -> f64 {
    let n = panel.rows;
    let d = panel.cols;
    debug_assert_eq!(x.len(), d + 1);
    let t = x[d];
    losses(panel, &x[..d], &mut scratch.losses);
    let mut tail = 0.0f64;
    for s in 0..n {
        tail += softplus_eta(scratch.losses[s] - t) as f64;
    }
    let c = 1.0 / ((1.0 - ALPHA) as f64 * n as f64);
    -(dot(&x[..d], rbar) as f64)
        + LAMBDA as f64 * (t as f64 + c * tail)
}

/// Joint LMO over Δ_capped × [−T_BOX, T_BOX]: the product set separates,
/// so the w block reuses the Task-1 analytic simplex LMO and the t
/// coordinate picks the interval endpoint minimizing g_t·t.
pub fn product_lmo(g: &[f32]) -> (Option<usize>, f32) {
    let d = g.len() - 1;
    let vertex = mean_variance::simplex_lmo(&g[..d]);
    let t_vertex = if g[d] < 0.0 { T_BOX } else { -T_BOX };
    (vertex, t_vertex)
}

/// FW update x ← x + γ(s − x) against the product vertex.
pub fn fw_product_update(x: &mut [f32], vertex: Option<usize>,
                         t_vertex: f32, gamma: f32) {
    let d = x.len() - 1;
    mean_variance::fw_vertex_update(&mut x[..d], vertex, gamma);
    x[d] += gamma * (t_vertex - x[d]);
}

/// Feasibility of the product set within `tol`.
pub fn in_product(x: &[f32], tol: f32) -> bool {
    let d = x.len() - 1;
    mean_variance::in_simplex(&x[..d], tol) && x[d].abs() <= T_BOX + tol
}

/// The coordinator's start iterate: uniform portfolio, t₀ = 0.
pub fn start_iterate(d: usize) -> Vec<f32> {
    let mut x = vec![1.0f32 / d as f32; d + 1];
    x[d] = 0.0;
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn panel(seed: u64, n: usize, d: usize) -> (Mat, Vec<f32>) {
        let mut p = Philox::new(seed);
        let m = Mat::from_vec(
            n,
            d,
            (0..n * d).map(|_| p.uniform_f32(-1.0, 1.0)).collect(),
        );
        let rbar = m.col_means();
        (m, rbar)
    }

    #[test]
    fn softplus_and_sigmoid_are_consistent() {
        // softplus_η ≥ max(x, 0), tends to the hinge, and its derivative is
        // sigmoid_eta (finite-difference check at a few scales).
        for &x in &[-1.0f32, -0.1, -0.01, 0.0, 0.01, 0.1, 1.0] {
            let sp = softplus_eta(x);
            assert!(sp >= x.max(0.0) - 1e-6, "sp({}) = {}", x, sp);
            let h = 1e-3f32;
            let fd = (softplus_eta(x + h) - softplus_eta(x - h)) / (2.0 * h);
            assert!((fd - sigmoid_eta(x)).abs() < 5e-3,
                    "sp'({}) = {} vs σ = {}", x, fd, sigmoid_eta(x));
        }
        // far tails: hinge behaviour, no overflow
        assert!((softplus_eta(5.0) - 5.0).abs() < 1e-5);
        assert!(softplus_eta(-5.0).abs() < 1e-5);
        assert!((sigmoid_eta(5.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid_eta(-5.0) < 1e-6);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (c, rbar) = panel(1, 32, 4);
        let mut x = vec![0.3f32, 0.2, 0.1, 0.15, 0.05];
        let mut scratch = CvScratch::new(32, 4);
        grad(&c, &rbar, &x, &mut scratch);
        let g = scratch.g.clone();
        let h = 1e-3f32;
        for j in 0..x.len() {
            let orig = x[j];
            x[j] = orig + h;
            let fp = objective(&c, &rbar, &x, &mut scratch);
            x[j] = orig - h;
            let fm = objective(&c, &rbar, &x, &mut scratch);
            x[j] = orig;
            let fd = ((fp - fm) / (2.0 * h as f64)) as f32;
            assert!((fd - g[j]).abs() < 3e-2,
                    "coord {}: fd {} vs grad {}", j, fd, g[j]);
        }
    }

    #[test]
    fn lmo_minimizes_over_product_set() {
        let g = [0.5f32, -1.0, 0.2, 0.7]; // d = 3 plus the t coordinate
        let (v, tv) = product_lmo(&g);
        assert_eq!(v, Some(1));
        assert_eq!(tv, -T_BOX); // g_t > 0 ⇒ lower endpoint
        let g2 = [0.5f32, 1.0, 0.2, -0.7];
        let (v2, tv2) = product_lmo(&g2);
        assert_eq!(v2, None); // all-positive w block ⇒ origin
        assert_eq!(tv2, T_BOX);
    }

    #[test]
    fn update_preserves_feasibility() {
        let mut x = start_iterate(6);
        assert!(in_product(&x, 1e-6));
        for m in 0..40 {
            let gamma = 2.0 / (m as f32 + 2.0);
            let vertex = if m % 3 == 0 { None } else { Some(m % 6) };
            let tv = if m % 2 == 0 { T_BOX } else { -T_BOX };
            fw_product_update(&mut x, vertex, tv, gamma);
            assert!(in_product(&x, 1e-5), "infeasible after step {}", m);
        }
    }

    #[test]
    fn fw_on_fixed_panel_descends() {
        let (c, rbar) = panel(4, 64, 8);
        let mut x = start_iterate(8);
        let mut scratch = CvScratch::new(64, 8);
        let first = objective(&c, &rbar, &x, &mut scratch);
        for m in 0..60 {
            grad(&c, &rbar, &x, &mut scratch);
            let (v, tv) = product_lmo(&scratch.g);
            let gamma = 2.0 / (m as f32 + 2.0);
            fw_product_update(&mut x, v, tv, gamma);
            assert!(in_product(&x, 1e-5));
        }
        let last = objective(&c, &rbar, &x, &mut scratch);
        assert!(last < first, "{} !< {}", last, first);
    }

    #[test]
    fn objective_penalizes_tail_losses() {
        // A portfolio concentrated on a high-mean asset must beat one on a
        // low-mean asset under the mean-CVaR objective.
        let n = 128;
        let d = 2;
        let mut p = Philox::new(9);
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.push(0.5 + 0.01 * p.uniform_f32(-1.0, 1.0)); // good asset
            data.push(-0.5 + 0.01 * p.uniform_f32(-1.0, 1.0)); // bad asset
        }
        let m = Mat::from_vec(n, d, data);
        let rbar = m.col_means();
        let mut scratch = CvScratch::new(n, d);
        let good = objective(&m, &rbar, &[1.0, 0.0, -0.5], &mut scratch);
        let bad = objective(&m, &rbar, &[0.0, 1.0, 0.5], &mut scratch);
        assert!(good < bad, "{} !< {}", good, bad);
    }

    #[test]
    fn t_gradient_brackets_var() {
        // ∂f/∂t = λ(1 − c·Σσ) is negative when t sits far below the losses
        // (tail mass ≫ 1−α) and positive far above — the RU optimality
        // condition pins t* at the smoothed VaR.
        let (c, rbar) = panel(7, 64, 4);
        let w = [0.25f32; 4];
        let mut scratch = CvScratch::new(64, 4);
        let mut x_lo = w.to_vec();
        x_lo.push(-1.5);
        grad(&c, &rbar, &x_lo, &mut scratch);
        assert!(scratch.g[4] < 0.0, "g_t at t=-1.5 is {}", scratch.g[4]);
        let mut x_hi = w.to_vec();
        x_hi.push(1.5);
        grad(&c, &rbar, &x_hi, &mut scratch);
        assert!(scratch.g[4] > 0.0, "g_t at t=+1.5 is {}", scratch.g[4]);
    }
}
