//! Task 1 math (paper §3.1): empirical mean-variance objective/gradient on a
//! centered sample panel, and the analytic LMO over the capped simplex.
//!
//! The gradient never materializes the d×d covariance: with the centered
//! panel C (n×d), ∇f̂(w) = Cᵀ(Cw)/(n−1) − R̄ — two matvecs, exactly the
//! decomposition the L1 Pallas kernel uses, so the native and XLA arms run
//! the same arithmetic.

use crate::linalg::blocked;
use crate::linalg::matrix::Mat;
use crate::linalg::vector::{self, dot};

/// Scratch buffers reused across iterations (no allocation in the hot loop).
#[derive(Debug, Clone)]
pub struct MvScratch {
    /// u = C w, length n.
    pub u: Vec<f32>,
    /// gradient, length d.
    pub g: Vec<f32>,
}

impl MvScratch {
    pub fn new(n_samples: usize, d: usize) -> Self {
        MvScratch { u: vec![0.0; n_samples], g: vec![0.0; d] }
    }
}

/// ∇f̂(w) = Cᵀ(Cw)/(n−1) − R̄ into `scratch.g` (sequential kernels).
pub fn grad(c: &Mat, rbar: &[f32], w: &[f32], scratch: &mut MvScratch) {
    let n = c.rows;
    c.matvec(w, &mut scratch.u);
    c.matvec_t(&scratch.u, &mut scratch.g);
    let inv = 1.0 / (n as f32 - 1.0);
    for j in 0..scratch.g.len() {
        scratch.g[j] = scratch.g[j] * inv - rbar[j];
    }
}

/// Blocked-kernel variant for the optimized-native ablation.
pub fn grad_blocked(c: &Mat, rbar: &[f32], w: &[f32], scratch: &mut MvScratch) {
    let n = c.rows;
    blocked::matvec_blocked(c, w, &mut scratch.u);
    blocked::matvec_t_blocked(c, &scratch.u, &mut scratch.g);
    let inv = 1.0 / (n as f32 - 1.0);
    for j in 0..scratch.g.len() {
        scratch.g[j] = scratch.g[j] * inv - rbar[j];
    }
}

/// f̂(w) = ½ wᵀĈw − wᵀR̄ = ½|Cw|²/(n−1) − w·R̄ (paper eq. (4)).
pub fn objective(c: &Mat, rbar: &[f32], w: &[f32], scratch: &mut MvScratch) -> f64 {
    let n = c.rows;
    c.matvec(w, &mut scratch.u);
    let quad = dot(&scratch.u, &scratch.u) as f64 / (n as f64 - 1.0);
    0.5 * quad - dot(w, rbar) as f64
}

/// Analytic LMO over W = {w ≥ 0, 1ᵀw ≤ 1} (Algorithm 1 line 8):
/// `Some(j)` for the vertex e_j (j = argmin g, if g_j < 0), `None` for the
/// origin.
pub fn simplex_lmo(g: &[f32]) -> Option<usize> {
    let j = vector::argmin(g)?;
    if g[j] < 0.0 {
        Some(j)
    } else {
        None
    }
}

/// FW update w ← w + γ(s − w) against a simplex vertex (Algorithm 1 line 10).
pub fn fw_vertex_update(w: &mut [f32], vertex: Option<usize>, gamma: f32) {
    let scale = 1.0 - gamma;
    for v in w.iter_mut() {
        *v *= scale;
    }
    if let Some(j) = vertex {
        w[j] += gamma;
    }
}

/// Feasibility of the capped simplex within `tol`.
pub fn in_simplex(w: &[f32], tol: f32) -> bool {
    w.iter().all(|&v| v >= -tol) && vector::sum(w) <= 1.0 + tol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn panel(seed: u64, n: usize, d: usize) -> (Mat, Vec<f32>) {
        let mut p = Philox::new(seed);
        let mut m = Mat::from_vec(
            n,
            d,
            (0..n * d).map(|_| p.uniform_f32(-1.0, 1.0)).collect(),
        );
        let rbar = m.col_means();
        m.center_rows(&rbar);
        (m, rbar)
    }

    #[test]
    fn grad_matches_explicit_covariance() {
        let (c, rbar) = panel(1, 16, 8);
        let w: Vec<f32> = (0..8).map(|i| 1.0 / (i + 2) as f32).collect();
        let mut scratch = MvScratch::new(16, 8);
        grad(&c, &rbar, &w, &mut scratch);
        // explicit: Σ̂ = CᵀC/(n−1); g = Σ̂w − rbar
        let ct = c.transpose();
        let cov = ct.matmul(&c); // d×d scaled by (n-1)
        let mut want = vec![0.0f32; 8];
        cov.matvec(&w, &mut want);
        for j in 0..8 {
            want[j] = want[j] / 15.0 - rbar[j];
            assert!((scratch.g[j] - want[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn blocked_grad_matches_sequential() {
        let (c, rbar) = panel(2, 33, 17);
        let w: Vec<f32> = (0..17).map(|i| (i as f32 * 0.3).sin().abs() / 17.0).collect();
        let mut s1 = MvScratch::new(33, 17);
        let mut s2 = MvScratch::new(33, 17);
        grad(&c, &rbar, &w, &mut s1);
        grad_blocked(&c, &rbar, &w, &mut s2);
        for (a, b) in s1.g.iter().zip(&s2.g) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn objective_is_half_quadratic_minus_linear() {
        let (c, rbar) = panel(3, 8, 4);
        let w = vec![0.25f32; 4];
        let mut scratch = MvScratch::new(8, 4);
        let obj = objective(&c, &rbar, &w, &mut scratch);
        // brute force
        let mut quad = 0.0f64;
        for i in 0..8 {
            let u: f32 = c.row(i).iter().zip(&w).map(|(a, b)| a * b).sum();
            quad += (u as f64) * (u as f64);
        }
        let want = 0.5 * quad / 7.0
            - w.iter().zip(&rbar).map(|(a, b)| (a * b) as f64).sum::<f64>();
        assert!((obj - want).abs() < 1e-6);
    }

    #[test]
    fn lmo_picks_most_negative() {
        assert_eq!(simplex_lmo(&[0.5, -1.0, -2.0, 0.1]), Some(2));
        assert_eq!(simplex_lmo(&[0.5, 1.0]), None);
        assert_eq!(simplex_lmo(&[]), None);
    }

    #[test]
    fn vertex_update_preserves_simplex() {
        let mut w = vec![0.2f32, 0.3, 0.1];
        fw_vertex_update(&mut w, Some(0), 0.5);
        assert!(in_simplex(&w, 1e-6));
        assert!((w[0] - 0.6).abs() < 1e-6);
        fw_vertex_update(&mut w, None, 0.5);
        assert!(in_simplex(&w, 1e-6));
        // sum was 0.8 after the vertex step; origin step halves it
        assert!((crate::linalg::vector::sum(&w) - 0.4).abs() < 1e-6);
    }

    #[test]
    fn fw_on_fixed_panel_descends() {
        let (c, rbar) = panel(4, 64, 12);
        let mut w = vec![1.0f32 / 12.0; 12];
        let mut scratch = MvScratch::new(64, 12);
        let first = objective(&c, &rbar, &w, &mut scratch);
        for m in 0..50 {
            grad(&c, &rbar, &w, &mut scratch);
            let s = simplex_lmo(&scratch.g);
            let gamma = 2.0 / (m as f32 + 2.0);
            fw_vertex_update(&mut w, s, gamma);
            assert!(in_simplex(&w, 1e-5));
        }
        let last = objective(&c, &rbar, &w, &mut scratch);
        assert!(last < first, "{} !< {}", last, first);
    }
}
