//! Problem definitions and their backend-shared math, plus the task
//! registry that turns the scenario count from a constant into a lookup
//! (DESIGN.md §12).
//!
//! Everything a backend needs that is *not* execution-model specific lives
//! here: objective/gradient math on a sample panel, the analytic simplex
//! LMO, the LP-backed newsvendor LMO, the SQN correction memory, and the
//! smoothed mean-CVaR functional.  [`registry`] binds each task's
//! spec-validation, backend factories, drivers, and artifact requirements
//! behind one [`registry::SimTask`] trait so the coordinator stays
//! task-generic.

pub mod classification;
pub mod cvar;
pub mod mean_variance;
pub mod newsvendor;
pub mod registry;

pub use classification::{BatchCorrectionMemory, BatchMemView,
                         CorrectionMemory, MemView};
pub use newsvendor::NvLmo;
pub use registry::SimTask;
