//! The paper's three problem definitions and their backend-shared math.
//!
//! Everything a backend needs that is *not* execution-model specific lives
//! here: objective/gradient math on a sample panel, the analytic simplex
//! LMO, the LP-backed newsvendor LMO, and the SQN correction memory.

pub mod classification;
pub mod mean_variance;
pub mod newsvendor;

pub use classification::{BatchCorrectionMemory, CorrectionMemory, MemView};
pub use newsvendor::NvLmo;
