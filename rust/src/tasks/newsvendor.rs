//! Task 2 math (paper §3.2): Monte-Carlo gradient/objective on a demand
//! panel, and the LP-backed LMO over {Ax ≤ C, x ≥ 0} (Algorithm 2 line 8).

use crate::lp::{self, LpStatus, PanelWorkspace};
use crate::sim::NewsvendorInstance;
use crate::util::pool;

/// MC gradient (paper eq. (9)) — sequential, one product at a time, one
/// sample at a time (the paper's description of CPU execution):
/// f̂ⱼ′ = kⱼ − vⱼ + (hⱼ+vⱼ)·(1/S)Σₛ 1{dₛⱼ ≤ xⱼ}.
pub fn grad(inst: &NewsvendorInstance, panel: &[f32], s_samples: usize,
            x: &[f32], g: &mut [f32]) {
    let d = inst.dim();
    debug_assert_eq!(panel.len(), s_samples * d);
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(g.len(), d);
    for j in 0..d {
        let mut count = 0u32;
        for s in 0..s_samples {
            if panel[s * d + j] <= x[j] {
                count += 1;
            }
        }
        let cdf = count as f32 / s_samples as f32;
        g[j] = inst.k[j] - inst.v[j] + (inst.h[j] + inst.v[j]) * cdf;
    }
}

/// Sample-average cost (paper eq. (6)):
/// Σⱼ kⱼxⱼ + (1/S)Σₛ [hⱼ max(xⱼ−dₛⱼ,0) + vⱼ max(dₛⱼ−xⱼ,0)].
pub fn objective(inst: &NewsvendorInstance, panel: &[f32], s_samples: usize,
                 x: &[f32]) -> f64 {
    let d = inst.dim();
    let mut total = 0.0f64;
    for j in 0..d {
        let mut over = 0.0f64;
        let mut under = 0.0f64;
        for s in 0..s_samples {
            let diff = (x[j] - panel[s * d + j]) as f64;
            if diff > 0.0 {
                over += diff;
            } else {
                under -= diff;
            }
        }
        let inv = 1.0 / s_samples as f64;
        total += inst.k[j] as f64 * x[j] as f64
            + inst.h[j] as f64 * over * inv
            + inst.v[j] as f64 * under * inv;
    }
    total
}

/// The Frank-Wolfe linear subproblem min_{s∈X} sᵀg over
/// X = {x : Ax ≤ cap, x ≥ 0}, solved by the two-phase simplex with
/// **delayed column generation** (§Perf L3-2).
///
/// The optimum is a vertex with at most m (= #resources ≪ n) nonzero
/// coordinates, so a small restricted LP over the most promising columns
/// almost always contains it.  Candidate columns are priced against the
/// restricted optimum's duals — r_j = g_j + Σᵢ σᵢ aᵢⱼ with σ ≥ 0 — and
/// only violating columns (r_j < 0) are pulled in.  Columns with g_j ≥ 0
/// can never price negative (A > 0) and are pruned outright.
pub struct NvLmo {
    a: Vec<f64>,
    cap: Vec<f64>,
    m: usize,
    n: usize,
    /// Number of LMO calls (dispatch-cost reporting).
    pub solves: usize,
    /// Column-generation rounds across all calls (≈ solves ⇒ the restricted
    /// pool almost always suffices on the first try).
    pub rounds: usize,
    /// Set true to bypass column generation (used by tests/benches to
    /// compare against the full dense solve).
    pub full_solve: bool,
    // Arenas (DESIGN.md §16): every per-call intermediate is re-initialized
    // from scratch each solve, so a reused LMO is bitwise-identical to a
    // fresh one; after the first call of a given shape, none of them
    // touches the heap again.
    neg: Vec<usize>,
    active: Vec<usize>,
    in_active: Vec<bool>,
    violators: Vec<(usize, f64)>,
    a_sub: Vec<f64>,
    c_sub: Vec<f64>,
    ws: lp::Workspace,
}

impl NvLmo {
    pub fn new(inst: &NewsvendorInstance) -> Self {
        let m = inst.resources();
        let n = inst.dim();
        let a = inst.a.data.iter().map(|&v| v as f64).collect();
        let cap = inst.cap.iter().map(|&v| v as f64).collect();
        NvLmo {
            a,
            cap,
            m,
            n,
            solves: 0,
            rounds: 0,
            full_solve: false,
            neg: Vec::new(),
            active: Vec::new(),
            in_active: Vec::new(),
            violators: Vec::new(),
            a_sub: Vec::new(),
            c_sub: Vec::new(),
            ws: lp::Workspace::default(),
        }
    }

    /// Solve the LMO for gradient `g`, returning the optimal vertex.
    pub fn solve(&mut self, g: &[f32]) -> anyhow::Result<Vec<f32>> {
        let mut x = vec![0.0f32; self.n];
        self.solve_into(g, &mut x)?;
        Ok(x)
    }

    /// Arena variant of [`NvLmo::solve`]: the optimal vertex is written
    /// into `x`, and every intermediate (candidate pool, restricted LP,
    /// pricing pass) lives in the LMO's own scratch.
    pub fn solve_into(&mut self, g: &[f32], x: &mut [f32])
        -> anyhow::Result<()> {
        self.solve_row_with(g, x, None)
    }

    /// Panel entry point (DESIGN.md §17): solve all R LMOs of one step
    /// together — `lmos[i]` takes gradient row i of the `[R × d]` panel
    /// `g` and writes vertex row i of `verts`.  Every `lmos[i]` must be
    /// built from the SAME instance: the shared `(A, cap)` seed is
    /// factored once into `seed` (and reused warm across steps via
    /// [`PanelWorkspace::ensure_seed`]), and dense/full solves run phase 2
    /// from it.  Rows fan out over `threads` pool workers with disjoint
    /// `&mut` LMO/vertex chunks (`pool::chunk_len` boundaries); one chunk
    /// at `threads == 1` runs inline and allocation-free at steady state.
    /// Per-row results are bitwise-identical to [`NvLmo::solve_into`]
    /// (pinned by `tests/batch_determinism.rs`).
    pub fn solve_panel_into(lmos: &mut [NvLmo], seed: &mut PanelWorkspace,
                            g: &[f32], verts: &mut [f32], threads: usize)
        -> anyhow::Result<()> {
        let r = lmos.len();
        if r == 0 {
            return Ok(());
        }
        let d = lmos[0].n;
        let m = lmos[0].m;
        anyhow::ensure!(lmos.iter().all(|l| l.n == d && l.m == m),
                        "panel LMOs must share one instance shape");
        anyhow::ensure!(g.len() == r * d, "gradient panel must be R×d");
        anyhow::ensure!(verts.len() == r * d, "vertex panel must be R×d");
        seed.ensure_seed(&lmos[0].a, &lmos[0].cap, m, d);
        let seed = &*seed;
        let chunk = pool::chunk_len(r, threads);
        let jobs = lmos
            .chunks_mut(chunk)
            .zip(g.chunks(chunk * d))
            .zip(verts.chunks_mut(chunk * d))
            .map(|((lmo_chunk, g_chunk), v_chunk)| {
                move || {
                    for ((lmo, gi), vi) in lmo_chunk
                        .iter_mut()
                        .zip(g_chunk.chunks(d))
                        .zip(v_chunk.chunks_mut(d))
                    {
                        lmo.solve_row_with(gi, vi, Some(seed))?;
                    }
                    Ok(())
                }
            });
        pool::parallel_try_jobs(jobs)
    }

    /// One row of the panel solve — [`NvLmo::solve_into`] with an
    /// optional shared-A seed for the dense/full path.  The column
    /// generation itself is unchanged (its restricted subproblems have
    /// per-row column sets, so they keep the plain arena solver), which
    /// is what keeps panel and sequential rows bitwise-equal.
    fn solve_row_with(&mut self, g: &[f32], x: &mut [f32],
                      seed: Option<&PanelWorkspace>) -> anyhow::Result<()> {
        assert_eq!(g.len(), self.n);
        assert_eq!(x.len(), self.n);
        self.solves += 1;
        if self.full_solve {
            return self.solve_full_with(g, x, seed);
        }

        // candidate pool: negative-gradient columns, most negative first
        self.neg.clear();
        self.neg.extend((0..self.n).filter(|&j| g[j] < 0.0));
        if self.neg.is_empty() {
            x.fill(0.0); // origin is optimal
            return Ok(());
        }
        let pool = (8 * self.m).max(64).min(self.neg.len());
        if pool < self.neg.len() {
            // partial selection: only the pool prefix needs ordering
            self.neg.select_nth_unstable_by(pool - 1, |&i, &j| {
                g[i].partial_cmp(&g[j]).unwrap()
            });
        }
        self.active.clear();
        self.active.extend_from_slice(&self.neg[..pool]);
        self.in_active.clear();
        self.in_active.resize(self.n, false);
        for &j in &self.active {
            self.in_active[j] = true;
        }

        const MAX_ROUNDS: usize = 12;
        for _ in 0..MAX_ROUNDS {
            self.rounds += 1;
            // restricted LP over the active columns (inlined so every
            // buffer is an arena field)
            let k = self.active.len();
            self.a_sub.clear();
            self.a_sub.resize(self.m * k, 0.0);
            for i in 0..self.m {
                for (pos, &j) in self.active.iter().enumerate() {
                    self.a_sub[i * k + pos] = self.a[i * self.n + j];
                }
            }
            self.c_sub.clear();
            self.c_sub.extend(self.active.iter().map(|&j| g[j] as f64));
            match lp::solve_into(&self.c_sub, &self.a_sub, &self.cap,
                                 self.m, k, &mut self.ws) {
                LpStatus::Optimal { .. } => {}
                LpStatus::Unbounded => anyhow::bail!(
                    "newsvendor LMO unbounded — technology matrix must be \
                     positive"
                ),
                LpStatus::Infeasible => anyhow::bail!(
                    "newsvendor LMO infeasible — capacities must be \
                     nonnegative"
                ),
            }
            // price the remaining candidates against the duals
            self.violators.clear();
            for &j in &self.neg {
                if self.in_active[j] {
                    continue;
                }
                let mut r = g[j] as f64;
                for i in 0..self.m {
                    r += self.ws.duals[i] * self.a[i * self.n + j];
                }
                if r < -1e-7 {
                    self.violators.push((j, r));
                }
            }
            if self.violators.is_empty() {
                // restricted optimum is globally optimal
                x.fill(0.0);
                for (pos, &j) in self.active.iter().enumerate() {
                    x[j] = self.ws.x[pos] as f32;
                }
                return Ok(());
            }
            // unstable sort: in-place (a stable sort allocates its merge
            // buffer); deterministic for any fixed input either way
            self.violators
                .sort_unstable_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let take = (4 * self.m).max(16).min(self.violators.len());
            for pos in 0..take {
                let j = self.violators[pos].0;
                self.active.push(j);
                self.in_active[j] = true;
            }
        }
        // pathological instance: fall back to the dense solve
        self.solve_full_with(g, x, seed)
    }

    /// Dense full-column solve (reference path / fallback).
    pub fn solve_full(&mut self, g: &[f32]) -> anyhow::Result<Vec<f32>> {
        let mut x = vec![0.0f32; self.n];
        self.solve_full_into(g, &mut x)?;
        Ok(x)
    }

    fn solve_full_into(&mut self, g: &[f32], x: &mut [f32])
        -> anyhow::Result<()> {
        self.solve_full_with(g, x, None)
    }

    /// Dense solve over the full shared `A` — the one LP in the LMO whose
    /// constraint system is exactly the shared `(A, cap)`, so the panel
    /// path runs it as phase 2 from the cached seed (bitwise-equal to the
    /// from-scratch solve by the `lp::panel` contract).
    fn solve_full_with(&mut self, g: &[f32], x: &mut [f32],
                       seed: Option<&PanelWorkspace>) -> anyhow::Result<()> {
        self.c_sub.clear();
        self.c_sub.extend(g.iter().map(|&v| v as f64));
        let status = match seed {
            Some(s) => s.solve_row(&self.c_sub, &mut self.ws),
            None => lp::solve_into(&self.c_sub, &self.a, &self.cap,
                                   self.m, self.n, &mut self.ws),
        };
        match status {
            LpStatus::Optimal { .. } => {
                for (slot, &v) in x.iter_mut().zip(&self.ws.x) {
                    *slot = v as f32;
                }
                Ok(())
            }
            LpStatus::Unbounded => anyhow::bail!(
                "newsvendor LMO unbounded — technology matrix must be positive"
            ),
            LpStatus::Infeasible => anyhow::bail!(
                "newsvendor LMO infeasible — capacities must be nonnegative"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StreamTree;

    fn inst(d: usize) -> NewsvendorInstance {
        NewsvendorInstance::generate(&StreamTree::new(42), d, 3, 0.6)
    }

    fn panel_for(inst: &NewsvendorInstance, s: usize, seed: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; s * inst.dim()];
        let mut sampler = StreamTree::new(seed).normal(&[1]);
        inst.sample_panel(&mut sampler, s, &mut out);
        out
    }

    #[test]
    fn grad_bracketed_by_cost_structure() {
        let inst = inst(16);
        let panel = panel_for(&inst, 32, 7);
        let x = inst.unconstrained_optimum();
        let mut g = vec![0.0f32; 16];
        grad(&inst, &panel, 32, &x, &mut g);
        for j in 0..16 {
            assert!(g[j] >= inst.k[j] - inst.v[j] - 1e-5);
            assert!(g[j] <= inst.k[j] + inst.h[j] + 1e-5);
        }
    }

    #[test]
    fn grad_monotone_in_stock_level() {
        // The CDF estimate is nondecreasing in x, hence so is the gradient.
        let inst = inst(8);
        let panel = panel_for(&inst, 64, 3);
        let lo = vec![0.0f32; 8];
        let hi = vec![100.0f32; 8];
        let mut g_lo = vec![0.0f32; 8];
        let mut g_hi = vec![0.0f32; 8];
        grad(&inst, &panel, 64, &lo, &mut g_lo);
        grad(&inst, &panel, 64, &hi, &mut g_hi);
        for j in 0..8 {
            assert!(g_lo[j] <= g_hi[j] + 1e-6);
        }
    }

    #[test]
    fn objective_convex_along_segment() {
        let inst = inst(8);
        let panel = panel_for(&inst, 64, 9);
        let a = vec![10.0f32; 8];
        let b = vec![60.0f32; 8];
        let mid: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 0.5 * (x + y)).collect();
        let fa = objective(&inst, &panel, 64, &a);
        let fb = objective(&inst, &panel, 64, &b);
        let fm = objective(&inst, &panel, 64, &mid);
        assert!(fm <= 0.5 * (fa + fb) + 1e-6);
    }

    #[test]
    fn lmo_vertex_feasible_and_optimal_vs_samples() {
        let inst = inst(12);
        let mut lmo = NvLmo::new(&inst);
        let panel = panel_for(&inst, 16, 5);
        let x = inst.feasible_start();
        let mut g = vec![0.0f32; 12];
        grad(&inst, &panel, 16, &x, &mut g);
        let s = lmo.solve(&g).unwrap();
        assert!(inst.is_feasible(&s, 1e-4));
        // LMO value must beat the current point and the origin
        let val_s: f64 = s.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum();
        let val_x: f64 = x.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum();
        assert!(val_s <= val_x + 1e-6);
        assert!(val_s <= 1e-6); // origin is feasible with value 0
        assert_eq!(lmo.solves, 1);
    }

    #[test]
    fn column_generation_matches_full_solve() {
        // The delayed-column-generation LMO must return an LP optimum:
        // same objective value as the dense solve on random gradients.
        let inst = NewsvendorInstance::generate(&StreamTree::new(9), 200, 5, 0.6);
        let mut lmo = NvLmo::new(&inst);
        let mut rng = crate::rng::Philox::new(77);
        for case in 0..25 {
            let g: Vec<f32> = (0..200)
                .map(|_| rng.uniform_f32(-3.0, 2.0))
                .collect();
            let s_cg = lmo.solve(&g).unwrap();
            let s_full = lmo.solve_full(&g).unwrap();
            let val = |s: &[f32]| -> f64 {
                s.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum()
            };
            assert!(inst.is_feasible(&s_cg, 1e-3), "case {}", case);
            assert!(
                (val(&s_cg) - val(&s_full)).abs()
                    < 1e-4 * (1.0 + val(&s_full).abs()),
                "case {}: cg {} vs full {}",
                case,
                val(&s_cg),
                val(&s_full)
            );
        }
        // pool almost always suffices in one round
        assert!(lmo.rounds <= lmo.solves * 3, "rounds {} solves {}",
                lmo.rounds, lmo.solves);
    }

    #[test]
    fn solve_into_reuse_is_bitwise_fresh_solve() {
        // One arena-backed LMO driven across many gradients must match a
        // fresh LMO per gradient bit-for-bit.
        let inst = NewsvendorInstance::generate(&StreamTree::new(9), 64, 4, 0.6);
        let mut reused = NvLmo::new(&inst);
        let mut rng = crate::rng::Philox::new(31);
        let mut x = vec![0.0f32; 64];
        for case in 0..10 {
            let g: Vec<f32> =
                (0..64).map(|_| rng.uniform_f32(-3.0, 2.0)).collect();
            let want = NvLmo::new(&inst).solve(&g).unwrap();
            reused.solve_into(&g, &mut x).unwrap();
            for (a, b) in want.iter().zip(&x) {
                assert_eq!(a.to_bits(), b.to_bits(), "case {}", case);
            }
        }
    }

    #[test]
    fn lmo_all_positive_gradient_returns_origin() {
        let inst = inst(6);
        let mut lmo = NvLmo::new(&inst);
        let g = vec![1.0f32; 6];
        let s = lmo.solve(&g).unwrap();
        assert!(s.iter().all(|&v| v.abs() < 1e-8));
    }

    #[test]
    fn panel_solve_is_bitwise_sequential_rows() {
        // solve_panel_into == per-row solve_into bit-for-bit, for every
        // thread count (uneven chunks included) and on both the CG and
        // dense/full paths.
        let d = 40;
        let inst = NewsvendorInstance::generate(&StreamTree::new(17), d, 3,
                                                0.6);
        let mut rng = crate::rng::Philox::new(53);
        for full in [false, true] {
            let r = 5usize;
            let g: Vec<f32> = (0..r * d)
                .map(|_| rng.uniform_f32(-3.0, 2.0))
                .collect();
            // reference: independent sequential rows
            let mut want = vec![0.0f32; r * d];
            for i in 0..r {
                let mut lmo = NvLmo::new(&inst);
                lmo.full_solve = full;
                lmo.solve_into(&g[i * d..(i + 1) * d],
                               &mut want[i * d..(i + 1) * d])
                    .unwrap();
            }
            for threads in 1..=4 {
                let mut lmos: Vec<NvLmo> = (0..r)
                    .map(|_| {
                        let mut l = NvLmo::new(&inst);
                        l.full_solve = full;
                        l
                    })
                    .collect();
                let mut seed = PanelWorkspace::new();
                let mut got = vec![0.0f32; r * d];
                // two passes through the SAME warm seed + arenas: the
                // second must still match a fresh sequential solve
                for pass in 0..2 {
                    NvLmo::solve_panel_into(&mut lmos, &mut seed, &g,
                                            &mut got, threads)
                        .unwrap();
                    for (pos, (a, b)) in
                        want.iter().zip(&got).enumerate()
                    {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "full={} threads={} pass={} pos={}",
                                   full, threads, pass, pos);
                    }
                }
                assert!(seed.is_ready());
            }
        }
    }

    #[test]
    fn panel_solve_rejects_mismatched_shapes() {
        let a = inst(8);
        let b = NewsvendorInstance::generate(&StreamTree::new(5), 10, 3, 0.6);
        let mut lmos = vec![NvLmo::new(&a), NvLmo::new(&b)];
        let mut seed = PanelWorkspace::new();
        let g = vec![0.0f32; 18];
        let mut v = vec![0.0f32; 18];
        assert!(NvLmo::solve_panel_into(&mut lmos, &mut seed, &g, &mut v, 1)
            .is_err());
    }
}
