//! Task 2 math (paper §3.2): Monte-Carlo gradient/objective on a demand
//! panel, and the LP-backed LMO over {Ax ≤ C, x ≥ 0} (Algorithm 2 line 8).

use crate::lp::{self, LpProblem, LpResult};
use crate::sim::NewsvendorInstance;

/// MC gradient (paper eq. (9)) — sequential, one product at a time, one
/// sample at a time (the paper's description of CPU execution):
/// f̂ⱼ′ = kⱼ − vⱼ + (hⱼ+vⱼ)·(1/S)Σₛ 1{dₛⱼ ≤ xⱼ}.
pub fn grad(inst: &NewsvendorInstance, panel: &[f32], s_samples: usize,
            x: &[f32], g: &mut [f32]) {
    let d = inst.dim();
    debug_assert_eq!(panel.len(), s_samples * d);
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(g.len(), d);
    for j in 0..d {
        let mut count = 0u32;
        for s in 0..s_samples {
            if panel[s * d + j] <= x[j] {
                count += 1;
            }
        }
        let cdf = count as f32 / s_samples as f32;
        g[j] = inst.k[j] - inst.v[j] + (inst.h[j] + inst.v[j]) * cdf;
    }
}

/// Sample-average cost (paper eq. (6)):
/// Σⱼ kⱼxⱼ + (1/S)Σₛ [hⱼ max(xⱼ−dₛⱼ,0) + vⱼ max(dₛⱼ−xⱼ,0)].
pub fn objective(inst: &NewsvendorInstance, panel: &[f32], s_samples: usize,
                 x: &[f32]) -> f64 {
    let d = inst.dim();
    let mut total = 0.0f64;
    for j in 0..d {
        let mut over = 0.0f64;
        let mut under = 0.0f64;
        for s in 0..s_samples {
            let diff = (x[j] - panel[s * d + j]) as f64;
            if diff > 0.0 {
                over += diff;
            } else {
                under -= diff;
            }
        }
        let inv = 1.0 / s_samples as f64;
        total += inst.k[j] as f64 * x[j] as f64
            + inst.h[j] as f64 * over * inv
            + inst.v[j] as f64 * under * inv;
    }
    total
}

/// The Frank-Wolfe linear subproblem min_{s∈X} sᵀg over
/// X = {x : Ax ≤ cap, x ≥ 0}, solved by the two-phase simplex with
/// **delayed column generation** (§Perf L3-2).
///
/// The optimum is a vertex with at most m (= #resources ≪ n) nonzero
/// coordinates, so a small restricted LP over the most promising columns
/// almost always contains it.  Candidate columns are priced against the
/// restricted optimum's duals — r_j = g_j + Σᵢ σᵢ aᵢⱼ with σ ≥ 0 — and
/// only violating columns (r_j < 0) are pulled in.  Columns with g_j ≥ 0
/// can never price negative (A > 0) and are pruned outright.
pub struct NvLmo {
    a: Vec<f64>,
    cap: Vec<f64>,
    m: usize,
    n: usize,
    /// Number of LMO calls (dispatch-cost reporting).
    pub solves: usize,
    /// Column-generation rounds across all calls (≈ solves ⇒ the restricted
    /// pool almost always suffices on the first try).
    pub rounds: usize,
    /// Set true to bypass column generation (used by tests/benches to
    /// compare against the full dense solve).
    pub full_solve: bool,
}

impl NvLmo {
    pub fn new(inst: &NewsvendorInstance) -> Self {
        let m = inst.resources();
        let n = inst.dim();
        let a = inst.a.data.iter().map(|&v| v as f64).collect();
        let cap = inst.cap.iter().map(|&v| v as f64).collect();
        NvLmo { a, cap, m, n, solves: 0, rounds: 0, full_solve: false }
    }

    /// Solve the LMO for gradient `g`, returning the optimal vertex.
    pub fn solve(&mut self, g: &[f32]) -> anyhow::Result<Vec<f32>> {
        assert_eq!(g.len(), self.n);
        self.solves += 1;
        if self.full_solve {
            return self.solve_full(g);
        }

        // candidate pool: negative-gradient columns, most negative first
        let mut neg: Vec<usize> = (0..self.n).filter(|&j| g[j] < 0.0).collect();
        if neg.is_empty() {
            return Ok(vec![0.0; self.n]); // origin is optimal
        }
        let pool = (8 * self.m).max(64).min(neg.len());
        if pool < neg.len() {
            // partial selection: only the pool prefix needs ordering
            neg.select_nth_unstable_by(pool - 1, |&i, &j| {
                g[i].partial_cmp(&g[j]).unwrap()
            });
        }
        let mut active: Vec<usize> = neg[..pool].to_vec();
        let mut in_active = vec![false; self.n];
        for &j in &active {
            in_active[j] = true;
        }

        const MAX_ROUNDS: usize = 12;
        for _ in 0..MAX_ROUNDS {
            self.rounds += 1;
            let (x_sub, duals) = self.solve_restricted(g, &active)?;
            // price the remaining candidates against the duals
            let mut violators: Vec<(usize, f64)> = Vec::new();
            for &j in &neg {
                if in_active[j] {
                    continue;
                }
                let mut r = g[j] as f64;
                for i in 0..self.m {
                    r += duals[i] * self.a[i * self.n + j];
                }
                if r < -1e-7 {
                    violators.push((j, r));
                }
            }
            if violators.is_empty() {
                // restricted optimum is globally optimal
                let mut x = vec![0.0f32; self.n];
                for (pos, &j) in active.iter().enumerate() {
                    x[j] = x_sub[pos] as f32;
                }
                return Ok(x);
            }
            violators.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            for (j, _) in violators.into_iter().take((4 * self.m).max(16)) {
                active.push(j);
                in_active[j] = true;
            }
        }
        // pathological instance: fall back to the dense solve
        self.solve_full(g)
    }

    fn solve_restricted(&self, g: &[f32], cols: &[usize])
        -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        let k = cols.len();
        let mut a_sub = vec![0.0f64; self.m * k];
        for i in 0..self.m {
            for (pos, &j) in cols.iter().enumerate() {
                a_sub[i * k + pos] = self.a[i * self.n + j];
            }
        }
        let c_sub: Vec<f64> = cols.iter().map(|&j| g[j] as f64).collect();
        let p = LpProblem::new(c_sub, a_sub, self.cap.clone());
        match lp::solve(&p) {
            LpResult::Optimal { x, duals, .. } => Ok((x, duals)),
            LpResult::Unbounded => anyhow::bail!(
                "newsvendor LMO unbounded — technology matrix must be positive"
            ),
            LpResult::Infeasible => anyhow::bail!(
                "newsvendor LMO infeasible — capacities must be nonnegative"
            ),
        }
    }

    /// Dense full-column solve (reference path / fallback).
    pub fn solve_full(&mut self, g: &[f32]) -> anyhow::Result<Vec<f32>> {
        let c: Vec<f64> = g.iter().map(|&v| v as f64).collect();
        let p = LpProblem::new(c, self.a.clone(), self.cap.clone());
        match lp::solve(&p) {
            LpResult::Optimal { x, .. } => {
                Ok(x.into_iter().map(|v| v as f32).collect())
            }
            LpResult::Unbounded => anyhow::bail!(
                "newsvendor LMO unbounded — technology matrix must be positive"
            ),
            LpResult::Infeasible => anyhow::bail!(
                "newsvendor LMO infeasible — capacities must be nonnegative"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StreamTree;

    fn inst(d: usize) -> NewsvendorInstance {
        NewsvendorInstance::generate(&StreamTree::new(42), d, 3, 0.6)
    }

    fn panel_for(inst: &NewsvendorInstance, s: usize, seed: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; s * inst.dim()];
        let mut sampler = StreamTree::new(seed).normal(&[1]);
        inst.sample_panel(&mut sampler, s, &mut out);
        out
    }

    #[test]
    fn grad_bracketed_by_cost_structure() {
        let inst = inst(16);
        let panel = panel_for(&inst, 32, 7);
        let x = inst.unconstrained_optimum();
        let mut g = vec![0.0f32; 16];
        grad(&inst, &panel, 32, &x, &mut g);
        for j in 0..16 {
            assert!(g[j] >= inst.k[j] - inst.v[j] - 1e-5);
            assert!(g[j] <= inst.k[j] + inst.h[j] + 1e-5);
        }
    }

    #[test]
    fn grad_monotone_in_stock_level() {
        // The CDF estimate is nondecreasing in x, hence so is the gradient.
        let inst = inst(8);
        let panel = panel_for(&inst, 64, 3);
        let lo = vec![0.0f32; 8];
        let hi = vec![100.0f32; 8];
        let mut g_lo = vec![0.0f32; 8];
        let mut g_hi = vec![0.0f32; 8];
        grad(&inst, &panel, 64, &lo, &mut g_lo);
        grad(&inst, &panel, 64, &hi, &mut g_hi);
        for j in 0..8 {
            assert!(g_lo[j] <= g_hi[j] + 1e-6);
        }
    }

    #[test]
    fn objective_convex_along_segment() {
        let inst = inst(8);
        let panel = panel_for(&inst, 64, 9);
        let a = vec![10.0f32; 8];
        let b = vec![60.0f32; 8];
        let mid: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 0.5 * (x + y)).collect();
        let fa = objective(&inst, &panel, 64, &a);
        let fb = objective(&inst, &panel, 64, &b);
        let fm = objective(&inst, &panel, 64, &mid);
        assert!(fm <= 0.5 * (fa + fb) + 1e-6);
    }

    #[test]
    fn lmo_vertex_feasible_and_optimal_vs_samples() {
        let inst = inst(12);
        let mut lmo = NvLmo::new(&inst);
        let panel = panel_for(&inst, 16, 5);
        let x = inst.feasible_start();
        let mut g = vec![0.0f32; 12];
        grad(&inst, &panel, 16, &x, &mut g);
        let s = lmo.solve(&g).unwrap();
        assert!(inst.is_feasible(&s, 1e-4));
        // LMO value must beat the current point and the origin
        let val_s: f64 = s.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum();
        let val_x: f64 = x.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum();
        assert!(val_s <= val_x + 1e-6);
        assert!(val_s <= 1e-6); // origin is feasible with value 0
        assert_eq!(lmo.solves, 1);
    }

    #[test]
    fn column_generation_matches_full_solve() {
        // The delayed-column-generation LMO must return an LP optimum:
        // same objective value as the dense solve on random gradients.
        let inst = NewsvendorInstance::generate(&StreamTree::new(9), 200, 5, 0.6);
        let mut lmo = NvLmo::new(&inst);
        let mut rng = crate::rng::Philox::new(77);
        for case in 0..25 {
            let g: Vec<f32> = (0..200)
                .map(|_| rng.uniform_f32(-3.0, 2.0))
                .collect();
            let s_cg = lmo.solve(&g).unwrap();
            let s_full = lmo.solve_full(&g).unwrap();
            let val = |s: &[f32]| -> f64 {
                s.iter().zip(&g).map(|(a, b)| (a * b) as f64).sum()
            };
            assert!(inst.is_feasible(&s_cg, 1e-3), "case {}", case);
            assert!(
                (val(&s_cg) - val(&s_full)).abs()
                    < 1e-4 * (1.0 + val(&s_full).abs()),
                "case {}: cg {} vs full {}",
                case,
                val(&s_cg),
                val(&s_full)
            );
        }
        // pool almost always suffices in one round
        assert!(lmo.rounds <= lmo.solves * 3, "rounds {} solves {}",
                lmo.rounds, lmo.solves);
    }

    #[test]
    fn lmo_all_positive_gradient_returns_origin() {
        let inst = inst(6);
        let mut lmo = NvLmo::new(&inst);
        let g = vec![1.0f32; 6];
        let s = lmo.solve(&g).unwrap();
        assert!(s.iter().all(|&v| v.abs() < 1e-8));
    }
}
