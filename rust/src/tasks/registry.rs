//! The task-registry execution plane (DESIGN.md §12).
//!
//! Each scenario registers ONE [`SimTask`] implementation binding
//! everything that used to be scattered per-task across the stack: CLI
//! names, default sizes/parameters, spec validation, backend construction
//! for native-seq / native-par / XLA on both execution plans, the
//! sequential and batched replication drivers, and the XLA artifact
//! requirements.  The coordinator, CLI, and artifact preflight are
//! registry lookups — adding a scenario is a leaf-level registration in
//! [`TASKS`], not six-layer surgery.
//!
//! The paper's CPU-vs-GPU comparison is an *axis*, not a property of its
//! three example tasks (Zhou, Lange & Suchard 2010 make the same point
//! for problem families once the problem-specific kernel is separated
//! from the generic iteration harness); the fourth registered scenario —
//! the smoothed mean-CVaR portfolio — exists to keep that separation
//! honest: it passes the same registry-conformance suite as the original
//! three without any suite changes.

use std::sync::Mutex;

use anyhow::Result;

use crate::backend::native::{
    NativeCvar, NativeCvarBatch, NativeLr, NativeLrBatch, NativeMode,
    NativeMv, NativeMvBatch, NativeNv, NativeNvBatch,
};
use crate::backend::plane::{self, ShardedBatch};
use crate::backend::xla::{
    XlaCvar, XlaCvarBatch, XlaLr, XlaLrBatch, XlaMv, XlaMvBatch, XlaNv,
    XlaNvBatch,
};
use crate::backend::{LrBackend, MvBackend, NvBackend};
use crate::config::{BackendKind, TaskKind, TaskParams};
use crate::coordinator::{rep_subtrees, Coordinator, ExperimentSpec,
                         RepRecord};
use crate::opt::{frank_wolfe, sqn, PanelCtl, ProgressSink, SharedSink};
use crate::rng::StreamTree;
use crate::runtime::Engine;
use crate::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use crate::tasks::{cvar, NvLmo};
use crate::util::pool::parallel_map;
use crate::util::profile::Profiler;

/// A per-replication backend boxed by task family — what
/// [`Coordinator::make_backend`] hands to examples and benches.
pub enum TaskBackend {
    /// Epoch-structured tasks (mean-variance, mean-CVaR): one fused epoch
    /// per call over the [`MvBackend`] contract.
    Epoch(Box<dyn MvBackend>),
    /// Per-iteration gradient tasks (newsvendor): [`NvBackend`].
    Gradient(Box<dyn NvBackend>),
    /// SQN tasks (classification): [`LrBackend`].
    Sqn(Box<dyn LrBackend>),
}

/// What a batched run hands back to the coordinator: the per-replication
/// records plus the [`crate::config::BudgetPolicy`] outcome (empty /
/// `None` when no budget was attached — the default).
pub struct BatchRun {
    pub records: Vec<RepRecord>,
    /// `(rep, epoch)` freeze decisions, in decision order (1-based epochs).
    pub frozen: Vec<(usize, usize)>,
    /// Checkpoint epoch at which every surviving replication converged.
    pub early_stop: Option<usize>,
    /// Panel-level per-phase attribution of the whole run (DESIGN.md §15).
    pub profile: Profiler,
}

/// One registered scenario: everything the execution plane needs to run
/// it, behind one object-safe trait.
pub trait SimTask: Sync {
    /// The [`TaskKind`] this registration backs.
    fn kind(&self) -> TaskKind;

    /// Canonical CLI/report name (the `Display` form of the kind).
    fn name(&self) -> &'static str;

    /// Additional names `TaskKind::parse` accepts.
    fn aliases(&self) -> &'static [&'static str];

    /// One-line description for `simopt --help`.
    fn about(&self) -> &'static str;

    /// The Figure-2 size axis.
    fn default_sizes(&self) -> Vec<usize>;

    /// Paper-§4.1-shaped defaults for one problem size.
    fn default_params(&self, size: usize) -> TaskParams;

    /// Figure-2 default epoch count (FW epochs / SQN iterations).
    fn default_epochs(&self) -> usize;

    /// The `--<flag>-dims` family flag of `python -m compile.aot` that
    /// regenerates this task's artifacts.
    fn dims_flag(&self) -> &'static str;

    /// Task-specific parameter validation (generic size/reps/iters checks
    /// live on [`ExperimentSpec::validate`]).
    fn validate(&self, spec: &ExperimentSpec) -> Result<()>;

    /// Artifacts `spec` needs on the XLA arm that `engine` does not have,
    /// as human-readable `entry param=value` strings (empty = ready).
    fn missing_artifacts(&self, engine: &Engine, spec: &ExperimentSpec)
        -> Vec<String>;

    /// Instantiate a boxed per-replication backend for one-off use; the
    /// task generates its own problem instance from `spec.seed`.
    fn make_backend(&self, cx: &mut Coordinator, spec: &ExperimentSpec)
        -> Result<TaskBackend>;

    /// Run `spec.reps` replications on the sequential plan (one backend
    /// dispatch per replication per step).  Every outer step of every
    /// replication is reported to `sink` (the execution plane's observer
    /// hook, DESIGN.md §14); pass [`crate::opt::NullSink`] for the
    /// historical silent behavior.  On the native arm replications run on
    /// pool threads, so events from different replications may interleave.
    ///
    /// The second return value is the merged per-phase profile of all
    /// replications (DESIGN.md §15) — probes read clocks outside the
    /// timed regions, so profiled traces are bitwise-identical to the
    /// pre-profiler behavior.
    fn run_seq(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
               sink: &mut dyn ProgressSink)
        -> Result<(Vec<RepRecord>, Profiler)>;

    /// Advance all replications together through the shard-aware panel
    /// plane (DESIGN.md §11/§13): `shards` contiguous row shards, one
    /// inner `*BatchBackend` per shard built through this registration's
    /// factories.  `shards == 1` is the single-panel batched engine;
    /// every shard count is bit-identical to it and to `run_seq` on the
    /// native arm (the coordinator resolves the count from the spec's
    /// `ExecMode` and has already validated `1 ≤ shards ≤ reps`).
    ///
    /// Each panel epoch is reported to `sink`, and `spec.budget` (when
    /// set) drives the adaptive replication budget inside the panel loop;
    /// the freeze / early-stop outcome rides back on [`BatchRun`].
    fn run_batch(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
                 shards: usize, sink: &mut dyn ProgressSink)
        -> Result<BatchRun>;

    /// A CI-sized native spec every registered task must complete —
    /// the registry-conformance suite (coordinator tests) runs / repeats /
    /// seq-vs-batch-compares exactly this spec for every registration.
    fn smoke_spec(&self) -> ExperimentSpec {
        let mut spec = ExperimentSpec::new(self.kind(), BackendKind::Native)
            .size(16)
            .replications(2)
            .seed(7);
        spec.track_every = 5;
        spec.params.iters = 4;
        spec.params.m_inner = 3;
        spec.params.samples = 8;
        spec
    }
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Registration order defines `TaskKind::all()` / CLI listing order.
pub static TASKS: [&dyn SimTask; 4] =
    [&MeanVarianceTask, &NewsvendorTask, &ClassificationTask, &MeanCvarTask];

/// Every registered task, in registration order.
pub fn all() -> impl Iterator<Item = &'static dyn SimTask> {
    TASKS.iter().copied()
}

/// The registration backing `kind` — total by the conformance tests.
pub fn get(kind: TaskKind) -> &'static dyn SimTask {
    all().find(|t| t.kind() == kind)
        .expect("every TaskKind variant is registered in tasks::registry")
}

/// Registered kinds, in registration order (backs `TaskKind::all`).
pub fn kinds() -> Vec<TaskKind> {
    all().map(|t| t.kind()).collect()
}

/// Canonical names, in registration order (CLI listings derive from this).
pub fn names() -> Vec<&'static str> {
    all().map(|t| t.name()).collect()
}

/// Name/alias lookup (backs `TaskKind::parse`).
pub fn parse(s: &str) -> Option<TaskKind> {
    let s = s.to_ascii_lowercase();
    all().find(|t| t.name() == s || t.aliases().iter().any(|a| *a == s))
        .map(|t| t.kind())
}

fn native_mode(kind: BackendKind, threads: usize) -> NativeMode {
    match kind {
        BackendKind::Native => NativeMode::Sequential,
        BackendKind::NativePar => NativeMode::Parallel { threads },
        BackendKind::Xla => {
            // callers dispatch Xla before reaching here
            unreachable!("native_mode called with Xla")
        }
    }
}

/// Fold `(record, profile)` results off the pool threads into the
/// `run_seq` return shape, merging per-replication profiles in
/// replication order.
fn collect_seq(results: Vec<Result<(RepRecord, Profiler)>>, reps: usize)
    -> Result<(Vec<RepRecord>, Profiler)> {
    let mut prof = Profiler::new();
    let mut records = Vec::with_capacity(reps);
    for res in results {
        let (rec, p) = res?;
        prof.merge(&p);
        records.push(rec);
    }
    Ok((records, prof))
}

fn ensure_fw_params(spec: &ExperimentSpec) -> Result<()> {
    anyhow::ensure!(spec.params.samples > 0, "samples must be positive");
    anyhow::ensure!(spec.params.m_inner > 0, "m_inner must be positive");
    Ok(())
}

// ---------------------------------------------------------------------------
// Task 1 — mean-variance portfolio (paper §3.1, Algorithm 1)
// ---------------------------------------------------------------------------

pub struct MeanVarianceTask;

impl SimTask for MeanVarianceTask {
    fn kind(&self) -> TaskKind {
        TaskKind::MeanVariance
    }

    fn name(&self) -> &'static str {
        "mean_variance"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["mv", "mean-variance", "portfolio"]
    }

    fn about(&self) -> &'static str {
        "§3.1 mean-variance portfolio (Frank-Wolfe, Algorithm 1)"
    }

    fn default_sizes(&self) -> Vec<usize> {
        vec![128, 512, 2048]
    }

    fn default_params(&self, size: usize) -> TaskParams {
        TaskParams {
            size,
            samples: 64,
            m_inner: 25,
            iters: 40,
            batch: 0,
            hbatch: 0,
            memory: 0,
            l_every: 0,
            beta: 0.0,
            resources: 0,
            tightness: 1.0,
        }
    }

    fn default_epochs(&self) -> usize {
        10
    }

    fn dims_flag(&self) -> &'static str {
        "mv"
    }

    fn validate(&self, spec: &ExperimentSpec) -> Result<()> {
        ensure_fw_params(spec)
    }

    fn missing_artifacts(&self, engine: &Engine, spec: &ExperimentSpec)
        -> Vec<String> {
        let p = &spec.params;
        let req = [("d", spec.size as i64), ("n", p.samples as i64),
                   ("m", p.m_inner as i64)];
        if engine.manifest.find("mv_epoch", &req).is_none() {
            vec![format!("mv_epoch d={} n={} m={}", spec.size, p.samples,
                         p.m_inner)]
        } else {
            vec![]
        }
    }

    fn make_backend(&self, cx: &mut Coordinator, spec: &ExperimentSpec)
        -> Result<TaskBackend> {
        let universe =
            AssetUniverse::generate(&StreamTree::new(spec.seed), spec.size);
        let p = &spec.params;
        Ok(TaskBackend::Epoch(match spec.backend {
            BackendKind::Xla => Box::new(XlaMv::new(
                cx.engine()?, &universe, p.samples, p.m_inner)?),
            b => Box::new(NativeMv::new(
                universe, p.samples, p.m_inner,
                native_mode(b, cx.native_threads))),
        }))
    }

    fn run_seq(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
               sink: &mut dyn ProgressSink)
        -> Result<(Vec<RepRecord>, Profiler)> {
        let tree = StreamTree::new(spec.seed);
        let universe = AssetUniverse::generate(&tree, spec.size);
        let p = &spec.params;
        let w0 = vec![1.0f32 / spec.size as f32; spec.size];
        let trees = rep_subtrees(&tree, spec.reps);
        match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let mut backend =
                    XlaMv::new(engine, &universe, p.samples, p.m_inner)?;
                let mut prof = Profiler::new();
                let mut records = Vec::with_capacity(spec.reps);
                for (r, sub) in trees.iter().enumerate() {
                    let (_, trace) = frank_wolfe::run_mv_ctl(
                        &mut backend, w0.clone(), p.iters, sub, r, sink)?;
                    prof.merge(&trace.profile);
                    records.push(RepRecord::from_fw(trace));
                }
                Ok((records, prof))
            }
            b => {
                let mode = native_mode(b, cx.native_threads);
                let shared = Mutex::new(sink);
                let results =
                    parallel_map(spec.reps, cx.native_threads, |r| {
                        let mut backend = NativeMv::new(
                            universe.clone(), p.samples, p.m_inner, mode);
                        let mut sink = SharedSink(&shared);
                        frank_wolfe::run_mv_ctl(&mut backend, w0.clone(),
                                                p.iters, &trees[r], r,
                                                &mut sink)
                            .map(|(_, t)| {
                                let p = t.profile;
                                (RepRecord::from_fw(t), p)
                            })
                    });
                collect_seq(results, spec.reps)
            }
        }
    }

    fn run_batch(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
                 shards: usize, sink: &mut dyn ProgressSink)
        -> Result<BatchRun> {
        let tree = StreamTree::new(spec.seed);
        let universe = AssetUniverse::generate(&tree, spec.size);
        let p = &spec.params;
        let w0 = vec![1.0f32 / spec.size as f32; spec.size];
        let trees = rep_subtrees(&tree, spec.reps);
        let mut ctl = PanelCtl { sink, budget: spec.budget };
        let out = match spec.backend {
            BackendKind::Xla => {
                // one shard-sized [R/S × …] artifact dispatch per shard
                let engine = cx.engine()?;
                let mut backend = ShardedBatch::serial(
                    spec.reps, shards, spec.size, |rows| {
                        XlaMvBatch::new(engine, &universe, p.samples,
                                        p.m_inner, rows.len())
                    })?;
                frank_wolfe::run_mv_batch_ctl(&mut backend, &w0, p.iters,
                                              &trees, &mut ctl)?
            }
            _ => {
                let threads = cx.native_threads;
                let inner = plane::inner_threads(threads, shards);
                let mut backend = ShardedBatch::pooled(
                    spec.reps, shards, spec.size, threads, |rows| {
                        Ok(NativeMvBatch::new(&universe, p.samples,
                                              p.m_inner, rows.len(), inner))
                    })?;
                frank_wolfe::run_mv_batch_ctl(&mut backend, &w0, p.iters,
                                              &trees, &mut ctl)?
            }
        };
        Ok(BatchRun {
            records: out.traces.into_iter().map(RepRecord::from_fw)
                .collect(),
            frozen: out.frozen,
            early_stop: out.early_stop,
            profile: out.profile,
        })
    }
}

// ---------------------------------------------------------------------------
// Task 2 — multi-product newsvendor (paper §3.2, Algorithm 2)
// ---------------------------------------------------------------------------

pub struct NewsvendorTask;

impl SimTask for NewsvendorTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Newsvendor
    }

    fn name(&self) -> &'static str {
        "newsvendor"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["nv", "news_vendor", "inventory"]
    }

    fn about(&self) -> &'static str {
        "§3.2 multi-product newsvendor (Frank-Wolfe + LP LMO, Algorithm 2)"
    }

    fn default_sizes(&self) -> Vec<usize> {
        vec![256, 2048, 16384]
    }

    fn default_params(&self, size: usize) -> TaskParams {
        TaskParams {
            size,
            samples: 32,
            m_inner: 25,
            iters: 40,
            batch: 0,
            hbatch: 0,
            memory: 0,
            l_every: 0,
            beta: 0.0,
            resources: 8,
            tightness: 0.6,
        }
    }

    fn default_epochs(&self) -> usize {
        10
    }

    fn dims_flag(&self) -> &'static str {
        "nv"
    }

    fn validate(&self, spec: &ExperimentSpec) -> Result<()> {
        ensure_fw_params(spec)
    }

    fn missing_artifacts(&self, engine: &Engine, spec: &ExperimentSpec)
        -> Vec<String> {
        let p = &spec.params;
        let req = [("d", spec.size as i64), ("s", p.samples as i64)];
        if engine.manifest.find("nv_grad", &req).is_none() {
            vec![format!("nv_grad d={} s={}", spec.size, p.samples)]
        } else {
            vec![]
        }
    }

    fn make_backend(&self, cx: &mut Coordinator, spec: &ExperimentSpec)
        -> Result<TaskBackend> {
        let tree = StreamTree::new(spec.seed);
        let inst = NewsvendorInstance::generate(
            &tree, spec.size, spec.params.resources,
            spec.params.tightness);
        let p = &spec.params;
        Ok(TaskBackend::Gradient(match spec.backend {
            BackendKind::Xla => {
                Box::new(XlaNv::new(cx.engine()?, &inst, p.samples)?)
            }
            b => Box::new(NativeNv::new(
                inst, p.samples, native_mode(b, cx.native_threads))),
        }))
    }

    fn run_seq(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
               sink: &mut dyn ProgressSink)
        -> Result<(Vec<RepRecord>, Profiler)> {
        let tree = StreamTree::new(spec.seed);
        let inst = NewsvendorInstance::generate(
            &tree, spec.size, spec.params.resources,
            spec.params.tightness);
        let p = &spec.params;
        let x0 = inst.feasible_start();
        let trees = rep_subtrees(&tree, spec.reps);
        match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let mut backend = XlaNv::new(engine, &inst, p.samples)?;
                let mut prof = Profiler::new();
                let mut records = Vec::with_capacity(spec.reps);
                for (r, sub) in trees.iter().enumerate() {
                    let mut lmo = NvLmo::new(&inst);
                    let (_, trace) = frank_wolfe::run_nv_ctl(
                        &mut backend, &mut lmo, x0.clone(), p.iters,
                        p.m_inner, sub, r, sink)?;
                    prof.merge(&trace.profile);
                    records.push(RepRecord::from_fw(trace));
                }
                Ok((records, prof))
            }
            b => {
                let mode = native_mode(b, cx.native_threads);
                let shared = Mutex::new(sink);
                let results =
                    parallel_map(spec.reps, cx.native_threads, |r| {
                        let mut backend =
                            NativeNv::new(inst.clone(), p.samples, mode);
                        let mut lmo = NvLmo::new(&inst);
                        let mut sink = SharedSink(&shared);
                        frank_wolfe::run_nv_ctl(&mut backend, &mut lmo,
                                                x0.clone(), p.iters,
                                                p.m_inner, &trees[r], r,
                                                &mut sink)
                            .map(|(_, t)| {
                                let p = t.profile;
                                (RepRecord::from_fw(t), p)
                            })
                    });
                collect_seq(results, spec.reps)
            }
        }
    }

    fn run_batch(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
                 shards: usize, sink: &mut dyn ProgressSink)
        -> Result<BatchRun> {
        let tree = StreamTree::new(spec.seed);
        let inst = NewsvendorInstance::generate(
            &tree, spec.size, spec.params.resources,
            spec.params.tightness);
        let p = &spec.params;
        let x0 = inst.feasible_start();
        let trees = rep_subtrees(&tree, spec.reps);
        let mut lmos: Vec<NvLmo> =
            (0..spec.reps).map(|_| NvLmo::new(&inst)).collect();
        let mut ctl = PanelCtl { sink, budget: spec.budget };
        // the panel LMO is pure host-side LP work on either backend, so
        // both arms fan its rows out over the native worker pool
        let threads = cx.native_threads;
        let out = match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let mut backend = ShardedBatch::serial(
                    spec.reps, shards, spec.size, |rows| {
                        XlaNvBatch::new(engine, &inst, p.samples,
                                        rows.len())
                    })?;
                frank_wolfe::run_nv_batch_ctl(&mut backend, &mut lmos, &x0,
                                              p.iters, p.m_inner, &trees,
                                              threads, &mut ctl)?
            }
            _ => {
                let inner = plane::inner_threads(threads, shards);
                let mut backend = ShardedBatch::pooled(
                    spec.reps, shards, spec.size, threads, |rows| {
                        Ok(NativeNvBatch::new(&inst, p.samples, rows.len(),
                                              inner))
                    })?;
                frank_wolfe::run_nv_batch_ctl(&mut backend, &mut lmos, &x0,
                                              p.iters, p.m_inner, &trees,
                                              threads, &mut ctl)?
            }
        };
        Ok(BatchRun {
            records: out.traces.into_iter().map(RepRecord::from_fw)
                .collect(),
            frozen: out.frozen,
            early_stop: out.early_stop,
            profile: out.profile,
        })
    }
}

// ---------------------------------------------------------------------------
// Task 3 — binary classification via SQN (paper §3.3, Algorithms 3-4)
// ---------------------------------------------------------------------------

pub struct ClassificationTask;

impl ClassificationTask {
    fn sqn_config(spec: &ExperimentSpec) -> sqn::SqnConfig {
        let p = &spec.params;
        sqn::SqnConfig {
            iters: p.iters,
            batch: p.batch,
            hbatch: p.hbatch,
            l_every: p.l_every,
            memory: p.memory,
            beta: p.beta,
            track_every: spec.track_every,
            track_rows: 2048,
        }
    }
}

impl SimTask for ClassificationTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Classification
    }

    fn name(&self) -> &'static str {
        "classification"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["lr", "logistic"]
    }

    fn about(&self) -> &'static str {
        "§3.3 binary classification (SQN, Algorithms 3-4)"
    }

    fn default_sizes(&self) -> Vec<usize> {
        vec![64, 256, 1024]
    }

    fn default_params(&self, size: usize) -> TaskParams {
        TaskParams {
            size,
            samples: 0,
            m_inner: 0,
            iters: 400,
            batch: 64,
            hbatch: 256,
            memory: 25,
            l_every: 10,
            beta: 2.0,
            resources: 0,
            tightness: 1.0,
        }
    }

    fn default_epochs(&self) -> usize {
        200
    }

    fn dims_flag(&self) -> &'static str {
        "lr"
    }

    fn validate(&self, spec: &ExperimentSpec) -> Result<()> {
        anyhow::ensure!(spec.params.batch > 0, "batch must be positive");
        anyhow::ensure!(spec.params.hbatch > 0, "hbatch must be positive");
        anyhow::ensure!(spec.params.l_every > 0, "l_every must be positive");
        anyhow::ensure!(spec.params.memory > 0, "memory must be positive");
        Ok(())
    }

    fn missing_artifacts(&self, engine: &Engine, spec: &ExperimentSpec)
        -> Vec<String> {
        let n = spec.size as i64;
        let mut m = Vec::new();
        if engine.manifest.find("lr_grad", &[("n", n)]).is_none() {
            m.push(format!("lr_grad n={}", n));
        }
        if engine.manifest.find("lr_hvp", &[("n", n)]).is_none() {
            m.push(format!("lr_hvp n={}", n));
        }
        m
    }

    fn make_backend(&self, cx: &mut Coordinator, spec: &ExperimentSpec)
        -> Result<TaskBackend> {
        let p = &spec.params;
        Ok(TaskBackend::Sqn(match spec.backend {
            BackendKind::Xla => {
                let data = ClassifyData::generate(
                    &StreamTree::new(spec.seed), spec.size);
                Box::new(XlaLr::new(cx.engine()?, &data, p.batch, p.hbatch,
                                    p.memory, spec.hessian_mode)?)
            }
            b => Box::new(NativeLr::with_dim(
                spec.size, native_mode(b, cx.native_threads),
                spec.hessian_mode)),
        }))
    }

    fn run_seq(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
               sink: &mut dyn ProgressSink)
        -> Result<(Vec<RepRecord>, Profiler)> {
        let tree = StreamTree::new(spec.seed);
        let data = ClassifyData::generate(&tree, spec.size);
        let cfg = Self::sqn_config(spec);
        let trees = rep_subtrees(&tree, spec.reps);
        match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let p = &spec.params;
                let mut backend = XlaLr::new(engine, &data, p.batch,
                                             p.hbatch, p.memory,
                                             spec.hessian_mode)?;
                let mut prof = Profiler::new();
                let mut records = Vec::with_capacity(spec.reps);
                for (r, sub) in trees.iter().enumerate() {
                    let (_, trace) = sqn::run_sqn_ctl(
                        &mut backend, &data, &cfg, sub, r, sink)?;
                    prof.merge(&trace.profile);
                    records.push(RepRecord::from_sqn(trace));
                }
                Ok((records, prof))
            }
            b => {
                let mode = native_mode(b, cx.native_threads);
                let shared = Mutex::new(sink);
                let results =
                    parallel_map(spec.reps, cx.native_threads, |r| {
                        let mut backend =
                            NativeLr::new(&data, mode, spec.hessian_mode);
                        let mut sink = SharedSink(&shared);
                        sqn::run_sqn_ctl(&mut backend, &data, &cfg,
                                         &trees[r], r, &mut sink)
                            .map(|(_, t)| {
                                let p = t.profile;
                                (RepRecord::from_sqn(t), p)
                            })
                    });
                collect_seq(results, spec.reps)
            }
        }
    }

    fn run_batch(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
                 shards: usize, sink: &mut dyn ProgressSink)
        -> Result<BatchRun> {
        let tree = StreamTree::new(spec.seed);
        let data = ClassifyData::generate(&tree, spec.size);
        let cfg = Self::sqn_config(spec);
        let trees = rep_subtrees(&tree, spec.reps);
        let mut ctl = PanelCtl { sink, budget: spec.budget };
        let out = match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let p = &spec.params;
                let mut backend = ShardedBatch::serial(
                    spec.reps, shards, spec.size, |rows| {
                        XlaLrBatch::new(engine, &data, p.batch, p.hbatch,
                                        p.memory, spec.hessian_mode,
                                        rows.len())
                    })?;
                sqn::run_sqn_batch_ctl(&mut backend, &data, &cfg, &trees,
                                       &mut ctl)?
            }
            _ => {
                let threads = cx.native_threads;
                let inner = plane::inner_threads(threads, shards);
                let mut backend = ShardedBatch::pooled(
                    spec.reps, shards, spec.size, threads, |rows| {
                        Ok(NativeLrBatch::new(&data, rows.len(), inner,
                                              spec.hessian_mode))
                    })?;
                sqn::run_sqn_batch_ctl(&mut backend, &data, &cfg, &trees,
                                       &mut ctl)?
            }
        };
        Ok(BatchRun {
            records: out.traces.into_iter().map(RepRecord::from_sqn)
                .collect(),
            frozen: out.frozen,
            early_stop: out.early_stop,
            profile: out.profile,
        })
    }

    fn smoke_spec(&self) -> ExperimentSpec {
        let mut spec = ExperimentSpec::new(self.kind(), BackendKind::Native)
            .size(16)
            .replications(2)
            .seed(7);
        spec.track_every = 5;
        spec.params.iters = 30;
        spec.params.batch = 16;
        spec.params.hbatch = 32;
        spec.params.l_every = 5;
        spec.params.memory = 3;
        spec
    }
}

// ---------------------------------------------------------------------------
// Task 4 — smoothed mean-CVaR portfolio (registry extension, DESIGN.md §12)
// ---------------------------------------------------------------------------

pub struct MeanCvarTask;

impl SimTask for MeanCvarTask {
    fn kind(&self) -> TaskKind {
        TaskKind::MeanCvar
    }

    fn name(&self) -> &'static str {
        "mean_cvar"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["cv", "cvar", "mean-cvar"]
    }

    fn about(&self) -> &'static str {
        "mean-CVaR portfolio (Rockafellar-Uryasev smoothed CVaR, \
         Frank-Wolfe; DESIGN.md §12)"
    }

    fn default_sizes(&self) -> Vec<usize> {
        vec![128, 512, 2048]
    }

    fn default_params(&self, size: usize) -> TaskParams {
        TaskParams {
            size,
            samples: 64,
            m_inner: 25,
            iters: 40,
            batch: 0,
            hbatch: 0,
            memory: 0,
            l_every: 0,
            beta: 0.0,
            resources: 0,
            tightness: 1.0,
        }
    }

    fn default_epochs(&self) -> usize {
        10
    }

    fn dims_flag(&self) -> &'static str {
        "cv"
    }

    fn validate(&self, spec: &ExperimentSpec) -> Result<()> {
        ensure_fw_params(spec)
    }

    fn missing_artifacts(&self, engine: &Engine, spec: &ExperimentSpec)
        -> Vec<String> {
        let p = &spec.params;
        let req = [("d", spec.size as i64), ("n", p.samples as i64),
                   ("m", p.m_inner as i64)];
        if engine.manifest.find("cv_epoch", &req).is_none() {
            vec![format!("cv_epoch d={} n={} m={}", spec.size, p.samples,
                         p.m_inner)]
        } else {
            vec![]
        }
    }

    fn make_backend(&self, cx: &mut Coordinator, spec: &ExperimentSpec)
        -> Result<TaskBackend> {
        let universe =
            AssetUniverse::generate(&StreamTree::new(spec.seed), spec.size);
        let p = &spec.params;
        Ok(TaskBackend::Epoch(match spec.backend {
            BackendKind::Xla => Box::new(XlaCvar::new(
                cx.engine()?, &universe, p.samples, p.m_inner)?),
            b => Box::new(NativeCvar::new(
                universe, p.samples, p.m_inner,
                native_mode(b, cx.native_threads))),
        }))
    }

    fn run_seq(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
               sink: &mut dyn ProgressSink)
        -> Result<(Vec<RepRecord>, Profiler)> {
        let tree = StreamTree::new(spec.seed);
        let universe = AssetUniverse::generate(&tree, spec.size);
        let p = &spec.params;
        let x0 = cvar::start_iterate(spec.size);
        let trees = rep_subtrees(&tree, spec.reps);
        match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let mut backend =
                    XlaCvar::new(engine, &universe, p.samples, p.m_inner)?;
                let mut prof = Profiler::new();
                let mut records = Vec::with_capacity(spec.reps);
                for (r, sub) in trees.iter().enumerate() {
                    let (_, trace) = frank_wolfe::run_mv_ctl(
                        &mut backend, x0.clone(), p.iters, sub, r, sink)?;
                    prof.merge(&trace.profile);
                    records.push(RepRecord::from_fw(trace));
                }
                Ok((records, prof))
            }
            b => {
                let mode = native_mode(b, cx.native_threads);
                let shared = Mutex::new(sink);
                let results =
                    parallel_map(spec.reps, cx.native_threads, |r| {
                        let mut backend = NativeCvar::new(
                            universe.clone(), p.samples, p.m_inner, mode);
                        let mut sink = SharedSink(&shared);
                        frank_wolfe::run_mv_ctl(&mut backend, x0.clone(),
                                                p.iters, &trees[r], r,
                                                &mut sink)
                            .map(|(_, t)| {
                                let p = t.profile;
                                (RepRecord::from_fw(t), p)
                            })
                    });
                collect_seq(results, spec.reps)
            }
        }
    }

    fn run_batch(&self, cx: &mut Coordinator, spec: &ExperimentSpec,
                 shards: usize, sink: &mut dyn ProgressSink)
        -> Result<BatchRun> {
        let tree = StreamTree::new(spec.seed);
        let universe = AssetUniverse::generate(&tree, spec.size);
        let p = &spec.params;
        let x0 = cvar::start_iterate(spec.size);
        // the joint [w, t] iterate makes the row width d+1 (tasks::cvar)
        let row = spec.size + 1;
        let trees = rep_subtrees(&tree, spec.reps);
        let mut ctl = PanelCtl { sink, budget: spec.budget };
        let out = match spec.backend {
            BackendKind::Xla => {
                let engine = cx.engine()?;
                let mut backend = ShardedBatch::serial(
                    spec.reps, shards, row, |rows| {
                        XlaCvarBatch::new(engine, &universe, p.samples,
                                          p.m_inner, rows.len())
                    })?;
                frank_wolfe::run_mv_batch_ctl(&mut backend, &x0, p.iters,
                                              &trees, &mut ctl)?
            }
            _ => {
                let threads = cx.native_threads;
                let inner = plane::inner_threads(threads, shards);
                let mut backend = ShardedBatch::pooled(
                    spec.reps, shards, row, threads, |rows| {
                        Ok(NativeCvarBatch::new(&universe, p.samples,
                                                p.m_inner, rows.len(),
                                                inner))
                    })?;
                frank_wolfe::run_mv_batch_ctl(&mut backend, &x0, p.iters,
                                              &trees, &mut ctl)?
            }
        };
        Ok(BatchRun {
            records: out.traces.into_iter().map(RepRecord::from_fw)
                .collect(),
            frozen: out.frozen,
            early_stop: out.early_stop,
            profile: out.profile,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn registry_covers_every_task_kind_bijectively() {
        let kinds = kinds();
        assert_eq!(kinds.len(), TASKS.len());
        let unique: HashSet<_> = kinds.iter().collect();
        assert_eq!(unique.len(), kinds.len(), "duplicate registration");
        for kind in TaskKind::all() {
            assert_eq!(get(kind).kind(), kind);
        }
    }

    #[test]
    fn names_and_aliases_are_unique_and_parse_back() {
        let mut seen = HashSet::new();
        for task in all() {
            assert!(seen.insert(task.name()), "name collision: {}",
                    task.name());
            assert_eq!(parse(task.name()), Some(task.kind()));
            for alias in task.aliases() {
                assert!(seen.insert(alias), "alias collision: {}", alias);
                assert_eq!(parse(alias), Some(task.kind()),
                           "alias {} does not parse", alias);
            }
            assert!(!task.about().is_empty());
        }
        assert_eq!(parse("not-a-task"), None);
    }

    #[test]
    fn smoke_specs_validate_and_stay_tiny() {
        for task in all() {
            let spec = task.smoke_spec();
            assert_eq!(spec.task, task.kind());
            spec.validate().unwrap_or_else(|e| {
                panic!("{} smoke spec invalid: {:#}", task.name(), e)
            });
            assert!(spec.reps >= 2,
                    "conformance needs ≥2 reps to check stream disjointness");
            assert!(spec.size <= 64, "{} smoke spec too big", task.name());
        }
    }

    #[test]
    fn default_params_match_default_sizes() {
        for task in all() {
            let sizes = task.default_sizes();
            assert!(!sizes.is_empty());
            assert!(sizes.windows(2).all(|w| w[0] < w[1]));
            let p = task.default_params(sizes[0]);
            assert_eq!(p.size, sizes[0]);
            assert!(p.iters > 0);
            assert!(task.default_epochs() > 0);
            assert!(!task.dims_flag().is_empty());
        }
    }

    #[test]
    fn make_backend_is_a_registry_lookup() {
        let mut c =
            Coordinator::new("artifacts", "/tmp/simopt-registry-test")
                .unwrap();
        for task in all() {
            let spec = task.smoke_spec();
            let backend = task.make_backend(&mut c, &spec).unwrap();
            match (task.kind(), backend) {
                (TaskKind::MeanVariance | TaskKind::MeanCvar,
                 TaskBackend::Epoch(b)) => assert_eq!(b.name(), "native"),
                (TaskKind::Newsvendor, TaskBackend::Gradient(b)) => {
                    assert_eq!(b.name(), "native")
                }
                (TaskKind::Classification, TaskBackend::Sqn(b)) => {
                    assert_eq!(b.name(), "native")
                }
                (kind, _) => panic!("{} returned wrong backend family",
                                    kind),
            }
        }
    }
}
