//! Task 3 math (paper §3.3): logistic loss/gradient, sub-sampled
//! Hessian-vector products, the SQN correction memory, and the explicit
//! Algorithm-4 inverse-Hessian build.

use crate::linalg::matrix::Mat;
use crate::linalg::vector::dot;

const EPS: f32 = 1e-10;

#[inline]
pub fn sigmoid(u: f32) -> f32 {
    1.0 / (1.0 + (-u).exp())
}

/// Stable per-sample BCE: max(u,0) − u·z + log(1 + e^{−|u|}).
#[inline]
pub fn bce(u: f32, z: f32) -> f32 {
    u.max(0.0) - u * z + (-u.abs()).exp().ln_1p()
}

/// Minibatch gradient (12) + mean loss, sequential sample loop.
/// `xb` is row-major (b × n).
pub fn grad(w: &[f32], xb: &[f32], zb: &[f32], g: &mut [f32]) -> f64 {
    let n = w.len();
    let b = zb.len();
    debug_assert_eq!(xb.len(), b * n);
    g.iter_mut().for_each(|v| *v = 0.0);
    let mut loss = 0.0f64;
    for i in 0..b {
        let row = &xb[i * n..(i + 1) * n];
        let u = dot(row, w);
        let c = sigmoid(u);
        let r = c - zb[i];
        for j in 0..n {
            g[j] += r * row[j];
        }
        loss += bce(u, zb[i]) as f64;
    }
    let inv = 1.0 / b as f32;
    g.iter_mut().for_each(|v| *v *= inv);
    loss / b as f64
}

/// Sub-sampled Hessian-vector product (13): Xᵀ diag(c(1−c)) X s / b_H.
pub fn hvp(wbar: &[f32], s: &[f32], xh: &[f32], out: &mut [f32]) {
    let n = wbar.len();
    let bh = xh.len() / n;
    debug_assert_eq!(xh.len(), bh * n);
    out.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..bh {
        let row = &xh[i * n..(i + 1) * n];
        let c = sigmoid(dot(row, wbar));
        let a = c * (1.0 - c);
        let xs = dot(row, s);
        let coef = a * xs;
        for j in 0..n {
            out[j] += coef * row[j];
        }
    }
    let inv = 1.0 / bh as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

/// Ring of correction pairs (s_t, y_t), oldest first — the layout the
/// `lr_hbuild` / `lr_dir_twoloop` artifacts expect (rows [0, count) valid).
#[derive(Debug, Clone)]
pub struct CorrectionMemory {
    pub s_mem: Vec<f32>,
    pub y_mem: Vec<f32>,
    pub capacity: usize,
    pub count: usize,
    pub n: usize,
}

impl CorrectionMemory {
    pub fn new(capacity: usize, n: usize) -> Self {
        CorrectionMemory {
            s_mem: vec![0.0; capacity * n],
            y_mem: vec![0.0; capacity * n],
            capacity,
            count: 0,
            n,
        }
    }

    /// Append a pair; evicts the oldest once full.  Pairs with non-positive
    /// curvature s·y are rejected (standard BFGS safeguard) — returns false.
    pub fn push(&mut self, s: &[f32], y: &[f32]) -> bool {
        assert_eq!(s.len(), self.n);
        assert_eq!(y.len(), self.n);
        if dot(s, y) <= EPS {
            return false;
        }
        if self.count == self.capacity {
            // shift left one row (O(capacity·n), every L iterations — cheap
            // relative to the O(b·n) gradient work between pushes)
            self.s_mem.copy_within(self.n.., 0);
            self.y_mem.copy_within(self.n.., 0);
            self.count -= 1;
        }
        let at = self.count * self.n;
        self.s_mem[at..at + self.n].copy_from_slice(s);
        self.y_mem[at..at + self.n].copy_from_slice(y);
        self.count += 1;
        true
    }

    pub fn pair(&self, i: usize) -> (&[f32], &[f32]) {
        assert!(i < self.count);
        let at = i * self.n;
        (&self.s_mem[at..at + self.n], &self.y_mem[at..at + self.n])
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// Algorithm 4, explicit form (the paper's matrix-operation showcase):
/// build the full inverse-Hessian approximation H_t.  O(count·n²)
/// sequential.  Returns the identity when the memory is empty.
pub fn hbuild_explicit(mem: &CorrectionMemory) -> Mat {
    let n = mem.n;
    if mem.is_empty() {
        return Mat::eye(n);
    }
    let (s_l, y_l) = mem.pair(mem.count - 1);
    let gamma = (dot(s_l, y_l) / dot(y_l, y_l).max(EPS)).max(EPS);
    let mut h = Mat::zeros(n, n);
    for i in 0..n {
        h.set(i, i, gamma);
    }
    let mut hy = vec![0.0f32; n];
    for idx in 0..mem.count {
        let (s, y) = mem.pair(idx);
        let denom = dot(y, s);
        if denom <= EPS {
            continue;
        }
        let rho = 1.0 / denom;
        h.matvec(y, &mut hy); // H is symmetric ⇒ yᵀH = hyᵀ
        let q = dot(y, &hy);
        let c2 = rho * rho * q + rho;
        for i in 0..n {
            let si = s[i];
            let hyi = hy[i];
            let row = h.row_mut(i);
            for j in 0..n {
                row[j] += -rho * si * hy[j] - rho * hyi * s[j] + c2 * si * s[j];
            }
        }
    }
    h
}

/// Build H (Algorithm 4) and apply it to `g` in one shot.
pub fn hdir_explicit(mem: &CorrectionMemory, g: &[f32]) -> Vec<f32> {
    let h = hbuild_explicit(mem);
    let mut d = vec![0.0f32; mem.n.max(g.len())];
    d.truncate(g.len());
    h.matvec(g, &mut d);
    d
}

/// L-BFGS two-loop recursion over the same memory (ablation A2); O(count·n).
pub fn hdir_twoloop(mem: &CorrectionMemory, g: &[f32]) -> Vec<f32> {
    let n = mem.n;
    assert_eq!(g.len(), n);
    if mem.is_empty() {
        return g.to_vec();
    }
    let mut q = g.to_vec();
    let mut alpha = vec![0.0f32; mem.count];
    let mut rho = vec![0.0f32; mem.count];
    for i in (0..mem.count).rev() {
        let (s, y) = mem.pair(i);
        let denom = dot(y, s);
        rho[i] = if denom > EPS { 1.0 / denom } else { 0.0 };
        let a = rho[i] * dot(s, &q);
        alpha[i] = a;
        for j in 0..n {
            q[j] -= a * y[j];
        }
    }
    let (s_l, y_l) = mem.pair(mem.count - 1);
    let gamma = (dot(s_l, y_l) / dot(y_l, y_l).max(EPS)).max(EPS);
    let mut r: Vec<f32> = q.iter().map(|&v| gamma * v).collect();
    for i in 0..mem.count {
        let (s, y) = mem.pair(i);
        let b = rho[i] * dot(y, &r);
        let coef = alpha[i] - b;
        for j in 0..n {
            r[j] += coef * s[j];
        }
    }
    r
}

/// Full-dataset (or subset) mean loss — the convergence metric the RSE trace
/// tracks; sequential row loop.
pub fn full_loss(w: &[f32], x: &[f32], z: &[f32]) -> f64 {
    let n = w.len();
    let rows = z.len();
    let mut total = 0.0f64;
    for i in 0..rows {
        let u = dot(&x[i * n..(i + 1) * n], w);
        total += bce(u, z[i]) as f64;
    }
    total / rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn batch(seed: u64, b: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut p = Philox::new(seed);
        let xb: Vec<f32> = (0..b * n).map(|_| (p.next_u32() & 1) as f32).collect();
        let zb: Vec<f32> = (0..b).map(|_| (p.next_u32() & 1) as f32).collect();
        let w: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.3, 0.3)).collect();
        (xb, zb, w)
    }

    #[test]
    fn sigmoid_and_bce_stability() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) > 0.9999);
        assert!(sigmoid(-100.0) < 1e-4);
        assert!(bce(500.0, 1.0).is_finite());
        assert!(bce(-500.0, 0.0).is_finite());
        assert!(bce(500.0, 0.0) > 100.0);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let (xb, zb, w) = batch(1, 16, 8);
        let mut g = vec![0.0f32; 8];
        grad(&w, &xb, &zb, &mut g);
        let h = 1e-3f32;
        for j in 0..8 {
            let mut wp = w.clone();
            wp[j] += h;
            let mut wm = w.clone();
            wm[j] -= h;
            let mut scratch = vec![0.0f32; 8];
            let fp = grad(&wp, &xb, &zb, &mut scratch);
            let fm = grad(&wm, &xb, &zb, &mut scratch);
            let fd = ((fp - fm) / (2.0 * h as f64)) as f32;
            assert!((g[j] - fd).abs() < 5e-3, "j={} {} vs {}", j, g[j], fd);
        }
    }

    #[test]
    fn hvp_matches_finite_difference_of_grad() {
        let (xb, zb, w) = batch(2, 32, 6);
        let mut p = Philox::new(9);
        let s: Vec<f32> = (0..6).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let mut out = vec![0.0f32; 6];
        hvp(&w, &s, &xb, &mut out);
        let h = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&s).map(|(a, b)| a + h * b).collect();
        let wm: Vec<f32> = w.iter().zip(&s).map(|(a, b)| a - h * b).collect();
        let mut gp = vec![0.0f32; 6];
        let mut gm = vec![0.0f32; 6];
        grad(&wp, &xb, &zb, &mut gp);
        grad(&wm, &xb, &zb, &mut gm);
        for j in 0..6 {
            let fd = (gp[j] - gm[j]) / (2.0 * h);
            assert!((out[j] - fd).abs() < 5e-3, "j={} {} vs {}", j, out[j], fd);
        }
    }

    #[test]
    fn memory_ring_semantics() {
        let mut mem = CorrectionMemory::new(3, 2);
        assert!(mem.is_empty());
        for t in 0..5 {
            let s = vec![1.0 + t as f32, 0.0];
            let y = vec![1.0, 0.5];
            assert!(mem.push(&s, &y));
        }
        assert_eq!(mem.count, 3);
        // oldest evicted: remaining pairs are t = 2, 3, 4
        assert_eq!(mem.pair(0).0[0], 3.0);
        assert_eq!(mem.pair(2).0[0], 5.0);
    }

    #[test]
    fn memory_rejects_nonpositive_curvature() {
        let mut mem = CorrectionMemory::new(2, 2);
        assert!(!mem.push(&[1.0, 0.0], &[-1.0, 0.0]));
        assert!(!mem.push(&[1.0, 0.0], &[0.0, 1.0])); // s·y = 0
        assert!(mem.is_empty());
    }

    #[test]
    fn explicit_and_twoloop_agree() {
        let mut p = Philox::new(5);
        let n = 10;
        let mut mem = CorrectionMemory::new(4, n);
        for _ in 0..4 {
            let s: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            // y = s + small SPD-ish perturbation keeps curvature positive
            let y: Vec<f32> = s.iter().map(|&v| 1.5 * v + 0.01).collect();
            if dot(&s, &y) > 0.0 {
                mem.push(&s, &y);
            }
        }
        assert!(mem.count >= 2);
        let g: Vec<f32> = (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let d1 = hdir_explicit(&mem, &g);
        let d2 = hdir_twoloop(&mem, &g);
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() < 2e-3, "{} vs {}", a, b);
        }
    }

    #[test]
    fn empty_memory_returns_gradient() {
        let mem = CorrectionMemory::new(4, 3);
        let g = vec![1.0f32, -2.0, 3.0];
        assert_eq!(hdir_explicit(&mem, &g), g);
        assert_eq!(hdir_twoloop(&mem, &g), g);
    }

    #[test]
    fn direction_is_descent() {
        let mut p = Philox::new(7);
        let n = 8;
        let mut mem = CorrectionMemory::new(3, n);
        for _ in 0..3 {
            let s: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            let y: Vec<f32> = s.iter().map(|&v| 2.0 * v).collect();
            mem.push(&s, &y);
        }
        let g: Vec<f32> = (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let d = hdir_explicit(&mem, &g);
        assert!(dot(&g, &d) > 0.0, "H must be positive definite on g");
    }

    #[test]
    fn full_loss_decreases_under_gd() {
        let (xb, zb, mut w) = batch(11, 64, 8);
        let before = full_loss(&w, &xb, &zb);
        let mut g = vec![0.0f32; 8];
        for _ in 0..20 {
            grad(&w, &xb, &zb, &mut g);
            for j in 0..8 {
                w[j] -= 0.5 * g[j];
            }
        }
        let after = full_loss(&w, &xb, &zb);
        assert!(after < before);
    }
}
