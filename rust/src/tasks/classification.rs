//! Task 3 math (paper §3.3): logistic loss/gradient, sub-sampled
//! Hessian-vector products, the SQN correction memory, and the explicit
//! Algorithm-4 inverse-Hessian build.

use crate::linalg::matrix::Mat;
use crate::linalg::vector::dot;

const EPS: f32 = 1e-10;

#[inline]
pub fn sigmoid(u: f32) -> f32 {
    1.0 / (1.0 + (-u).exp())
}

/// Stable per-sample BCE: max(u,0) − u·z + log(1 + e^{−|u|}).
#[inline]
pub fn bce(u: f32, z: f32) -> f32 {
    u.max(0.0) - u * z + (-u.abs()).exp().ln_1p()
}

/// Minibatch gradient (12) + mean loss, sequential sample loop.
/// `xb` is row-major (b × n).
pub fn grad(w: &[f32], xb: &[f32], zb: &[f32], g: &mut [f32]) -> f64 {
    let n = w.len();
    let b = zb.len();
    debug_assert_eq!(xb.len(), b * n);
    g.iter_mut().for_each(|v| *v = 0.0);
    let mut loss = 0.0f64;
    for i in 0..b {
        let row = &xb[i * n..(i + 1) * n];
        let u = dot(row, w);
        let c = sigmoid(u);
        let r = c - zb[i];
        for j in 0..n {
            g[j] += r * row[j];
        }
        loss += bce(u, zb[i]) as f64;
    }
    let inv = 1.0 / b as f32;
    g.iter_mut().for_each(|v| *v *= inv);
    loss / b as f64
}

/// Sub-sampled Hessian-vector product (13): Xᵀ diag(c(1−c)) X s / b_H.
pub fn hvp(wbar: &[f32], s: &[f32], xh: &[f32], out: &mut [f32]) {
    let n = wbar.len();
    let bh = xh.len() / n;
    debug_assert_eq!(xh.len(), bh * n);
    out.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..bh {
        let row = &xh[i * n..(i + 1) * n];
        let c = sigmoid(dot(row, wbar));
        let a = c * (1.0 - c);
        let xs = dot(row, s);
        let coef = a * xs;
        for j in 0..n {
            out[j] += coef * row[j];
        }
    }
    let inv = 1.0 / bh as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

/// Ring of correction pairs (s_t, y_t), oldest first — the layout the
/// `lr_hbuild` / `lr_dir_twoloop` artifacts expect (rows [0, count) valid).
#[derive(Debug, Clone)]
pub struct CorrectionMemory {
    pub s_mem: Vec<f32>,
    pub y_mem: Vec<f32>,
    pub capacity: usize,
    pub count: usize,
    pub n: usize,
}

impl CorrectionMemory {
    pub fn new(capacity: usize, n: usize) -> Self {
        CorrectionMemory {
            s_mem: vec![0.0; capacity * n],
            y_mem: vec![0.0; capacity * n],
            capacity,
            count: 0,
            n,
        }
    }

    /// Append a pair; evicts the oldest once full.  Pairs with non-positive
    /// curvature s·y are rejected (standard BFGS safeguard) — returns false.
    pub fn push(&mut self, s: &[f32], y: &[f32]) -> bool {
        push_into(&mut self.s_mem, &mut self.y_mem, &mut self.count,
                  self.capacity, self.n, s, y)
    }

    pub fn pair(&self, i: usize) -> (&[f32], &[f32]) {
        assert!(i < self.count);
        let at = i * self.n;
        (&self.s_mem[at..at + self.n], &self.y_mem[at..at + self.n])
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Borrowed padded view of this memory (rows `[0, count)` valid).
    pub fn view(&self) -> MemView<'_> {
        MemView {
            s_mem: &self.s_mem,
            y_mem: &self.y_mem,
            count: self.count,
            n: self.n,
        }
    }
}

/// The one push algorithm both memory layouts run: append (s, y) into a
/// padded `[capacity × n]` block whose first `count` slots are valid,
/// rejecting non-positive curvature s·y (standard BFGS safeguard) and
/// ring-evicting the oldest pair once full.  [`CorrectionMemory::push`]
/// hands its whole buffer here; [`BatchCorrectionMemory::push_row`] hands
/// one row's block — identical semantics by construction, which the
/// batched == sequential bit-identity guarantee rests on.
fn push_into(s_mem: &mut [f32], y_mem: &mut [f32], count: &mut usize,
             capacity: usize, n: usize, s: &[f32], y: &[f32]) -> bool {
    assert_eq!(s.len(), n);
    assert_eq!(y.len(), n);
    if dot(s, y) <= EPS {
        return false;
    }
    if *count == capacity {
        // shift left one row (O(capacity·n), every L iterations — cheap
        // relative to the O(b·n) gradient work between pushes)
        s_mem.copy_within(n.., 0);
        y_mem.copy_within(n.., 0);
        *count -= 1;
    }
    let at = *count * n;
    s_mem[at..at + n].copy_from_slice(s);
    y_mem[at..at + n].copy_from_slice(y);
    *count += 1;
    true
}

/// Borrowed view of ONE replication's padded correction memory: `s_mem` /
/// `y_mem` are `[capacity × n]` row-major with rows `[0, count)` valid,
/// oldest first, zero-padded tail — the layout [`CorrectionMemory`] itself
/// stores and the per-row layout of [`BatchCorrectionMemory`]'s dense
/// `[R × capacity × n]` panels.  The Algorithm-4 recursions below run on
/// this view, so the ragged (per-replication) and padded (batched) paths
/// share one implementation and are bit-identical by construction.
#[derive(Debug, Clone, Copy)]
pub struct MemView<'a> {
    pub s_mem: &'a [f32],
    pub y_mem: &'a [f32],
    pub count: usize,
    pub n: usize,
}

impl MemView<'_> {
    pub fn pair(&self, i: usize) -> (&[f32], &[f32]) {
        assert!(i < self.count);
        let at = i * self.n;
        (&self.s_mem[at..at + self.n], &self.y_mem[at..at + self.n])
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// All R replications' correction memories in dense padded panels
/// (DESIGN.md §11): `s_mem` / `y_mem` are row-major `[R × capacity × n]`,
/// row r's pairs sit in `[r·capacity·n, r·capacity·n + counts[r]·n)`
/// oldest first, and the tail of every row block stays zero.  Rows evolve
/// independently under exactly [`CorrectionMemory::push`]'s semantics
/// (curvature rejection, ring eviction), so per-row fill levels are
/// heterogeneous — the padding is what lets ONE batched dispatch apply
/// Algorithm 4 to every replication at once.
#[derive(Debug, Clone)]
pub struct BatchCorrectionMemory {
    s_mem: Vec<f32>,
    y_mem: Vec<f32>,
    counts: Vec<usize>,
    reps: usize,
    capacity: usize,
    n: usize,
}

impl BatchCorrectionMemory {
    pub fn new(reps: usize, capacity: usize, n: usize) -> Self {
        BatchCorrectionMemory {
            s_mem: vec![0.0; reps * capacity * n],
            y_mem: vec![0.0; reps * capacity * n],
            counts: vec![0; reps],
            reps,
            capacity,
            n,
        }
    }

    pub fn reps(&self) -> usize {
        self.reps
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    pub fn count(&self, r: usize) -> usize {
        self.counts[r]
    }

    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    /// Whether row r has accepted at least one pair (the driver falls back
    /// to the plain-gradient step for inactive rows, exactly as the
    /// sequential path does before its memory fills).
    pub fn is_active(&self, r: usize) -> bool {
        self.counts[r] > 0
    }

    pub fn any_active(&self) -> bool {
        self.counts.iter().any(|&c| c > 0)
    }

    /// Append a pair to row r — the SAME [`push_into`] core
    /// [`CorrectionMemory::push`] runs (curvature rejection, ring
    /// eviction), confined to row r's block.
    pub fn push_row(&mut self, r: usize, s: &[f32], y: &[f32]) -> bool {
        assert!(r < self.reps);
        let block = r * self.capacity * self.n
            ..(r + 1) * self.capacity * self.n;
        push_into(&mut self.s_mem[block.clone()],
                  &mut self.y_mem[block], &mut self.counts[r],
                  self.capacity, self.n, s, y)
    }

    /// Row r as a padded per-replication view — the exact input the shared
    /// Algorithm-4 recursions consume.
    pub fn row(&self, r: usize) -> MemView<'_> {
        assert!(r < self.reps);
        let base = r * self.capacity * self.n;
        let block = base..base + self.capacity * self.n;
        MemView {
            s_mem: &self.s_mem[block.clone()],
            y_mem: &self.y_mem[block],
            count: self.counts[r],
            n: self.n,
        }
    }

    /// The dense `[R × capacity × n]` s-panel (zero-padded) — uploaded
    /// as-is to the batched `lr_dir_batch` artifact.
    pub fn s_panel(&self) -> &[f32] {
        &self.s_mem
    }

    /// The dense `[R × capacity × n]` y-panel (zero-padded).
    pub fn y_panel(&self) -> &[f32] {
        &self.y_mem
    }

    /// Borrowed whole-panel view — what [`crate::backend::LrBatchBackend`]
    /// consumes, and what the shard plane slices per shard
    /// (DESIGN.md §13).
    pub fn view(&self) -> BatchMemView<'_> {
        BatchMemView {
            s_mem: &self.s_mem,
            y_mem: &self.y_mem,
            counts: &self.counts,
            capacity: self.capacity,
            n: self.n,
        }
    }
}

/// Borrowed view of a [`BatchCorrectionMemory`] — or of a contiguous
/// shard of its replication rows (`backend::plane`, DESIGN.md §13).  The
/// panels stay dense `[reps × capacity × n]` slices, so a shard's rows
/// are one contiguous sub-slice and a shard view is the exact zero-copy
/// input that shard's inner `direction_batch` dispatch consumes.
#[derive(Debug, Clone, Copy)]
pub struct BatchMemView<'a> {
    s_mem: &'a [f32],
    y_mem: &'a [f32],
    counts: &'a [usize],
    capacity: usize,
    n: usize,
}

impl<'a> BatchMemView<'a> {
    pub fn reps(&self) -> usize {
        self.counts.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    pub fn count(&self, r: usize) -> usize {
        self.counts[r]
    }

    pub fn counts(&self) -> &'a [usize] {
        self.counts
    }

    /// Whether row r has accepted at least one pair (rows that have not
    /// take the plain-gradient step in the driver, exactly as the
    /// sequential path does before its memory fills).
    pub fn is_active(&self, r: usize) -> bool {
        self.counts[r] > 0
    }

    pub fn any_active(&self) -> bool {
        self.counts.iter().any(|&c| c > 0)
    }

    /// Row r as a padded per-replication view — the exact input the
    /// shared Algorithm-4 recursions consume.
    pub fn row(&self, r: usize) -> MemView<'a> {
        assert!(r < self.reps());
        let base = r * self.capacity * self.n;
        let block = base..base + self.capacity * self.n;
        MemView {
            s_mem: &self.s_mem[block.clone()],
            y_mem: &self.y_mem[block],
            count: self.counts[r],
            n: self.n,
        }
    }

    /// The dense `[reps × capacity × n]` s-panel (zero-padded).
    pub fn s_panel(&self) -> &'a [f32] {
        self.s_mem
    }

    /// The dense `[reps × capacity × n]` y-panel (zero-padded).
    pub fn y_panel(&self) -> &'a [f32] {
        self.y_mem
    }

    /// Rows `rows` as their own dense view — contiguous slicing only,
    /// matching the shard plane's partition (`backend::plane::ShardMap`).
    pub fn shard(&self, rows: std::ops::Range<usize>) -> BatchMemView<'a> {
        assert!(rows.start <= rows.end && rows.end <= self.reps(),
                "shard rows out of range");
        let block = self.capacity * self.n;
        BatchMemView {
            s_mem: &self.s_mem[rows.start * block..rows.end * block],
            y_mem: &self.y_mem[rows.start * block..rows.end * block],
            counts: &self.counts[rows],
            capacity: self.capacity,
            n: self.n,
        }
    }
}

/// Algorithm 4, explicit form (the paper's matrix-operation showcase):
/// build the full inverse-Hessian approximation H_t.  O(count·n²)
/// sequential.  Returns the identity when the memory is empty.
pub fn hbuild_explicit(mem: &CorrectionMemory) -> Mat {
    hbuild_explicit_view(mem.view())
}

/// [`hbuild_explicit`] on a padded view — the shared core both the ragged
/// per-replication path and the batched engine's padded rows run, so the
/// two are bit-identical by construction.
pub fn hbuild_explicit_view(mem: MemView<'_>) -> Mat {
    let mut h = Mat::zeros(mem.n, mem.n);
    let mut hy = Vec::new();
    hbuild_explicit_into(mem, &mut h, &mut hy);
    h
}

/// Arena variant of [`hbuild_explicit_view`]: rebuild H_t INTO a
/// caller-owned matrix (reshaped/zeroed in place) with a reusable `hy`
/// scratch.  Every cell is re-initialized per call, so a reused `h` is
/// bitwise-identical to a fresh build — this is what lets the native
/// batch arm's per-row explicit-H caches refresh without reallocating
/// an n×n matrix every L steps.
pub fn hbuild_explicit_into(mem: MemView<'_>, h: &mut Mat,
                            hy: &mut Vec<f32>) {
    let n = mem.n;
    h.rows = n;
    h.cols = n;
    h.data.clear();
    h.data.resize(n * n, 0.0);
    hy.clear();
    hy.resize(n, 0.0);
    if mem.is_empty() {
        for i in 0..n {
            h.set(i, i, 1.0);
        }
        return;
    }
    let (s_l, y_l) = mem.pair(mem.count - 1);
    let gamma = (dot(s_l, y_l) / dot(y_l, y_l).max(EPS)).max(EPS);
    for i in 0..n {
        h.set(i, i, gamma);
    }
    for idx in 0..mem.count {
        let (s, y) = mem.pair(idx);
        let denom = dot(y, s);
        if denom <= EPS {
            continue;
        }
        let rho = 1.0 / denom;
        h.matvec(y, hy); // H is symmetric ⇒ yᵀH = hyᵀ
        let q = dot(y, hy);
        let c2 = rho * rho * q + rho;
        for i in 0..n {
            let si = s[i];
            let hyi = hy[i];
            let row = h.row_mut(i);
            for j in 0..n {
                row[j] += -rho * si * hy[j] - rho * hyi * s[j] + c2 * si * s[j];
            }
        }
    }
}

/// Build H (Algorithm 4) and apply it to `g` in one shot.
pub fn hdir_explicit(mem: &CorrectionMemory, g: &[f32]) -> Vec<f32> {
    let h = hbuild_explicit(mem);
    let mut d = vec![0.0f32; mem.n.max(g.len())];
    d.truncate(g.len());
    h.matvec(g, &mut d);
    d
}

/// L-BFGS two-loop recursion over the same memory (ablation A2); O(count·n).
pub fn hdir_twoloop(mem: &CorrectionMemory, g: &[f32]) -> Vec<f32> {
    hdir_twoloop_view(mem.view(), g)
}

/// [`hdir_twoloop`] on a padded view (see [`hbuild_explicit_view`]).
pub fn hdir_twoloop_view(mem: MemView<'_>, g: &[f32]) -> Vec<f32> {
    let mut scratch = TwoLoopScratch::default();
    let mut out = vec![0.0f32; g.len()];
    hdir_twoloop_into(mem, g, &mut scratch, &mut out);
    out
}

/// Reusable q/alpha/rho buffers for [`hdir_twoloop_into`]; every field is
/// re-initialized per call, so one scratch serves any sequence of views.
#[derive(Debug, Default, Clone)]
pub struct TwoLoopScratch {
    q: Vec<f32>,
    alpha: Vec<f32>,
    rho: Vec<f32>,
}

/// Arena variant of [`hdir_twoloop_view`]: write the two-loop direction
/// INTO a caller-owned slice using caller-owned temporaries.
pub fn hdir_twoloop_into(mem: MemView<'_>, g: &[f32],
                         scratch: &mut TwoLoopScratch, out: &mut [f32]) {
    let n = mem.n;
    assert_eq!(g.len(), n);
    assert_eq!(out.len(), n);
    if mem.is_empty() {
        out.copy_from_slice(g);
        return;
    }
    let q = &mut scratch.q;
    q.clear();
    q.extend_from_slice(g);
    let alpha = &mut scratch.alpha;
    alpha.clear();
    alpha.resize(mem.count, 0.0);
    let rho = &mut scratch.rho;
    rho.clear();
    rho.resize(mem.count, 0.0);
    for i in (0..mem.count).rev() {
        let (s, y) = mem.pair(i);
        let denom = dot(y, s);
        rho[i] = if denom > EPS { 1.0 / denom } else { 0.0 };
        let a = rho[i] * dot(s, q);
        alpha[i] = a;
        for j in 0..n {
            q[j] -= a * y[j];
        }
    }
    let (s_l, y_l) = mem.pair(mem.count - 1);
    let gamma = (dot(s_l, y_l) / dot(y_l, y_l).max(EPS)).max(EPS);
    for (slot, &v) in out.iter_mut().zip(q.iter()) {
        *slot = gamma * v;
    }
    for i in 0..mem.count {
        let (s, y) = mem.pair(i);
        let b = rho[i] * dot(y, out);
        let coef = alpha[i] - b;
        for j in 0..n {
            out[j] += coef * s[j];
        }
    }
}

/// Full-dataset (or subset) mean loss — the convergence metric the RSE trace
/// tracks; sequential row loop.
pub fn full_loss(w: &[f32], x: &[f32], z: &[f32]) -> f64 {
    let n = w.len();
    let rows = z.len();
    let mut total = 0.0f64;
    for i in 0..rows {
        let u = dot(&x[i * n..(i + 1) * n], w);
        total += bce(u, z[i]) as f64;
    }
    total / rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn batch(seed: u64, b: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut p = Philox::new(seed);
        let xb: Vec<f32> = (0..b * n).map(|_| (p.next_u32() & 1) as f32).collect();
        let zb: Vec<f32> = (0..b).map(|_| (p.next_u32() & 1) as f32).collect();
        let w: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.3, 0.3)).collect();
        (xb, zb, w)
    }

    #[test]
    fn sigmoid_and_bce_stability() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) > 0.9999);
        assert!(sigmoid(-100.0) < 1e-4);
        assert!(bce(500.0, 1.0).is_finite());
        assert!(bce(-500.0, 0.0).is_finite());
        assert!(bce(500.0, 0.0) > 100.0);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let (xb, zb, w) = batch(1, 16, 8);
        let mut g = vec![0.0f32; 8];
        grad(&w, &xb, &zb, &mut g);
        let h = 1e-3f32;
        for j in 0..8 {
            let mut wp = w.clone();
            wp[j] += h;
            let mut wm = w.clone();
            wm[j] -= h;
            let mut scratch = vec![0.0f32; 8];
            let fp = grad(&wp, &xb, &zb, &mut scratch);
            let fm = grad(&wm, &xb, &zb, &mut scratch);
            let fd = ((fp - fm) / (2.0 * h as f64)) as f32;
            assert!((g[j] - fd).abs() < 5e-3, "j={} {} vs {}", j, g[j], fd);
        }
    }

    #[test]
    fn hvp_matches_finite_difference_of_grad() {
        let (xb, zb, w) = batch(2, 32, 6);
        let mut p = Philox::new(9);
        let s: Vec<f32> = (0..6).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let mut out = vec![0.0f32; 6];
        hvp(&w, &s, &xb, &mut out);
        let h = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&s).map(|(a, b)| a + h * b).collect();
        let wm: Vec<f32> = w.iter().zip(&s).map(|(a, b)| a - h * b).collect();
        let mut gp = vec![0.0f32; 6];
        let mut gm = vec![0.0f32; 6];
        grad(&wp, &xb, &zb, &mut gp);
        grad(&wm, &xb, &zb, &mut gm);
        for j in 0..6 {
            let fd = (gp[j] - gm[j]) / (2.0 * h);
            assert!((out[j] - fd).abs() < 5e-3, "j={} {} vs {}", j, out[j], fd);
        }
    }

    #[test]
    fn memory_ring_semantics() {
        let mut mem = CorrectionMemory::new(3, 2);
        assert!(mem.is_empty());
        for t in 0..5 {
            let s = vec![1.0 + t as f32, 0.0];
            let y = vec![1.0, 0.5];
            assert!(mem.push(&s, &y));
        }
        assert_eq!(mem.count, 3);
        // oldest evicted: remaining pairs are t = 2, 3, 4
        assert_eq!(mem.pair(0).0[0], 3.0);
        assert_eq!(mem.pair(2).0[0], 5.0);
    }

    #[test]
    fn memory_rejects_nonpositive_curvature() {
        let mut mem = CorrectionMemory::new(2, 2);
        assert!(!mem.push(&[1.0, 0.0], &[-1.0, 0.0]));
        assert!(!mem.push(&[1.0, 0.0], &[0.0, 1.0])); // s·y = 0
        assert!(mem.is_empty());
    }

    #[test]
    fn batch_memory_rows_match_ragged_memories() {
        // Heterogeneous pushes per row must leave every row bit-identical
        // to an independently maintained CorrectionMemory.
        let (reps, cap, n) = (4usize, 3usize, 2usize);
        let mut batch = BatchCorrectionMemory::new(reps, cap, n);
        let mut ragged: Vec<CorrectionMemory> =
            (0..reps).map(|_| CorrectionMemory::new(cap, n)).collect();
        // row r receives r + 2 pushes: row 0 partial … row 3 wraps the ring
        for r in 0..reps {
            for t in 0..r + 2 {
                let s = vec![1.0 + (r * 7 + t) as f32, 0.5];
                let y = vec![1.0, 0.25 + t as f32 * 0.5];
                assert_eq!(batch.push_row(r, &s, &y), ragged[r].push(&s, &y));
            }
        }
        for r in 0..reps {
            let row = batch.row(r);
            assert_eq!(row.count, ragged[r].count, "row {}", r);
            let take = row.count * n;
            assert_eq!(&row.s_mem[..take], &ragged[r].s_mem[..take]);
            assert_eq!(&row.y_mem[..take], &ragged[r].y_mem[..take]);
        }
        assert!(batch.any_active());
    }

    #[test]
    fn batch_memory_rejects_and_pads_like_ragged() {
        let mut batch = BatchCorrectionMemory::new(2, 3, 2);
        // non-positive curvature rejected, row stays inactive
        assert!(!batch.push_row(0, &[1.0, 0.0], &[-1.0, 0.0]));
        assert!(!batch.is_active(0));
        assert!(!batch.any_active());
        // a partial row keeps its padded tail at exactly zero (the batched
        // artifact contract: invalid slots are masked, padding stays 0)
        assert!(batch.push_row(1, &[1.0, 0.0], &[2.0, 0.0]));
        let row = batch.row(1);
        assert_eq!(row.count, 1);
        assert!(row.s_mem[2..].iter().all(|&v| v == 0.0));
        assert!(row.y_mem[2..].iter().all(|&v| v == 0.0));
        // panels expose the dense [R × cap × n] layout
        assert_eq!(batch.s_panel().len(), 2 * 3 * 2);
        assert_eq!(batch.s_panel()[3 * 2], 1.0); // row 1, slot 0, j 0
    }

    #[test]
    fn batch_memory_shard_views_are_zero_copy_row_windows() {
        // The shard plane's contract (DESIGN.md §13): a contiguous shard
        // of a BatchMemView is itself a dense view whose rows, counts,
        // and panels match the whole-panel view's corresponding rows.
        let (reps, cap, n) = (5usize, 2usize, 3usize);
        let mut batch = BatchCorrectionMemory::new(reps, cap, n);
        for r in 1..reps {
            for t in 0..r {
                let s = vec![1.0 + (r + t) as f32; n];
                let y = vec![0.5 + t as f32; n];
                batch.push_row(r, &s, &y);
            }
        }
        let whole = batch.view();
        assert_eq!(whole.reps(), reps);
        assert_eq!(whole.counts(), batch.counts());
        let shard = whole.shard(2..5);
        assert_eq!(shard.reps(), 3);
        assert_eq!(shard.capacity(), cap);
        assert_eq!(shard.dim(), n);
        assert_eq!(shard.counts(), &whole.counts()[2..5]);
        assert!(shard.is_active(0) && shard.any_active());
        for (local, global) in (2..5).enumerate() {
            let a = shard.row(local);
            let b = whole.row(global);
            assert_eq!(a.count, b.count, "row {}", global);
            assert_eq!(a.s_mem, b.s_mem);
            assert_eq!(a.y_mem, b.y_mem);
        }
        // the shard's panels are the contiguous sub-slices of the dense
        // layout (what a shard's XLA dispatch uploads verbatim)
        let block = cap * n;
        assert_eq!(shard.s_panel(), &whole.s_panel()[2 * block..5 * block]);
        assert_eq!(shard.y_panel(), &whole.y_panel()[2 * block..5 * block]);
        // a row-0 shard of untouched rows is inactive
        assert!(!whole.shard(0..1).any_active());
    }

    #[test]
    fn view_recursions_match_ragged_entrypoints() {
        let mut p = Philox::new(13);
        let n = 6;
        let mut mem = CorrectionMemory::new(4, n);
        for _ in 0..3 {
            let s: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            let y: Vec<f32> = s.iter().map(|&v| 1.5 * v + 0.01).collect();
            mem.push(&s, &y);
        }
        let g: Vec<f32> = (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let h_a = hbuild_explicit(&mem);
        let h_b = hbuild_explicit_view(mem.view());
        assert_eq!(h_a.data, h_b.data);
        assert_eq!(hdir_twoloop(&mem, &g), hdir_twoloop_view(mem.view(), &g));
    }

    #[test]
    fn into_recursions_with_reused_arenas_are_bitwise() {
        let mut p = Philox::new(17);
        let n = 6;
        // Reused arenas across four views of growing count (incl. empty).
        let mut h = Mat::zeros(1, 1);
        let mut hy = Vec::new();
        let mut scratch = TwoLoopScratch::default();
        let mut out = vec![0.0f32; n];
        let mut mem = CorrectionMemory::new(4, n);
        for round in 0..4 {
            let g: Vec<f32> =
                (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
            let h_fresh = hbuild_explicit_view(mem.view());
            hbuild_explicit_into(mem.view(), &mut h, &mut hy);
            assert_eq!(h_fresh.rows, h.rows);
            for (a, b) in h_fresh.data.iter().zip(&h.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {}", round);
            }
            let d_fresh = hdir_twoloop_view(mem.view(), &g);
            hdir_twoloop_into(mem.view(), &g, &mut scratch, &mut out);
            for (a, b) in d_fresh.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {}", round);
            }
            let s: Vec<f32> =
                (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            let y: Vec<f32> = s.iter().map(|&v| 1.5 * v + 0.01).collect();
            mem.push(&s, &y);
        }
    }

    #[test]
    fn explicit_and_twoloop_agree() {
        let mut p = Philox::new(5);
        let n = 10;
        let mut mem = CorrectionMemory::new(4, n);
        for _ in 0..4 {
            let s: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            // y = s + small SPD-ish perturbation keeps curvature positive
            let y: Vec<f32> = s.iter().map(|&v| 1.5 * v + 0.01).collect();
            if dot(&s, &y) > 0.0 {
                mem.push(&s, &y);
            }
        }
        assert!(mem.count >= 2);
        let g: Vec<f32> = (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let d1 = hdir_explicit(&mem, &g);
        let d2 = hdir_twoloop(&mem, &g);
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() < 2e-3, "{} vs {}", a, b);
        }
    }

    #[test]
    fn empty_memory_returns_gradient() {
        let mem = CorrectionMemory::new(4, 3);
        let g = vec![1.0f32, -2.0, 3.0];
        assert_eq!(hdir_explicit(&mem, &g), g);
        assert_eq!(hdir_twoloop(&mem, &g), g);
    }

    #[test]
    fn direction_is_descent() {
        let mut p = Philox::new(7);
        let n = 8;
        let mut mem = CorrectionMemory::new(3, n);
        for _ in 0..3 {
            let s: Vec<f32> = (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            let y: Vec<f32> = s.iter().map(|&v| 2.0 * v).collect();
            mem.push(&s, &y);
        }
        let g: Vec<f32> = (0..n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
        let d = hdir_explicit(&mem, &g);
        assert!(dot(&g, &d) > 0.0, "H must be positive definite on g");
    }

    #[test]
    fn full_loss_decreases_under_gd() {
        let (xb, zb, mut w) = batch(11, 64, 8);
        let before = full_loss(&w, &xb, &zb);
        let mut g = vec![0.0f32; 8];
        for _ in 0..20 {
            grad(&w, &xb, &zb, &mut g);
            for j in 0..8 {
                w[j] -= 0.5 * g[j];
            }
        }
        let after = full_loss(&w, &xb, &zb);
        assert!(after < before);
    }
}
