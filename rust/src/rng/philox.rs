//! Philox4x32-10 counter-based RNG (Salmon, Moraes, Dror, Shaw; SC'11).
//!
//! Stateless in the cryptographic sense: output block i is a pure function
//! of (key, counter=i).  This gives us O(1) jump-ahead, trivially
//! independent streams per (replication, epoch), and bit-reproducible runs
//! regardless of threading — the properties the L'Ecuyer et al. (2017) GPU
//! RNG survey calls out and that JAX's own threefry shares.

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;
const ROUNDS: usize = 10;

/// Iterator-style wrapper around the Philox block function.
#[derive(Debug, Clone)]
pub struct Philox {
    key: [u32; 2],
    counter: u64,
    /// Buffered outputs from the current block.
    buf: [u32; 4],
    buf_pos: usize,
}

#[inline]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// The raw Philox4x32-10 block function: 4 output words per (key, counter).
pub fn philox4x32(key: [u32; 2], counter: [u32; 4]) -> [u32; 4] {
    let mut c = counter;
    let mut k = key;
    for _ in 0..ROUNDS {
        let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
        let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
        c = [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0];
        k = [k[0].wrapping_add(PHILOX_W0), k[1].wrapping_add(PHILOX_W1)];
    }
    c
}

impl Philox {
    pub fn new(seed: u64) -> Self {
        Philox {
            key: [(seed >> 32) as u32, seed as u32],
            counter: 0,
            buf: [0; 4],
            buf_pos: 4, // force refill
        }
    }

    /// Same key, but starting at an arbitrary block — O(1) jump-ahead.
    pub fn at_block(seed: u64, block: u64) -> Self {
        let mut p = Self::new(seed);
        p.counter = block;
        p
    }

    pub fn key(&self) -> [u32; 2] {
        self.key
    }

    fn refill(&mut self) {
        let ctr = [self.counter as u32, (self.counter >> 32) as u32, 0, 0];
        self.buf = philox4x32(self.key, ctr);
        self.counter = self.counter.wrapping_add(1);
        self.buf_pos = 0;
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.buf_pos >= 4 {
            self.refill();
        }
        let v = self.buf[self.buf_pos];
        self.buf_pos += 1;
        v
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (f32-grade).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Uniform integer in [0, n) by rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut scratch = SampleScratch::for_draws(n, k);
        let mut out = vec![0usize; k];
        self.sample_indices_into(n, &mut scratch, &mut out);
        out
    }

    /// Arena variant of [`Philox::sample_indices`]: writes `out.len()`
    /// distinct indices from [0, n) into `out` using caller-owned scratch.
    ///
    /// The draw sequence is identical to `sample_indices` — both branches
    /// consume the same `below` calls in the same order (set membership and
    /// swap targets do not depend on scratch layout), so a reused scratch is
    /// bitwise-equivalent to a fresh one.  With a scratch sized by
    /// [`SampleScratch::for_draws`], steady-state calls touch no heap.
    pub fn sample_indices_into(&mut self, n: usize,
                               scratch: &mut SampleScratch,
                               out: &mut [usize]) {
        let k = out.len();
        assert!(k <= n, "cannot sample {} from {}", k, n);
        // For small k relative to n use a set-based draw; else shuffle.
        if k * 8 < n {
            scratch.seen.clear();
            let mut filled = 0;
            while filled < k {
                let i = self.below(n as u32) as usize;
                if scratch.seen.insert(i) {
                    out[filled] = i;
                    filled += 1;
                }
            }
        } else {
            scratch.idx.clear();
            scratch.idx.extend(0..n);
            for i in 0..k {
                let j = i + self.below((n - i) as u32) as usize;
                scratch.idx.swap(i, j);
            }
            out.copy_from_slice(&scratch.idx[..k]);
        }
    }
}

/// Reusable scratch for [`Philox::sample_indices_into`].  The rejection set
/// never holds more than `k` entries and the shuffle buffer never more than
/// `n`, so a scratch built by [`SampleScratch::for_draws`] is allocation-free
/// for every subsequent draw of the same (or smaller) shape.
#[derive(Debug, Default)]
pub struct SampleScratch {
    seen: std::collections::HashSet<usize>,
    idx: Vec<usize>,
}

impl SampleScratch {
    pub fn for_draws(n: usize, k: usize) -> Self {
        let mut s = SampleScratch::default();
        s.seen.reserve(k * 2);
        if !(k * 8 < n) {
            s.idx.reserve(n);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_zero_key_zero_counter() {
        // Philox4x32-10 with key=0, ctr=0 — reference value from the
        // Random123 distribution's kat_vectors.
        let out = philox4x32([0, 0], [0, 0, 0, 0]);
        assert_eq!(out, [0x6627_e8d5, 0xe169_c58d, 0xbc57_ac4c, 0x9b00_dbd8]);
    }

    #[test]
    fn known_answer_ff_key() {
        // key=ff.., ctr=ff.. from Random123 kat_vectors.
        let out = philox4x32(
            [0xffff_ffff, 0xffff_ffff],
            [0xffff_ffff, 0xffff_ffff, 0xffff_ffff, 0xffff_ffff],
        );
        assert_eq!(out, [0x408f_276d, 0x41c8_3b0e, 0xa20b_c7c6, 0x6d54_51fd]);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Philox::new(42);
        let mut b = Philox::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Philox::new(1);
        let mut b = Philox::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn jump_ahead_matches_sequential() {
        let mut seq = Philox::new(9);
        for _ in 0..8 {
            seq.next_u32(); // consume blocks 0..2 (4 words per block)
        }
        let mut jumped = Philox::at_block(9, 2);
        assert_eq!(seq.next_u32(), jumped.next_u32());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut p = Philox::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {}", var);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut p = Philox::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[p.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {}", c);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut p = Philox::new(5);
        for (n, k) in [(100, 5), (50, 50), (1000, 100)] {
            let idx = p.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    #[should_panic(expected = "cannot sample")]
    fn sample_more_than_population_panics() {
        Philox::new(0).sample_indices(3, 4);
    }

    #[test]
    fn sample_indices_into_matches_allocating_variant() {
        // (100, 5) takes the set branch, (50, 50) and (1000, 100) exercise
        // both Fisher-Yates and the borderline; one REUSED scratch across
        // all shapes must still reproduce the fresh-scratch draws exactly.
        let mut scratch = SampleScratch::default();
        for (n, k) in [(100usize, 5usize), (50, 50), (1000, 100), (100, 5)] {
            let mut a = Philox::new(11);
            let mut b = Philox::new(11);
            let want = a.sample_indices(n, k);
            let mut got = vec![0usize; k];
            b.sample_indices_into(n, &mut scratch, &mut got);
            assert_eq!(want, got, "n={} k={}", n, k);
            assert_eq!(a.next_u32(), b.next_u32(),
                       "stream positions diverged at n={} k={}", n, k);
        }
    }
}
