//! Gaussian sampling over Philox uniforms (Box-Muller, pair-cached).
//!
//! Box-Muller rather than ziggurat: branch-free inner math, no tables, and
//! statistically exact — at the sample counts the paper's tasks use
//! (25-600 per estimate) generation is never the bottleneck; see
//! `benches/micro_substrates.rs` for the measured cost.

use super::philox::Philox;

/// Pair-caching standard-normal sampler.
#[derive(Debug, Clone)]
pub struct NormalSampler {
    rng: Philox,
    spare: Option<f32>,
}

impl NormalSampler {
    pub fn new(rng: Philox) -> Self {
        NormalSampler { rng, spare: None }
    }

    pub fn from_seed(seed: u64) -> Self {
        Self::new(Philox::new(seed))
    }

    /// One standard normal draw.
    #[inline]
    pub fn next(&mut self) -> f32 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Box-Muller on (0,1] × [0,1) uniforms
        let u1 = 1.0 - self.rng.next_f32(); // (0, 1]
        let u2 = self.rng.next_f32();
        let r = (-2.0 * (u1 as f64).ln()).sqrt() as f32;
        let theta = 2.0 * std::f32::consts::PI * u2;
        let (s, c) = theta.sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// N(mu, sigma²) draw.
    #[inline]
    pub fn normal(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.next()
    }

    /// Fill `out` with one row per sample: out[s*d + j] ~ N(mu[j], sigma[j]²).
    /// This is the CPU-sequential analogue of the in-graph panel sampling the
    /// XLA artifacts perform.
    pub fn fill_panel(&mut self, mu: &[f32], sigma: &[f32], samples: usize,
                      out: &mut [f32]) {
        let d = mu.len();
        assert_eq!(sigma.len(), d);
        assert_eq!(out.len(), samples * d);
        for s in 0..samples {
            let row = &mut out[s * d..(s + 1) * d];
            for j in 0..d {
                row[j] = self.normal(mu[j], sigma[j]);
            }
        }
    }

    pub fn rng_mut(&mut self) -> &mut Philox {
        self.spare = None; // interleaving raw draws invalidates the cache
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_match_standard_normal() {
        let mut s = NormalSampler::from_seed(11);
        let n = 200_000;
        let (mut m1, mut m2, mut m3, mut m4) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for _ in 0..n {
            let x = s.next() as f64;
            m1 += x;
            m2 += x * x;
            m3 += x * x * x;
            m4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.01, "mean {}", m1 / nf);
        assert!((m2 / nf - 1.0).abs() < 0.02, "var {}", m2 / nf);
        assert!((m3 / nf).abs() < 0.05, "skew {}", m3 / nf);
        assert!((m4 / nf - 3.0).abs() < 0.1, "kurtosis {}", m4 / nf);
    }

    #[test]
    fn location_scale() {
        let mut s = NormalSampler::from_seed(3);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let x = s.normal(40.0, 5.0) as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 40.0).abs() < 0.1);
        assert!((var - 25.0).abs() < 0.6);
    }

    #[test]
    fn deterministic() {
        let mut a = NormalSampler::from_seed(5);
        let mut b = NormalSampler::from_seed(5);
        for _ in 0..64 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn panel_shape_and_columns() {
        let mu = [0.0f32, 100.0];
        let sigma = [1.0f32, 0.0];
        let samples = 1000;
        let mut out = vec![0.0f32; samples * 2];
        NormalSampler::from_seed(8).fill_panel(&mu, &sigma, samples, &mut out);
        // sigma=0 column is exactly mu
        for s in 0..samples {
            assert_eq!(out[s * 2 + 1], 100.0);
        }
        let col0_mean: f32 = (0..samples).map(|s| out[s * 2]).sum::<f32>() / samples as f32;
        assert!(col0_mean.abs() < 0.15);
    }

    #[test]
    fn all_finite() {
        let mut s = NormalSampler::from_seed(999);
        for _ in 0..100_000 {
            assert!(s.next().is_finite());
        }
    }
}
