//! Counter-based random numbers for reproducible, parallel simulation.
//!
//! The paper's experiments hinge on common random numbers across the CPU and
//! GPU arms ("apart from the computation hardware, all other parameters
//! remain the same").  We reproduce that discipline with:
//!
//! * [`Philox`] — Philox4x32-10 (Salmon et al. 2011), the same family JAX's
//!   threefry belongs to: stateless, counter-indexed, splittable.
//! * [`normal`] — Box-Muller transform over Philox uniforms.
//! * [`StreamTree`] — a hierarchical seed derivation
//!   (experiment → replication → epoch) so every Monte-Carlo panel has an
//!   independent, reconstructible stream, and the XLA backend receives a
//!   unique in-graph threefry key per (replication, epoch).

pub mod normal;
pub mod philox;
pub mod streams;

pub use normal::NormalSampler;
pub use philox::{Philox, SampleScratch};
pub use streams::StreamTree;
