//! Hierarchical stream derivation: experiment → replication → epoch.
//!
//! Every Monte-Carlo panel in the system draws from a stream addressed by a
//! path of indices under a root seed.  The same path always yields the same
//! stream, so (a) replications are independent, (b) a run is reproducible
//! from `(seed, path)` alone, and (c) the native and XLA backends can be
//! paired on common random numbers at the *stream* level (the XLA side uses
//! the derived 64 bits as its in-graph threefry key).

use super::philox::{philox4x32, Philox};
use super::NormalSampler;

/// Root of the derivation hierarchy.
#[derive(Debug, Clone, Copy)]
pub struct StreamTree {
    seed: u64,
}

impl StreamTree {
    pub fn new(seed: u64) -> Self {
        StreamTree { seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Derive the 64-bit child seed at `path` by iterated Philox mixing —
    /// each level feeds (parent_hi, parent_lo) as the key and the path index
    /// as the counter.
    pub fn derive(&self, path: &[u64]) -> u64 {
        let mut state = self.seed;
        for (level, &ix) in path.iter().enumerate() {
            let key = [(state >> 32) as u32, state as u32];
            let ctr = [ix as u32, (ix >> 32) as u32, level as u32, 0x5eed];
            let out = philox4x32(key, ctr);
            state = (out[0] as u64) << 32 | out[1] as u64;
        }
        state
    }

    /// A Philox stream at `path`.
    pub fn stream(&self, path: &[u64]) -> Philox {
        Philox::new(self.derive(path))
    }

    /// A Gaussian sampler at `path`.
    pub fn normal(&self, path: &[u64]) -> NormalSampler {
        NormalSampler::new(self.stream(path))
    }

    /// The 2×u32 key handed to an XLA artifact as its in-graph threefry key
    /// for `path` (JAX accepts arbitrary raw key data).
    pub fn jax_key(&self, path: &[u64]) -> [u32; 2] {
        let s = self.derive(path);
        [(s >> 32) as u32, s as u32]
    }

    /// Sub-tree rooted at `path` (e.g. one replication's tree).
    pub fn subtree(&self, path: &[u64]) -> StreamTree {
        StreamTree::new(self.derive(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn derivation_is_deterministic() {
        let t = StreamTree::new(42);
        assert_eq!(t.derive(&[1, 2, 3]), t.derive(&[1, 2, 3]));
    }

    #[test]
    fn sibling_paths_distinct() {
        let t = StreamTree::new(7);
        let mut seen = HashSet::new();
        for rep in 0..100u64 {
            for epoch in 0..20u64 {
                assert!(seen.insert(t.derive(&[rep, epoch])),
                        "collision at ({}, {})", rep, epoch);
            }
        }
    }

    #[test]
    fn path_is_not_flattenable() {
        // [1,2] must differ from [2,1] and from [1] then [2] at another root
        let t = StreamTree::new(3);
        assert_ne!(t.derive(&[1, 2]), t.derive(&[2, 1]));
        assert_ne!(t.derive(&[1, 2]), t.derive(&[12]));
        assert_ne!(t.derive(&[0]), t.derive(&[0, 0]));
    }

    #[test]
    fn subtree_consistency() {
        let t = StreamTree::new(99);
        let sub = t.subtree(&[4]);
        assert_eq!(sub.derive(&[5]), t.subtree(&[4]).derive(&[5]));
        // different subtrees diverge
        assert_ne!(t.subtree(&[4]).derive(&[5]), t.subtree(&[5]).derive(&[5]));
    }

    #[test]
    fn jax_key_roundtrips_seed_bits() {
        let t = StreamTree::new(1);
        let s = t.derive(&[6, 7]);
        let k = t.jax_key(&[6, 7]);
        assert_eq!((k[0] as u64) << 32 | k[1] as u64, s);
    }

    #[test]
    fn streams_at_distinct_paths_are_uncorrelated() {
        let t = StreamTree::new(1234);
        let mut a = t.stream(&[0]);
        let mut b = t.stream(&[1]);
        let n = 10_000;
        let mut dot = 0.0f64;
        for _ in 0..n {
            dot += (a.next_f64() - 0.5) * (b.next_f64() - 0.5);
        }
        // correlation ≈ dot / (n/12); should be tiny
        let corr = dot / (n as f64 / 12.0);
        assert!(corr.abs() < 0.05, "corr {}", corr);
    }

    #[test]
    fn different_roots_differ() {
        assert_ne!(StreamTree::new(1).derive(&[0]), StreamTree::new(2).derive(&[0]));
    }
}
