//! Cache-blocked, multi-accumulator kernels for the optimized-native
//! ablation (A3): same math as [`super::matrix`], restructured so the
//! compiler can keep four independent dependency chains in flight and the
//! working set stays in L1/L2.
//!
//! These quantify how much of the paper's GPU speedup a *tuned* CPU kernel
//! recovers — separating "vectorized execution" from "better scheduling".

use super::matrix::Mat;

/// Dot product with 4 independent f64 accumulators (ILP-friendly).
#[inline]
pub fn dot4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as f64 * b[i] as f64;
        s1 += a[i + 1] as f64 * b[i + 1] as f64;
        s2 += a[i + 2] as f64 * b[i + 2] as f64;
        s3 += a[i + 3] as f64 * b[i + 3] as f64;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        tail += a[i] as f64 * b[i] as f64;
    }
    ((s0 + s1) + (s2 + s3) + tail) as f32
}

/// y = A x with row blocking (block of 4 rows shares the x streaming pass).
pub fn matvec_blocked(a: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols);
    assert_eq!(y.len(), a.rows);
    let rb = a.rows / 4 * 4;
    let cols = a.cols;
    let mut i = 0;
    while i < rb {
        let r0 = &a.data[i * cols..(i + 1) * cols];
        let r1 = &a.data[(i + 1) * cols..(i + 2) * cols];
        let r2 = &a.data[(i + 2) * cols..(i + 3) * cols];
        let r3 = &a.data[(i + 3) * cols..(i + 4) * cols];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for j in 0..cols {
            let xj = x[j] as f64;
            s0 += r0[j] as f64 * xj;
            s1 += r1[j] as f64 * xj;
            s2 += r2[j] as f64 * xj;
            s3 += r3[j] as f64 * xj;
        }
        y[i] = s0 as f32;
        y[i + 1] = s1 as f32;
        y[i + 2] = s2 as f32;
        y[i + 3] = s3 as f32;
        i += 4;
    }
    for i in rb..a.rows {
        y[i] = dot4(a.row(i), x);
    }
}

/// y = Aᵀ x with 4-row unrolling of the accumulation loop.
pub fn matvec_t_blocked(a: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.rows);
    assert_eq!(y.len(), a.cols);
    y.fill(0.0);
    let cols = a.cols;
    let rb = a.rows / 4 * 4;
    let mut i = 0;
    while i < rb {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        let r0 = &a.data[i * cols..(i + 1) * cols];
        let r1 = &a.data[(i + 1) * cols..(i + 2) * cols];
        let r2 = &a.data[(i + 2) * cols..(i + 3) * cols];
        let r3 = &a.data[(i + 3) * cols..(i + 4) * cols];
        for j in 0..cols {
            y[j] += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
        }
        i += 4;
    }
    for i in rb..a.rows {
        let xi = x[i];
        let row = a.row(i);
        for j in 0..cols {
            y[j] += xi * row[j];
        }
    }
}

/// C = A·B with i-k-j loop order and 64×64×64 cache tiling.
pub fn matmul_blocked(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    const T: usize = 64;
    let mut c = Mat::zeros(a.rows, b.cols);
    for ii in (0..a.rows).step_by(T) {
        for kk in (0..a.cols).step_by(T) {
            for jj in (0..b.cols).step_by(T) {
                let i_hi = (ii + T).min(a.rows);
                let k_hi = (kk + T).min(a.cols);
                let j_hi = (jj + T).min(b.cols);
                for i in ii..i_hi {
                    for k in kk..k_hi {
                        let aik = a.get(i, k);
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = b.row(k);
                        let crow = c.row_mut(i);
                        for j in jj..j_hi {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut p = Philox::new(seed);
        Mat::from_vec(r, c, (0..r * c).map(|_| p.uniform_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn dot4_matches_naive() {
        let mut p = Philox::new(1);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..n).map(|_| p.uniform_f32(-2.0, 2.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| p.uniform_f32(-2.0, 2.0)).collect();
            let want = crate::linalg::vector::dot(&a, &b);
            assert!((dot4(&a, &b) - want).abs() <= 1e-4 * want.abs().max(1.0));
        }
    }

    #[test]
    fn matvec_blocked_matches_naive() {
        for (r, c) in [(1, 5), (4, 8), (7, 16), (33, 65)] {
            let m = rand_mat(2, r, c);
            let x: Vec<f32> = (0..c).map(|i| (i as f32).sin()).collect();
            let mut y1 = vec![0.0; r];
            let mut y2 = vec![0.0; r];
            m.matvec(&x, &mut y1);
            matvec_blocked(&m, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
            }
        }
    }

    #[test]
    fn matvec_t_blocked_matches_naive() {
        for (r, c) in [(1, 5), (4, 8), (9, 3), (33, 65)] {
            let m = rand_mat(3, r, c);
            let x: Vec<f32> = (0..r).map(|i| (i as f32).cos()).collect();
            let mut y1 = vec![0.0; c];
            let mut y2 = vec![0.0; c];
            m.matvec_t(&x, &mut y1);
            matvec_t_blocked(&m, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_blocked_matches_naive() {
        for (r, k, c) in [(3, 4, 5), (64, 64, 64), (65, 70, 63)] {
            let a = rand_mat(4, r, k);
            let b = rand_mat(5, k, c);
            let want = a.matmul(&b);
            let got = matmul_blocked(&a, &b);
            for (x, y) in want.data.iter().zip(&got.data) {
                assert!((x - y).abs() < 1e-3, "{} vs {}", x, y);
            }
        }
    }
}
