//! Dense row-major matrix and the sequential matvec/matmul kernels used by
//! the native backend (the paper's "CPU" arm).

use super::vector::dot;

/// Row-major dense matrix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// y = A x (sequential, row-by-row).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    /// y = Aᵀ x without forming the transpose (accumulate down columns).
    pub fn matvec_t(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for j in 0..self.cols {
                y[j] += xi * row[j];
            }
        }
    }

    /// C = A·B (naive triple loop with row-major friendly ordering).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = c.row_mut(i);
                for j in 0..b.cols {
                    crow[j] += aik * brow[j];
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.get(i, j);
            }
        }
        t
    }

    /// Column means — R̄ in Algorithm 1's panel.
    pub fn col_means(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        self.col_means_into(&mut out);
        out
    }

    /// Column means written into a caller-owned buffer — the arena variant
    /// of [`Mat::col_means`].  Each column accumulates in f64 over ascending
    /// rows (columns are independent, so per-column scalar accumulation is
    /// the same addition sequence the row-major pass performs), hence the
    /// two variants are bitwise-identical.
    pub fn col_means_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols);
        let denom = self.rows.max(1) as f64;
        for (j, slot) in out.iter_mut().enumerate() {
            let mut s = 0.0f64;
            for i in 0..self.rows {
                s += self.data[i * self.cols + j] as f64;
            }
            *slot = (s / denom) as f32;
        }
    }

    /// Subtract `mu` from every row in place — panel centering.
    pub fn center_rows(&mut self, mu: &[f32]) {
        assert_eq!(mu.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for j in 0..self.cols {
                row[j] -= mu[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Mat {
        Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]])
    }

    #[test]
    fn constructors() {
        let m = sample();
        assert_eq!((m.rows, m.cols), (2, 3));
        assert_eq!(m.get(1, 2), 6.0);
        let e = Mat::eye(3);
        assert_eq!(e.get(1, 1), 1.0);
        assert_eq!(e.get(0, 1), 0.0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        Mat::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn matvec_known() {
        let m = sample();
        let mut y = [0.0f32; 2];
        m.matvec(&[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, [-2.0, -2.0]);
    }

    #[test]
    fn matvec_t_equals_transpose_matvec() {
        let m = sample();
        let x = [2.0f32, -3.0];
        let mut y1 = [0.0f32; 3];
        m.matvec_t(&x, &mut y1);
        let t = m.transpose();
        let mut y2 = [0.0f32; 3];
        t.matvec(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn matmul_identity() {
        let m = sample();
        let e = Mat::eye(3);
        assert_eq!(m.matmul(&e), m);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn col_means_into_is_bitwise_col_means() {
        let m = Mat::from_rows(vec![
            vec![1.0e-3, 2.5, -3.75],
            vec![0.125, 5.0, 6.5],
            vec![9.25, -0.5, 0.0625],
        ]);
        let want = m.col_means();
        let mut got = vec![f32::NAN; 3];
        m.col_means_into(&mut got);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn centering_zeroes_means() {
        let mut m = sample();
        let mu = m.col_means();
        assert_eq!(mu, vec![2.5, 3.5, 4.5]);
        m.center_rows(&mu);
        for v in m.col_means() {
            assert!(v.abs() < 1e-6);
        }
    }
}
