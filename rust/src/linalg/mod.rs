//! Dense linear algebra for the native (CPU) backend.
//!
//! Two tiers, mirroring the paper's CPU-vs-GPU framing:
//! * [`vector`] / [`matrix`] — straightforward sequential implementations
//!   (the "CPU processes each sample individually" arm);
//! * [`blocked`] — cache-blocked, multi-accumulator versions used by the
//!   `native_par`/optimized ablation (A3) to separate *CPU parallelism*
//!   from *vectorized execution* in the speedup attribution.

pub mod blocked;
pub mod matrix;
pub mod vector;

pub use matrix::Mat;
