//! Sequential vector kernels (f32 storage, f64 accumulation where it guards
//! against catastrophic cancellation at the panel sizes the paper sweeps).

/// Dot product with f64 accumulator.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc as f32
}

/// y ← y + alpha·x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y ← alpha·x + beta·y
#[inline]
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = alpha * x[i] + beta * y[i];
    }
}

#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

#[inline]
pub fn add_assign(x: &[f32], y: &mut [f32]) {
    axpy(1.0, x, y);
}

#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

pub fn norm2(a: &[f32]) -> f32 {
    (dot(a, a) as f64).sqrt() as f32
}

pub fn linf(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

pub fn sum(a: &[f32]) -> f32 {
    a.iter().map(|&v| v as f64).sum::<f64>() as f32
}

/// Index of the minimum element (first on ties); None for empty input.
pub fn argmin(a: &[f32]) -> Option<usize> {
    if a.is_empty() {
        return None;
    }
    let mut best = 0;
    for i in 1..a.len() {
        if a[i] < a[best] {
            best = i;
        }
    }
    Some(best)
}

/// Max |a-b| — the tolerance check for cross-backend agreement tests.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs()))
}

/// FW iterate update  w ← w + γ(s − w)  (Algorithm 1 line 10), in place.
pub fn fw_update(w: &mut [f32], s: &[f32], gamma: f32) {
    debug_assert_eq!(w.len(), s.len());
    for i in 0..w.len() {
        w[i] += gamma * (s[i] - w[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_cancellation_resistant() {
        // f32-naive summation of [1e8, 1, -1e8] * [1,1,1] loses the 1.
        let a = [1e8f32, 1.0, -1e8];
        let b = [1.0f32, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 1.0);
    }

    #[test]
    fn axpy_axpby() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
    }

    #[test]
    fn norms_and_sums() {
        let v = [3.0f32, -4.0];
        assert_eq!(norm2(&v), 5.0);
        assert_eq!(linf(&v), 4.0);
        assert_eq!(sum(&v), -1.0);
    }

    #[test]
    fn argmin_cases() {
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(argmin(&[1.0, 1.0]), Some(0)); // first on ties
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn fw_update_is_convex_combination() {
        let mut w = [0.5f32, 0.5];
        fw_update(&mut w, &[1.0, 0.0], 0.25);
        assert!((w[0] - 0.625).abs() < 1e-7);
        assert!((w[1] - 0.375).abs() < 1e-7);
        // gamma=0 no-op, gamma=1 jumps to s
        let mut w2 = [0.3f32, 0.7];
        fw_update(&mut w2, &[1.0, 0.0], 0.0);
        assert_eq!(w2, [0.3, 0.7]);
        fw_update(&mut w2, &[1.0, 0.0], 1.0);
        assert_eq!(w2, [1.0, 0.0]);
    }

    #[test]
    fn diff_helpers() {
        let mut out = [0.0f32; 2];
        sub(&[3.0, 5.0], &[1.0, 10.0], &mut out);
        assert_eq!(out, [2.0, -5.0]);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }
}
