//! The persistent experiment server behind `simopt serve` (DESIGN.md §14).
//!
//! Architecture: an accept loop hands each connection to a short-lived
//! handler thread that parses ONE request; `submit` requests are admitted
//! into the bounded [`Bounded`] queue (or answered `busy`) and executed by
//! long-lived *worker* threads, each owning one warm [`Coordinator`] —
//! constructed once at startup, so artifact manifests, the lazily-built
//! PJRT engine, and the native thread budget are reused across every
//! request instead of being paid per experiment (the whole point of
//! serving: the paper's speedup lives in amortizing setup across many
//! requests).  The PJRT handles are thread-affine, which is exactly why
//! warm state is per-worker rather than shared: a worker's engine never
//! crosses threads.
//!
//! All frames of one conversation are written by its handler thread (the
//! worker passes frames back over a per-job channel — for a v2 streaming
//! submit that is every `progress` frame followed by the terminal one),
//! so two threads never interleave bytes on one socket.  Every answer is
//! stamped at the *request's* protocol version; a version outside this
//! build's range gets the typed `unsupported_version` frame.
//!
//! Shutdown: the `shutdown` frame flips a flag and self-connects to wake
//! the accept loop; the queue closes, workers drain every admitted job
//! (each still gets its `result` frame), the socket file is removed, and
//! [`Server::run`] returns its counters.

use std::fs;
use std::io::{self, BufReader};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::{report, Coordinator, ExperimentSpec, RunResult};
use crate::opt::{NullSink, ProgressSink, StepEvent, TracingSink};
use crate::util::json::{num, obj, s, Value};
use crate::util::log;
use crate::util::profile::Profiler;
use crate::util::trace::{now_us, Span, TraceId, Tracer};

use super::cache::ResultCache;
use super::metrics::{MetricsSnapshot, ServiceMetrics};
use super::protocol::{frame_version, read_frame, stamp_trace,
                      write_frame, ProgressInfo, Request, Response,
                      StatusInfo, WorkerStats, MIN_PROTOCOL_VERSION,
                      PROTOCOL_VERSION};
use super::queue::{Bounded, PushError};

/// How `simopt serve` configures the plane.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub socket: PathBuf,
    pub artifact_dir: String,
    /// Default results directory for the workers' coordinators; a spec's
    /// own `results_dir` overrides per request.
    pub results_dir: String,
    /// Executor threads, one warm [`Coordinator`] each (≥ 1).
    pub workers: usize,
    /// Admission queue bound; `0` admits nothing (every submit answers
    /// `busy` — the deterministic backpressure arm of the test suite).
    pub queue_capacity: usize,
    /// Result-cache bound in entries (FIFO eviction; `0` disables
    /// caching) — payloads carry full traces, so a long-lived server
    /// must not grow without limit.
    pub cache_capacity: usize,
    /// Append request spans (admission → cache check → queue wait →
    /// per-epoch execution → relay) to this file as Chrome-trace JSONL
    /// (`--trace-out`; `None` records nothing — DESIGN.md §18).
    pub trace_out: Option<PathBuf>,
}

/// Counters [`Server::run`] reports after a graceful shutdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    /// Experiments executed (cache hits excluded).
    pub executed: u64,
    pub cache_hits: u64,
    pub cache_entries: usize,
}

struct Job {
    id: u64,
    /// The conversation's trace id (minted at admission) — the worker
    /// tags its queue-wait/execute spans with it; frame stamping stays
    /// with the handler, the single place that writes the socket.
    trace: TraceId,
    spec: Box<ExperimentSpec>,
    /// Cache key + canonical spec string, computed once at admission —
    /// the worker reuses them, so admission and execution dedup are
    /// byte-identical by construction (and the hot path renders the
    /// canonical JSON once, not three times).
    key: u64,
    canonical: String,
    /// Protocol version of the submitting conversation — every frame
    /// the worker renders for it is stamped with this.
    v: u64,
    /// v2 streaming submit: render per-epoch `progress` frames onto
    /// `reply` ahead of the terminal frame.
    stream: bool,
    /// Frames travel back to the handler that owns the connection —
    /// workers never write to sockets.
    reply: mpsc::Sender<Value>,
}

/// One worker's counters behind the v2 status `stats.per_worker` entry.
struct WorkerCounters {
    executed: AtomicU64,
    /// Worker-side dedup hits (the second cache look in `worker_loop`);
    /// handler fast-path hits never reach a worker and are counted only
    /// in the global cache totals.
    cache_hits: AtomicU64,
}

struct Shared {
    queue: Bounded<Job>,
    cache: ResultCache,
    executed: AtomicU64,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    workers: usize,
    /// Per-worker executed/cache-hit split, indexed by worker
    /// (`stats.per_worker` on v2 status frames).
    worker_counters: Vec<WorkerCounters>,
    /// Aggregate per-phase seconds over every run this server executed
    /// (`stats.per_phase`, DESIGN.md §15) — merged from each completed
    /// run's profile, outside any timed region.
    phase_totals: Mutex<Profiler>,
    /// The always-on metrics registry behind the v2 `metrics` verb
    /// (DESIGN.md §18); queue/cache gauges are read from their owners
    /// at snapshot time.
    metrics: ServiceMetrics,
    /// Span sink when the server runs with `--trace-out`.
    tracer: Option<Arc<Tracer>>,
    socket: PathBuf,
}

impl Shared {
    /// Record a completed span, when tracing is on.  Every call site
    /// sits outside the timed regions (§18 invariance bar).
    fn span(&self, span: Span) {
        if let Some(tracer) = &self.tracer {
            tracer.record(&span);
        }
    }

    /// Freeze the registry + owner-held gauges into the `metrics`
    /// answer.
    fn snapshot_metrics(&self) -> MetricsSnapshot {
        let per_phase = *self.phase_totals.lock().unwrap();
        self.metrics.snapshot(
            self.queue.len(),
            self.queue.high_water(),
            self.cache.entries(),
            self.cache.hits(),
            &per_phase,
        )
    }
}

/// A bound-but-not-yet-running server.  Splitting bind from run lets the
/// in-process tests (and the CLI) know the socket exists before any
/// client connects.
pub struct Server {
    cfg: ServerConfig,
    listener: UnixListener,
}

impl Server {
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        ensure!(cfg.workers >= 1, "the service needs at least one worker");
        match UnixListener::bind(&cfg.socket) {
            Ok(listener) => Ok(Server { cfg, listener }),
            Err(e) if e.kind() == io::ErrorKind::AddrInUse => {
                // a live server answers a connect; a stale socket from a
                // crashed one does not and is safe to replace
                if UnixStream::connect(&cfg.socket).is_ok() {
                    bail!("{} already has a live server — pick another \
                           --socket or shut that one down",
                          cfg.socket.display());
                }
                // only ever delete an actual dead *socket*: a regular
                // file at this path is someone's data, not our leftover
                use std::os::unix::fs::FileTypeExt;
                let is_socket = fs::metadata(&cfg.socket)
                    .map(|m| m.file_type().is_socket())
                    .unwrap_or(false);
                ensure!(is_socket,
                        "{} exists and is not a socket — refusing to \
                         replace it", cfg.socket.display());
                fs::remove_file(&cfg.socket).with_context(|| {
                    format!("removing stale socket {}", cfg.socket.display())
                })?;
                let listener = UnixListener::bind(&cfg.socket)
                    .with_context(|| {
                        format!("binding {}", cfg.socket.display())
                    })?;
                Ok(Server { cfg, listener })
            }
            Err(e) => Err(e).with_context(|| {
                format!("binding {}", cfg.socket.display())
            }),
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Serve until a `shutdown` frame arrives; drain, then report.
    pub fn run(self) -> Result<ServerStats> {
        let tracer = match &self.cfg.trace_out {
            Some(path) => Some(Arc::new(Tracer::to_file(path)?)),
            None => None,
        };
        let shared = Arc::new(Shared {
            queue: Bounded::new(self.cfg.queue_capacity),
            cache: ResultCache::new(self.cfg.cache_capacity),
            executed: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            workers: self.cfg.workers,
            worker_counters: (0..self.cfg.workers)
                .map(|_| WorkerCounters {
                    executed: AtomicU64::new(0),
                    cache_hits: AtomicU64::new(0),
                })
                .collect(),
            phase_totals: Mutex::new(Profiler::new()),
            metrics: ServiceMetrics::new(),
            tracer,
            socket: self.cfg.socket.clone(),
        });
        let mut workers = Vec::with_capacity(self.cfg.workers);
        for idx in 0..self.cfg.workers {
            let shared = Arc::clone(&shared);
            let artifacts = self.cfg.artifact_dir.clone();
            let results = self.cfg.results_dir.clone();
            workers.push(thread::spawn(move || {
                worker_loop(&shared, idx, &artifacts, &results)
            }));
        }
        let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if shared.shutdown.load(Ordering::SeqCst) {
                // the connection that woke us (the shutdown self-connect,
                // or a client racing the shutdown) gets EOF
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    // persistent accept errors (EMFILE under load) must
                    // not become a silent busy-spin: say why, back off,
                    // give the handler/worker threads room to free fds
                    log::warn("serve", "accept_failed")
                        .field("err", e)
                        .field("backoff_ms", 100)
                        .emit();
                    thread::sleep(Duration::from_millis(100));
                    continue;
                }
            };
            // bound the request-line read so an idle connection can't
            // stall the handler join at shutdown (replies are unaffected:
            // submit handlers wait on a channel, not a socket read)
            let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
            handlers.retain(|h| !h.is_finished());
            let shared = Arc::clone(&shared);
            handlers.push(
                thread::spawn(move || handle_connection(stream, &shared)));
        }
        // drain: admitted jobs still answer, new pushes see Closed
        shared.queue.close();
        for w in workers {
            let _ = w.join();
        }
        // workers have sent every admitted job's terminal frame; keep the
        // process alive until the handlers have flushed them to their
        // sockets — otherwise a drained client would see EOF instead of
        // its promised result
        for h in handlers {
            let _ = h.join();
        }
        let _ = fs::remove_file(&self.cfg.socket);
        Ok(ServerStats {
            executed: shared.executed.load(Ordering::SeqCst),
            cache_hits: shared.cache.hits(),
            cache_entries: shared.cache.entries(),
        })
    }
}

/// Build a `result` frame around an already-encoded payload, stamped at
/// the conversation's protocol version.  The payload is versioned too:
/// stored payloads are `RunResult::to_json` (the v2 grammar) and a v2
/// conversation reuses them without re-parsing, but a v1 conversation
/// must carry the flat legacy grammar its deployed strict parser
/// expects — so for v1 the payload is re-rendered through the
/// `RunResult` codec.
fn completed_frame(ver: u64, id: u64, cache_hit: bool, payload: Value)
    -> Value {
    let payload = if ver < 2 {
        match RunResult::from_json(&payload) {
            Ok(r) => r.to_json_legacy(),
            // unreachable for payloads we rendered ourselves; a typed
            // error beats handing a v1 client a frame it cannot parse
            Err(e) => return error_frame(ver, &format!(
                "stored payload unreadable: {:#}", e)),
        }
    } else {
        payload
    };
    obj(vec![
        ("v", num(ver as f64)),
        ("type", s("result")),
        ("id", num(id as f64)),
        ("cache_hit", Value::Bool(cache_hit)),
        ("result", payload),
    ])
}

fn error_frame(ver: u64, message: &str) -> Value {
    Response::Error { message: message.to_string() }.to_json_for(ver)
}

/// The observer a worker attaches to a streaming submit: renders each
/// [`StepEvent`] as a `progress` frame onto the job's reply channel.
/// A hung-up client (dead channel) is not an execution error — the run
/// completes and its result still lands in the cache.
struct ChannelSink {
    v: u64,
    id: u64,
    tx: mpsc::Sender<Value>,
}

impl ProgressSink for ChannelSink {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> anyhow::Result<()> {
        let frame = Response::Progress(ProgressInfo {
            id: self.id,
            epoch: ev.epoch,
            epochs: ev.epochs,
            reps: ev.reps.to_vec(),
            objs: ev.objs.to_vec(),
            live: ev.live,
            step_s: ev.step_s,
            per_phase: ev.profile,
        })
        .to_json_for(self.v);
        let _ = self.tx.send(frame);
        Ok(())
    }
}

/// Honor a cache-answered request's `results_dir` delivery: reconstruct
/// the stored payload and persist the report bundle with zero
/// re-execution (an *executed* run persists through `Coordinator::run`
/// instead, which sees the spec's own `results_dir`).  An `Err` becomes
/// a typed error frame — the same outcome an executed run gets when its
/// persist fails, so the two paths agree on whether delivery failure is
/// fatal.
fn deliver_report(spec: &ExperimentSpec, payload: &Value) -> Result<()> {
    let Some(dir) = &spec.results_dir else { return Ok(()) };
    let result = RunResult::from_json(payload)
        .context("cached payload unreadable")?;
    // same recipe as an executed run's persist — bundle naming and
    // checkpoint fractions can't diverge between the paths
    report::persist_run_report(dir, &result)
        .with_context(|| format!("persisting report under {}", dir))
}

/// Answer a cache hit: deliver the requested report bundle (if any),
/// then frame the stored payload — or a typed error if delivery failed.
/// Cache hits never stream: there are no epochs to report.
fn cache_hit_frame(ver: u64, id: u64, spec: &ExperimentSpec, hit: &Value)
    -> Value {
    match deliver_report(spec, hit) {
        // deep-copy outside the cache lock (get returned an Arc bump)
        Ok(()) => completed_frame(ver, id, true, hit.clone()),
        Err(e) => error_frame(ver, &format!("{:#}", e)),
    }
}

/// One warm executor: a Coordinator built once, reused for every job this
/// worker pops — the engine/artifact state survives across requests.
fn worker_loop(shared: &Shared, idx: usize, artifacts: &str,
               results: &str) {
    let mut coord = match Coordinator::new(artifacts, results) {
        Ok(c) => Some(c),
        Err(e) => {
            // stay up and answer every job with a typed error — but make
            // sure the operator can see WHY from the server log
            log::error("serve", "worker_init_failed")
                .field("worker", idx)
                .field("err", format!("{:#}", e))
                .emit();
            None
        }
    };
    while let Some(popped) = shared.queue.pop() {
        let job = popped.item;
        // queue wait is a *measured* quantity — enqueue and pop
        // timestamps both come from the queue (DESIGN.md §18) — and both
        // the span and the histogram are fed outside any timed region
        shared.metrics.queue_wait.observe(popped.wait_s);
        shared.span(
            Span::new(job.trace, "queue_wait", popped.enqueued_us,
                      popped.enqueued_us + (popped.wait_s * 1e6) as u64)
                .with("id", job.id)
                .with("worker", idx));
        let exec_start = now_us();
        // second look at the cache (admission-time key/canonical reused):
        // identical specs admitted back-to-back both missed at admission,
        // but only the first needs to execute.  This dedup is best-effort
        // — two workers popping identical specs concurrently can both
        // execute (determinism makes the duplicate harmless: both produce
        // the identical payload) — and exact on a single-worker plane.
        let (key, canonical) = (job.key, &job.canonical);
        let mut executed_run = false;
        let (frame, outcome) = if let Some(hit) =
            shared.cache.get(key, canonical)
        {
            // cache hits never stream — the terminal frame is the answer
            shared.worker_counters[idx].cache_hits
                .fetch_add(1, Ordering::SeqCst);
            (cache_hit_frame(job.v, job.id, &job.spec, &hit), "cache_hit")
        } else if coord.is_some() {
            // contain panics per job: one poisoned spec must not take the
            // worker down and leave every queued client hanging
            let ran = {
                let c = coord.as_mut().unwrap();
                std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        // one sink chain for both arms: the base observer
                        // is the streaming relay or the null sink, and
                        // --trace-out wraps either in a TracingSink that
                        // records per-epoch spans from already-measured
                        // step times
                        let mut base: Box<dyn ProgressSink> = if job.stream
                        {
                            Box::new(ChannelSink {
                                v: job.v,
                                id: job.id,
                                tx: job.reply.clone(),
                            })
                        } else {
                            Box::new(NullSink)
                        };
                        match &shared.tracer {
                            Some(tracer) => {
                                let mut sink = TracingSink::new(
                                    Arc::clone(tracer), job.trace,
                                    &mut *base);
                                c.run_with(&job.spec, &mut sink)
                            }
                            None => c.run_with(&job.spec, &mut *base),
                        }
                    }))
            };
            match ran {
                Ok(Ok(result)) => {
                    let payload = Arc::new(result.to_json());
                    shared.cache.insert(key, canonical,
                                        Arc::clone(&payload));
                    shared.executed.fetch_add(1, Ordering::SeqCst);
                    shared.worker_counters[idx].executed
                        .fetch_add(1, Ordering::SeqCst);
                    executed_run = true;
                    shared.metrics.runs_executed.inc();
                    shared.metrics.frozen_rows
                        .add(result.frozen.len() as u64);
                    shared.phase_totals.lock().unwrap()
                        .merge(&result.profile);
                    (completed_frame(job.v, job.id, false,
                                     (*payload).clone()),
                     "executed")
                }
                Ok(Err(e)) => {
                    (error_frame(job.v, &format!("{:#}", e)), "error")
                }
                Err(_) => {
                    // the coordinator may be mid-mutation; rebuild it so
                    // the next job starts from a clean slate
                    log::error("serve", "worker_panicked")
                        .field("worker", idx)
                        .field("label", job.spec.label())
                        .field("action", "rebuilding coordinator")
                        .emit();
                    coord = Coordinator::new(artifacts, results).ok();
                    (error_frame(job.v, &format!(
                        "execution panicked running {} (see server log)",
                        job.spec.label())),
                     "panicked")
                }
            }
        } else {
            (error_frame(job.v,
                         "worker failed to initialize its coordinator \
                          (see server log)"),
             "init_failed")
        };
        // exactly one execute span per popped job, recorded (and the
        // latency observed) BEFORE the terminal frame travels back, so
        // the handler's relay/request spans always close after it
        let exec_end = now_us();
        if executed_run {
            shared.metrics.run_latency
                .observe(exec_end.saturating_sub(exec_start) as f64 / 1e6);
        }
        shared.span(
            Span::new(job.trace, "execute", exec_start, exec_end)
                .with("id", job.id)
                .with("worker", idx)
                .with("task", job.spec.label())
                .with("outcome", outcome));
        // a vanished handler (client hung up) just drops the frame
        let _ = job.reply.send(frame);
    }
}

/// Parse and answer one request; submits wait here for the worker's
/// frames (every `progress` frame, then the terminal one) so every byte
/// on the socket comes from this thread.
///
/// This thread is also the conversation's single trace point: it mints
/// the [`TraceId`] once the protocol version is known and stamps it on
/// every v2 frame it writes — its own answers and the frames it relays
/// from the worker alike — so the worker never touches frame grammar.
fn handle_connection(stream: UnixStream, shared: &Shared) {
    let t_admit = now_us();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    // errors emitted before version negotiation (unreadable frame,
    // missing/invalid 'v') are stamped at MIN_PROTOCOL_VERSION: the
    // sender's version is unknown, and the floor is the one stamp every
    // client in the supported range parses — a strict v1 client rejects
    // a v:2 frame outright
    let frame = match read_frame(&mut reader) {
        Ok(Some(v)) => v,
        Ok(None) => return, // client connected and hung up
        Err(e) => {
            let _ = write_frame(
                &mut writer,
                &error_frame(MIN_PROTOCOL_VERSION, &format!("{:#}", e)));
            return;
        }
    };
    // the version gate comes before request parsing: a client from the
    // future gets told the ceiling in a typed frame, not a parse error
    let ver = match frame_version(&frame) {
        Ok(v) => v,
        Err(e) => {
            let _ = write_frame(
                &mut writer,
                &error_frame(MIN_PROTOCOL_VERSION, &format!("{:#}", e)));
            return;
        }
    };
    if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&ver) {
        let _ = write_frame(
            &mut writer,
            &Response::UnsupportedVersion { max: PROTOCOL_VERSION }
                .to_json());
        return;
    }
    let req = match Request::from_json(&frame) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_frame(&mut writer,
                                &error_frame(ver, &format!("{:#}", e)));
            return;
        }
    };
    // version gate passed, request parsed: this conversation gets an
    // identity.  Every frame written below goes through `send`, the one
    // place that stamps it (v2 grammar only — v1 stays frozen).
    let trace = TraceId::mint();
    let send = |writer: &mut UnixStream, mut frame: Value| {
        stamp_trace(&mut frame, ver, trace);
        let _ = write_frame(writer, &frame);
    };
    let verb = match &req {
        Request::Status => "status",
        Request::Shutdown => "shutdown",
        Request::Metrics => "metrics",
        Request::Submit { .. } => "submit",
    };
    match req {
        Request::Status => {
            let info = StatusInfo {
                queue_depth: shared.queue.len(),
                capacity: shared.queue.capacity(),
                workers: shared.workers,
                executed: shared.executed.load(Ordering::SeqCst),
                cache_entries: shared.cache.entries(),
                cache_hits: shared.cache.hits(),
                per_worker: shared.worker_counters.iter()
                    .map(|w| WorkerStats {
                        executed: w.executed.load(Ordering::SeqCst),
                        cache_hits: w.cache_hits.load(Ordering::SeqCst),
                    })
                    .collect(),
                per_phase: *shared.phase_totals.lock().unwrap(),
            };
            send(&mut writer, Response::Status(info).to_json_for(ver));
        }
        Request::Metrics => {
            // freeze the registry + owner-held gauges in one read; the
            // answer is the JSON exposition (the CLI renders prometheus
            // text from it client-side)
            send(&mut writer,
                 Response::Metrics(shared.snapshot_metrics())
                     .to_json_for(ver));
        }
        Request::Shutdown => {
            send(&mut writer, Response::ShuttingDown.to_json_for(ver));
            shared.shutdown.store(true, Ordering::SeqCst);
            // wake the blocking accept loop so it observes the flag.
            // This nudge is load-bearing (without it the loop waits for
            // the next client), so retry through transient failures
            // (e.g. fd exhaustion) instead of shrugging one off.
            let mut woke = false;
            for _ in 0..20 {
                if UnixStream::connect(&shared.socket).is_ok() {
                    woke = true;
                    break;
                }
                thread::sleep(Duration::from_millis(25));
            }
            if !woke {
                log::warn("serve", "shutdown_waker_failed")
                    .field("note", "accept loop will notice at the next \
                                    connection")
                    .emit();
            }
        }
        Request::Submit { spec, stream } => {
            shared.metrics.submits.inc();
            if let Err(e) = spec.validate() {
                send(&mut writer,
                     error_frame(ver, &format!("invalid spec: {:#}", e)));
            } else {
                submit(&mut writer, shared, &send, t_admit, trace, ver,
                       spec, stream);
            }
        }
    }
    // the conversation's parent span: admission timestamp → last frame
    // written, recorded after all socket writes
    shared.span(Span::new(trace, "request", t_admit, now_us())
        .with("verb", verb)
        .with("v", ver));
}

/// The submit arm of [`handle_connection`]: cache fast path, admission
/// into the queue, then the relay loop.  Split out so the span/counter
/// bookkeeping reads linearly.
#[allow(clippy::too_many_arguments)]
fn submit(writer: &mut UnixStream, shared: &Shared,
          send: &dyn Fn(&mut UnixStream, Value), t_admit: u64,
          trace: TraceId, ver: u64, spec: Box<ExperimentSpec>,
          stream: bool) {
    // fast path: cached specs answer instantly, without taking a
    // queue slot — repeat submissions cannot be crowded out by a
    // full queue.  A cache hit never streams: no epochs run.
    let key = spec.spec_hash();
    let canonical = spec.canonical_json().to_string_compact();
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
    // admission covers read/parse/validate/hash; cache_check is the
    // fast-path probe.  The boundary timestamp is shared so the request's
    // spans chain without gaps.
    let t_cache = now_us();
    let hit = shared.cache.get(key, &canonical);
    let t_cache_end = now_us();
    shared.span(Span::new(trace, "admission", t_admit, t_cache)
        .with("id", id));
    shared.span(Span::new(trace, "cache_check", t_cache, t_cache_end)
        .with("id", id)
        .with("hit", hit.is_some()));
    if let Some(hit) = hit {
        send(writer, cache_hit_frame(ver, id, &spec, &hit));
        return;
    }
    shared.metrics.cache_misses.inc();
    let (reply, result_rx) = mpsc::channel();
    match shared.queue.try_push(Job { id, trace, spec, key, canonical,
                                      v: ver, stream, reply }) {
        Ok(position) => {
            send(writer, Response::Queued { id, position }
                .to_json_for(ver));
            // relay worker frames until the terminal one: every frame
            // that is not `progress` ends the conversation
            loop {
                match result_rx.recv() {
                    Ok(frame) => {
                        let t_recv = now_us();
                        let terminal = frame.get("type")
                            .and_then(Value::as_str)
                            != Some("progress");
                        // counted before the write: a client that reads
                        // its terminal frame and immediately queries
                        // `metrics` must see this frame in the total
                        shared.metrics.frames_relayed.inc();
                        send(writer, frame);
                        if terminal {
                            shared.span(
                                Span::new(trace, "relay", t_recv,
                                          now_us())
                                    .with("id", id));
                            break;
                        }
                    }
                    Err(_) => {
                        send(writer,
                             error_frame(ver, "worker exited before \
                                               answering"));
                        break;
                    }
                }
            }
        }
        Err(PushError::Full(_)) => {
            shared.metrics.busy_rejections.inc();
            send(writer, Response::Busy {
                capacity: shared.queue.capacity(),
            }
            .to_json_for(ver));
        }
        Err(PushError::Closed(_)) => {
            send(writer, error_frame(ver, "service is shutting down"));
        }
    }
}
