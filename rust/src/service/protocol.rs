//! Versioned JSON-lines wire protocol for `simopt serve` / `simopt submit`
//! (DESIGN.md §14 gives the full grammar).
//!
//! Framing: every frame is ONE line of compact JSON
//! (`Value::to_string_compact` never emits a newline) terminated by `\n`,
//! over a Unix-domain stream socket.  Every frame carries `"v"`; this
//! build speaks versions [`MIN_PROTOCOL_VERSION`]..=[`PROTOCOL_VERSION`]
//! and a server answers every conversation *at the request's version* —
//! a v1 client keeps receiving exactly the v1 frames it always did.  A
//! version outside that range is answered with a typed
//! `unsupported_version` frame naming the server's maximum; a malformed
//! line gets a typed `error` frame rather than a dropped connection, so
//! clients always have something to report.
//!
//! Conversation shape: one *request* per connection.  `submit` is answered
//! by an immediate `queued` ack (or `busy` / `error`), then — on the same
//! connection — zero or more `progress` frames (v2 streaming submits
//! only) and finally the terminal `result` frame; `status` and `shutdown`
//! are answered by a single frame.  Specs travel in the canonical
//! [`ExperimentSpec::to_json`] encoding, results as
//! [`RunResult::to_json`] — except on v1 conversations, whose `result`
//! frames embed the flat legacy payload ([`RunResult::to_json_legacy`])
//! that a deployed v1 client's strict parser expects.  Unknown
//! top-level keys on any frame are ignored, so v2+ additions never
//! break a v1 parser.
//!
//! Observability additions (DESIGN.md §18), both v2-only:
//! * every server frame of a v2 conversation carries a `"trace"` key —
//!   the request's [`TraceId`] in hex, stamped by [`stamp_trace`] so a
//!   client can find its spans in the server's `--trace-out` JSONL.  On
//!   a v1 conversation the key is never emitted (those frames stay
//!   bit-identical), and every parser treats it as an ignorable
//!   unknown key.
//! * the `metrics` request verb answers a [`MetricsSnapshot`] frame; a
//!   v1 frame asking for it is rejected at parse with a typed error
//!   (the v1 grammar is frozen).

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{ExperimentSpec, RunResult};
use crate::util::json::{arr, num, obj, s, Value};
use crate::util::profile::Profiler;
use crate::util::trace::TraceId;

use super::metrics::MetricsSnapshot;

/// Highest protocol version this build speaks; bump on any frame-grammar
/// change.  v2 added streaming submits (`stream` on `submit`, `progress`
/// frames) and the `unsupported_version` answer.
pub const PROTOCOL_VERSION: u64 = 2;

/// Lowest version this build still answers — v1 conversations are served
/// verbatim (no `progress` frames can occur on them).
pub const MIN_PROTOCOL_VERSION: u64 = 1;

/// Client → server frames.
#[derive(Debug)]
pub enum Request {
    /// Run (or answer from cache) one experiment spec.  `stream` (v2+)
    /// asks for per-epoch `progress` frames before the terminal `result`;
    /// on a v1 conversation the key is never emitted and never honored.
    Submit { spec: Box<ExperimentSpec>, stream: bool },
    /// Report queue/cache/worker counters.
    Status,
    /// Report the metrics registry (DESIGN.md §18).  v2-only: the v1
    /// grammar is frozen, so a v1 frame with this type parses to a
    /// typed error.
    Metrics,
    /// Stop accepting, drain admitted work, exit.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Value {
        let head = |t: &str| vec![("v", num(PROTOCOL_VERSION as f64)),
                                  ("type", s(t))];
        match self {
            Request::Submit { spec, stream } => {
                let mut kv = head("submit");
                kv.push(("spec", spec.to_json()));
                if *stream {
                    kv.push(("stream", Value::Bool(true)));
                }
                obj(kv)
            }
            Request::Status => obj(head("status")),
            Request::Metrics => obj(head("metrics")),
            Request::Shutdown => obj(head("shutdown")),
        }
    }

    pub fn from_json(v: &Value) -> Result<Request> {
        let ver = check_version(v)?;
        match frame_type(v)? {
            "submit" => {
                let spec = v.get("spec")
                    .context("submit frame is missing 'spec'")?;
                // `stream` is v2 grammar: a v1 frame carrying it is a
                // foreign key and is ignored like any other unknown key
                let stream = ver >= 2
                    && v.get("stream").and_then(Value::as_bool)
                        .unwrap_or(false);
                Ok(Request::Submit {
                    spec: Box::new(ExperimentSpec::from_json(spec)?),
                    stream,
                })
            }
            "status" => Ok(Request::Status),
            "metrics" => {
                anyhow::ensure!(
                    ver >= 2,
                    "the 'metrics' verb requires protocol v2 (the v1 \
                     grammar is frozen; this frame spoke v{})", ver);
                Ok(Request::Metrics)
            }
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown request type '{}'", other),
        }
    }
}

/// One worker's execution counters (an entry of the structured `stats`
/// object a v2 `status` frame carries).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Experiments this worker executed (cache hits excluded).
    pub executed: u64,
    /// Submits this worker answered straight from the cache.
    pub cache_hits: u64,
}

/// Server status counters (the `status` response payload).  The flat
/// totals are the v1 grammar; v2 frames additionally carry a structured
/// `"stats"` object (per-worker counters + aggregate per-phase seconds)
/// — additive-only keys, so a v1 parser never notices.
#[derive(Debug, Clone, PartialEq)]
pub struct StatusInfo {
    pub queue_depth: usize,
    pub capacity: usize,
    pub workers: usize,
    /// Experiments actually executed (cache hits excluded).
    pub executed: u64,
    pub cache_entries: usize,
    pub cache_hits: u64,
    /// Per-worker executed/cache-hit split (`stats.per_worker`; empty on
    /// frames from v1 producers).
    pub per_worker: Vec<WorkerStats>,
    /// Aggregate per-phase seconds over every run this server executed
    /// (`stats.per_phase`, DESIGN.md §15).
    pub per_phase: Profiler,
}

/// One per-epoch snapshot of a streamed run (the v2 `progress` frame):
/// which replications stepped, their objective values after the step,
/// the live replication count, and the step's timed seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgressInfo {
    pub id: u64,
    /// 1-based epoch within `epochs`.
    pub epoch: usize,
    pub epochs: usize,
    /// Replication indices this event covers (one per entry of `objs`).
    pub reps: Vec<usize>,
    pub objs: Vec<f64>,
    /// Replications still live after this epoch (budget freezes shrink
    /// it; without a budget it equals the plan's replication count).
    pub live: usize,
    /// Timed seconds of this step's kernel region.
    pub step_s: f64,
    /// Per-phase attribution of this step (DESIGN.md §15); empty on
    /// frames from pre-profiler producers.
    pub per_phase: Profiler,
}

/// Server → client frames.
#[derive(Debug)]
pub enum Response {
    /// Submit ack: admitted at 1-based queue `position`.
    Queued { id: u64, position: usize },
    /// One per-epoch snapshot of a streaming submit (v2; non-terminal).
    Progress(ProgressInfo),
    /// Terminal submit answer: the run's payload, `cache_hit` marking a
    /// result served from the content-addressed cache with no execution.
    Completed { id: u64, cache_hit: bool, result: Box<RunResult> },
    /// Typed backpressure: the admission queue holds `capacity` requests.
    Busy { capacity: usize },
    /// Parse/validation/execution failure, with the reason.
    Error { message: String },
    Status(StatusInfo),
    /// The metrics registry snapshot (v2-only `metrics` answer, §18).
    Metrics(MetricsSnapshot),
    /// Shutdown ack; the server drains admitted work, then exits.
    ShuttingDown,
    /// The request's `v` is outside this build's range; `max` names the
    /// highest version the server speaks.  Terminal.
    UnsupportedVersion { max: u64 },
}

impl Response {
    /// Render at this build's own version.
    pub fn to_json(&self) -> Value {
        self.to_json_for(PROTOCOL_VERSION)
    }

    /// Render stamped with protocol version `ver` — the server answers
    /// every conversation at the version the request spoke, so v1
    /// clients see bit-identical v1 frames from a v2 server.
    pub fn to_json_for(&self, ver: u64) -> Value {
        let head = |t: &str| vec![("v", num(ver as f64)), ("type", s(t))];
        match self {
            Response::Queued { id, position } => {
                let mut kv = head("queued");
                kv.push(("id", num(*id as f64)));
                kv.push(("position", num(*position as f64)));
                obj(kv)
            }
            Response::Progress(p) => {
                let mut kv = head("progress");
                kv.push(("id", num(p.id as f64)));
                kv.push(("epoch", num(p.epoch as f64)));
                kv.push(("epochs", num(p.epochs as f64)));
                kv.push(("reps", arr(p.reps.iter()
                    .map(|&r| num(r as f64)).collect())));
                kv.push(("objs", arr(p.objs.iter()
                    .map(|&o| num(o)).collect())));
                kv.push(("live", num(p.live as f64)));
                kv.push(("step_s", num(p.step_s)));
                kv.push(("per_phase", p.per_phase.to_json()));
                obj(kv)
            }
            Response::Completed { id, cache_hit, result } => {
                let mut kv = head("result");
                kv.push(("id", num(*id as f64)));
                kv.push(("cache_hit", Value::Bool(*cache_hit)));
                // the payload is versioned too: a v1 conversation's
                // result embeds the flat legacy grammar its deployed
                // strict parser expects, not the v2 "plan" object
                kv.push(("result", if ver < 2 { result.to_json_legacy() }
                                   else { result.to_json() }));
                obj(kv)
            }
            Response::Busy { capacity } => {
                let mut kv = head("busy");
                kv.push(("capacity", num(*capacity as f64)));
                obj(kv)
            }
            Response::Error { message } => {
                let mut kv = head("error");
                kv.push(("error", s(message)));
                obj(kv)
            }
            Response::Status(st) => {
                let mut kv = head("status");
                kv.push(("queue_depth", num(st.queue_depth as f64)));
                kv.push(("capacity", num(st.capacity as f64)));
                kv.push(("workers", num(st.workers as f64)));
                kv.push(("executed", num(st.executed as f64)));
                kv.push(("cache_entries", num(st.cache_entries as f64)));
                kv.push(("cache_hits", num(st.cache_hits as f64)));
                // the structured stats object is v2 grammar; a v1
                // conversation's status frame stays bit-identical
                if ver >= 2 {
                    kv.push(("stats", obj(vec![
                        ("per_worker",
                         arr(st.per_worker.iter().map(|w| obj(vec![
                             ("executed", num(w.executed as f64)),
                             ("cache_hits", num(w.cache_hits as f64)),
                         ])).collect())),
                        ("per_phase", st.per_phase.to_json()),
                    ])));
                }
                obj(kv)
            }
            Response::Metrics(snapshot) => {
                let mut kv = head("metrics");
                kv.push(("metrics", snapshot.to_json()));
                obj(kv)
            }
            Response::ShuttingDown => obj(head("shutting_down")),
            Response::UnsupportedVersion { max } => {
                let mut kv = head("unsupported_version");
                kv.push(("max", num(*max as f64)));
                obj(kv)
            }
        }
    }

    pub fn from_json(v: &Value) -> Result<Response> {
        check_version(v)?;
        let get_u64 = |key: &str| -> Result<u64> { frame_u64(v, key) };
        match frame_type(v)? {
            "queued" => Ok(Response::Queued {
                id: get_u64("id")?,
                position: get_u64("position")? as usize,
            }),
            "progress" => {
                let uints = |key: &str| -> Result<Vec<usize>> {
                    v.get(key).and_then(Value::as_arr)
                        .with_context(|| format!(
                            "progress frame is missing '{}'", key))?
                        .iter()
                        .map(|x| x.as_uint().map(|u| u as usize)
                            .with_context(|| format!(
                                "'{}' entries must be non-negative \
                                 integers", key)))
                        .collect()
                };
                let objs: Vec<f64> = v.get("objs")
                    .and_then(Value::as_arr)
                    .context("progress frame is missing 'objs'")?
                    .iter()
                    .map(|x| x.as_f64()
                        .context("'objs' entries must be numbers"))
                    .collect::<Result<_>>()?;
                Ok(Response::Progress(ProgressInfo {
                    id: get_u64("id")?,
                    epoch: get_u64("epoch")? as usize,
                    epochs: get_u64("epochs")? as usize,
                    reps: uints("reps")?,
                    objs,
                    live: get_u64("live")? as usize,
                    step_s: v.get("step_s").and_then(Value::as_f64)
                        .context("progress frame is missing 'step_s'")?,
                    per_phase: match v.get("per_phase") {
                        None | Some(Value::Null) => Profiler::new(),
                        Some(pp) => Profiler::from_json(pp)
                            .context("parsing progress 'per_phase'")?,
                    },
                }))
            }
            "result" => Ok(Response::Completed {
                id: get_u64("id")?,
                cache_hit: v.get("cache_hit")
                    .and_then(Value::as_bool)
                    .context("result frame is missing 'cache_hit'")?,
                result: Box::new(RunResult::from_json(
                    v.get("result")
                        .context("result frame is missing 'result'")?)?),
            }),
            "busy" => Ok(Response::Busy {
                capacity: get_u64("capacity")? as usize,
            }),
            "error" => Ok(Response::Error {
                message: v.get("error")
                    .and_then(Value::as_str)
                    .context("error frame is missing 'error'")?
                    .to_string(),
            }),
            "status" => {
                // the stats object is additive v2 grammar — absent on v1
                // frames, so both halves default to empty
                let mut per_worker = Vec::new();
                let mut per_phase = Profiler::new();
                if let Some(stats) = v.get("stats") {
                    if let Some(ws) =
                        stats.get("per_worker").and_then(Value::as_arr) {
                        for w in ws {
                            per_worker.push(WorkerStats {
                                executed: frame_u64(w, "executed")?,
                                cache_hits: frame_u64(w, "cache_hits")?,
                            });
                        }
                    }
                    if let Some(pp) = stats.get("per_phase") {
                        per_phase = Profiler::from_json(pp)
                            .context("parsing status 'per_phase'")?;
                    }
                }
                Ok(Response::Status(StatusInfo {
                    queue_depth: get_u64("queue_depth")? as usize,
                    capacity: get_u64("capacity")? as usize,
                    workers: get_u64("workers")? as usize,
                    executed: get_u64("executed")?,
                    cache_entries: get_u64("cache_entries")? as usize,
                    cache_hits: get_u64("cache_hits")?,
                    per_worker,
                    per_phase,
                }))
            }
            "metrics" => Ok(Response::Metrics(MetricsSnapshot::from_json(
                v.get("metrics")
                    .context("metrics frame is missing 'metrics'")?)?)),
            "shutting_down" => Ok(Response::ShuttingDown),
            "unsupported_version" => Ok(Response::UnsupportedVersion {
                max: get_u64("max")?,
            }),
            other => bail!("unknown response type '{}'", other),
        }
    }
}

fn frame_type(v: &Value) -> Result<&str> {
    v.get("type")
        .and_then(Value::as_str)
        .context("frame is missing 'type'")
}

/// Strict frame-field integer (`Value::as_uint`: present, non-negative,
/// no fraction) — a corrupt frame becomes a typed error, never a
/// silently truncated value.
fn frame_u64(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_uint)
        .with_context(|| format!("frame '{}' must be a non-negative \
                                  integer", key))
}

/// The frame's raw `v` field, without range-checking it — what the
/// server reads first so an out-of-range version can be answered with
/// the typed `unsupported_version` frame instead of a generic error.
pub fn frame_version(v: &Value) -> Result<u64> {
    frame_u64(v, "v")
        .context("frame carries no valid protocol version 'v'")
}

/// Stamp a server frame with the conversation's [`TraceId`] (`"trace"`
/// key, 16 hex digits).  v2-only additive grammar: a v1 frame is left
/// untouched so deployed v1 parsers keep seeing bit-identical bytes.
pub fn stamp_trace(frame: &mut Value, ver: u64, trace: TraceId) {
    if ver >= 2 {
        if let Value::Obj(kv) = frame {
            kv.push(("trace".to_string(), s(&trace.as_hex())));
        }
    }
}

/// The frame's `"trace"` stamp, if it carries one.
pub fn frame_trace(v: &Value) -> Option<TraceId> {
    v.get("trace").and_then(Value::as_str).and_then(TraceId::from_hex)
}

fn check_version(v: &Value) -> Result<u64> {
    let got = frame_version(v)?;
    anyhow::ensure!(
        (MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&got),
        "unsupported protocol version {} (this build speaks {}..={})",
        got, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION);
    Ok(got)
}

/// Write one frame as a single JSON line.
pub fn write_frame(w: &mut impl Write, frame: &Value) -> Result<()> {
    let mut line = frame.to_string_compact();
    line.push('\n');
    w.write_all(line.as_bytes()).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame line; `None` at clean EOF.
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<Value>> {
    let mut line = String::new();
    let n = r.read_line(&mut line).context("reading frame")?;
    if n == 0 {
        return Ok(None);
    }
    let trimmed = line.trim();
    if trimmed.is_empty() {
        bail!("empty frame line");
    }
    Ok(Some(Value::parse(trimmed)
        .map_err(|e| anyhow!("malformed frame: {}", e))?))
}

/// One-request-per-connection client for the service socket — what
/// `simopt submit` and the served conformance arm drive.
pub struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Client {
    pub fn connect(socket: impl AsRef<Path>) -> Result<Client> {
        let socket = socket.as_ref();
        let stream = UnixStream::connect(socket).with_context(|| {
            format!("connecting to service socket {} (is `simopt serve` \
                     running?)", socket.display())
        })?;
        let writer = stream.try_clone().context("cloning socket stream")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn send(&mut self, req: &Request) -> Result<()> {
        write_frame(&mut self.writer, &req.to_json())
    }

    /// Read the next frame; EOF before a frame is a protocol error here
    /// (callers only recv when an answer is owed).
    pub fn recv(&mut self) -> Result<Response> {
        Response::from_json(&self.recv_frame()?)
    }

    /// Read the next raw frame value (what [`Session`] uses to also
    /// capture the conversation's `"trace"` stamp).
    fn recv_frame(&mut self) -> Result<Value> {
        read_frame(&mut self.reader)?
            .context("server closed the connection mid-conversation")
    }

    /// Open a submit conversation and return its [`Session`] handle —
    /// the v2 client surface.  `stream` asks the server for per-epoch
    /// `progress` events between the `queued` ack and the terminal
    /// `result`.
    pub fn session(&mut self, spec: &ExperimentSpec, stream: bool)
        -> Result<Session<'_>> {
        self.send(&Request::Submit {
            spec: Box::new(spec.clone()),
            stream,
        })?;
        Ok(Session { client: self, done: false, trace: None })
    }

    /// Submit a spec and return the terminal answer (`Completed`, `Busy`,
    /// or `Error`), reporting interim `queued` acks through `on_queued`.
    ///
    /// Deprecated in favor of [`Client::session`], which exposes the
    /// whole event stream; kept as a thin non-streaming wrapper for the
    /// v1-era call sites.
    pub fn submit_with(&mut self, spec: &ExperimentSpec,
                       mut on_queued: impl FnMut(u64, usize))
        -> Result<Response> {
        let mut session = self.session(spec, false)?;
        loop {
            match session.next_event()? {
                Some(Response::Queued { id, position }) => {
                    on_queued(id, position)
                }
                Some(Response::Progress(_)) => {} // not requested; skip
                Some(terminal) => return Ok(terminal),
                None => bail!("session ended without a terminal frame"),
            }
        }
    }

    /// [`Client::submit_with`] without an ack observer.
    ///
    /// Deprecated in favor of [`Client::session`]; kept as a thin
    /// wrapper.
    pub fn submit(&mut self, spec: &ExperimentSpec) -> Result<Response> {
        self.submit_with(spec, |_, _| {})
    }

    pub fn status(&mut self) -> Result<StatusInfo> {
        self.send(&Request::Status)?;
        match self.recv()? {
            Response::Status(info) => Ok(info),
            Response::Error { message } => bail!("server error: {}", message),
            other => bail!("expected a status frame, got {:?}", other),
        }
    }

    /// Fetch the server's metrics registry snapshot (v2-only verb).
    pub fn metrics(&mut self) -> Result<MetricsSnapshot> {
        self.send(&Request::Metrics)?;
        match self.recv()? {
            Response::Metrics(snapshot) => Ok(snapshot),
            Response::Error { message } => bail!("server error: {}", message),
            other => bail!("expected a metrics frame, got {:?}", other),
        }
    }

    /// Request graceful shutdown; returns once the server acked it.
    pub fn shutdown(&mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv()? {
            Response::ShuttingDown => Ok(()),
            Response::Error { message } => bail!("server error: {}", message),
            other => bail!("expected a shutting_down frame, got {:?}", other),
        }
    }
}

/// One submit conversation on a [`Client`], event by event:
/// `queued` → `progress`* → terminal (`result`, `busy`, `error`, or
/// `unsupported_version`).  Anything that is not `queued` or `progress`
/// is terminal and ends the iteration; the borrow on the client ends
/// with the session, so the same connection's client can be reused for
/// a follow-up conversation where the transport allows it.
pub struct Session<'a> {
    client: &'a mut Client,
    done: bool,
    trace: Option<TraceId>,
}

impl Session<'_> {
    /// The next event of the conversation, or `None` once the terminal
    /// frame has been consumed.
    pub fn next_event(&mut self) -> Result<Option<Response>> {
        if self.done {
            return Ok(None);
        }
        let frame = self.client.recv_frame()?;
        if let Some(trace) = frame_trace(&frame) {
            self.trace = Some(trace);
        }
        let event = Response::from_json(&frame)?;
        if !matches!(event,
                     Response::Queued { .. } | Response::Progress(_)) {
            self.done = true;
        }
        Ok(Some(event))
    }

    /// The conversation's server-minted trace id, once any v2 frame has
    /// carried it — the handle for finding this request's spans in the
    /// server's `--trace-out` JSONL.
    pub fn trace(&self) -> Option<TraceId> {
        self.trace
    }

    /// Drain the remaining events and return the terminal answer,
    /// reporting each interim `progress` frame through `on_progress`.
    pub fn finish_with(mut self,
                       mut on_progress: impl FnMut(&ProgressInfo))
        -> Result<Response> {
        loop {
            match self.next_event()? {
                Some(Response::Queued { .. }) => {}
                Some(Response::Progress(p)) => on_progress(&p),
                Some(terminal) => return Ok(terminal),
                None => bail!("session ended without a terminal frame"),
            }
        }
    }

    /// [`Session::finish_with`] without a progress observer.
    pub fn finish(self) -> Result<Response> {
        self.finish_with(|_| {})
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, TaskKind};

    fn spec() -> ExperimentSpec {
        ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
    }

    fn roundtrip_req(r: &Request) -> Request {
        let line = r.to_json().to_string_compact();
        assert!(!line.contains('\n'));
        Request::from_json(&Value::parse(&line).unwrap()).unwrap()
    }

    fn roundtrip_resp(r: &Response) -> Response {
        let line = r.to_json().to_string_compact();
        assert!(!line.contains('\n'));
        Response::from_json(&Value::parse(&line).unwrap()).unwrap()
    }

    #[test]
    fn request_frames_roundtrip() {
        for streaming in [false, true] {
            let req = Request::Submit {
                spec: Box::new(spec()),
                stream: streaming,
            };
            // `stream` is only on the wire when asked for — a default
            // submit is byte-identical to the v1 one apart from `v`
            assert_eq!(req.to_json().to_string_compact()
                           .contains("\"stream\""),
                       streaming);
            match roundtrip_req(&req) {
                Request::Submit { spec: back, stream } => {
                    assert_eq!(stream, streaming);
                    assert_eq!(back.to_json().to_string_compact(),
                               spec().to_json().to_string_compact());
                }
                other => panic!("{:?}", other),
            }
        }
        assert!(matches!(roundtrip_req(&Request::Status), Request::Status));
        assert!(matches!(roundtrip_req(&Request::Shutdown),
                         Request::Shutdown));
    }

    #[test]
    fn response_frames_roundtrip() {
        match roundtrip_resp(&Response::Queued { id: 9, position: 2 }) {
            Response::Queued { id: 9, position: 2 } => {}
            other => panic!("{:?}", other),
        }
        match roundtrip_resp(&Response::Busy { capacity: 16 }) {
            Response::Busy { capacity: 16 } => {}
            other => panic!("{:?}", other),
        }
        match roundtrip_resp(&Response::Error {
            message: "no such task 'wat'".into(),
        }) {
            Response::Error { message } => {
                assert_eq!(message, "no such task 'wat'")
            }
            other => panic!("{:?}", other),
        }
        let mut per_phase = Profiler::new();
        per_phase.add(crate::util::profile::Phase::Compute, 1.5);
        let info = StatusInfo {
            queue_depth: 1,
            capacity: 8,
            workers: 2,
            executed: 40,
            cache_entries: 3,
            cache_hits: 7,
            per_worker: vec![
                WorkerStats { executed: 25, cache_hits: 3 },
                WorkerStats { executed: 15, cache_hits: 4 },
            ],
            per_phase,
        };
        match roundtrip_resp(&Response::Status(info.clone())) {
            Response::Status(back) => assert_eq!(back, info),
            other => panic!("{:?}", other),
        }
        // the stats object is v2-only, additive grammar
        let v2_text = Response::Status(info.clone()).to_json_for(2)
            .to_string_compact();
        assert!(v2_text.contains(
            "\"stats\":{\"per_worker\":[{\"executed\":25,\
             \"cache_hits\":3},{\"executed\":15,\"cache_hits\":4}],\
             \"per_phase\":{\"compute\":1.5}}"), "{}", v2_text);
        let v1_text = Response::Status(info).to_json_for(1)
            .to_string_compact();
        assert!(!v1_text.contains("\"stats\""), "{}", v1_text);
        assert!(matches!(roundtrip_resp(&Response::ShuttingDown),
                         Response::ShuttingDown));
    }

    #[test]
    fn result_frame_carries_the_payload() {
        let result = RunResult::new(spec(), vec![]);
        let frame = Response::Completed {
            id: 3,
            cache_hit: true,
            result: Box::new(result),
        };
        match roundtrip_resp(&frame) {
            Response::Completed { id: 3, cache_hit: true, result } => {
                assert_eq!(result.spec.task, TaskKind::MeanVariance);
                assert!(result.reps.is_empty());
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn version_and_type_are_enforced() {
        // both in-range versions parse — the v1 grammar is a subset
        for ver in [1, 2] {
            let ok = Value::parse(
                &format!(r#"{{"v":{},"type":"status"}}"#, ver)).unwrap();
            assert!(Request::from_json(&ok).is_ok(), "v{} rejected", ver);
        }
        // beyond the range is rejected by the parser (the server answers
        // it with a typed unsupported_version frame before parsing)
        let bad = Value::parse(r#"{"v":3,"type":"status"}"#).unwrap();
        assert!(Request::from_json(&bad).is_err());
        assert!(Response::from_json(&bad).is_err());
        let none = Value::parse(r#"{"type":"status"}"#).unwrap();
        assert!(Request::from_json(&none).is_err());
        let unk = Value::parse(r#"{"v":1,"type":"dance"}"#).unwrap();
        assert!(Request::from_json(&unk).is_err());
        assert!(Response::from_json(&unk).is_err());
    }

    #[test]
    fn progress_and_unsupported_version_frames_roundtrip() {
        let mut per_phase = Profiler::new();
        per_phase.add(crate::util::profile::Phase::Compute, 0.05);
        per_phase.add(crate::util::profile::Phase::Lmo, 0.0125);
        let info = ProgressInfo {
            id: 12,
            epoch: 3,
            epochs: 40,
            reps: vec![0, 2],
            objs: vec![1.25, -0.5],
            live: 2,
            step_s: 0.0625,
            per_phase,
        };
        match roundtrip_resp(&Response::Progress(info.clone())) {
            Response::Progress(back) => assert_eq!(back, info),
            other => panic!("{:?}", other),
        }
        // the snapshot carries its per-phase split on the wire…
        assert!(Response::Progress(info.clone()).to_json()
            .to_string_compact()
            .contains("\"per_phase\":{\"compute\":0.05,\"lmo\":0.0125}"));
        // …and a frame without one (pre-profiler producer) still parses
        let mut bare = info;
        bare.per_phase = Profiler::new();
        let line = Response::Progress(bare.clone()).to_json()
            .to_string_compact()
            .replace(",\"per_phase\":{}", "");
        assert!(!line.contains("per_phase"), "{}", line);
        match Response::from_json(&Value::parse(&line).unwrap()).unwrap() {
            Response::Progress(back) => assert_eq!(back, bare),
            other => panic!("{:?}", other),
        }
        match roundtrip_resp(&Response::UnsupportedVersion { max: 2 }) {
            Response::UnsupportedVersion { max: 2 } => {}
            other => panic!("{:?}", other),
        }
        // corrupt snapshots are typed errors, not truncated data
        let bad = Value::parse(
            r#"{"v":2,"type":"progress","id":1,"epoch":1,"epochs":4,
                "reps":[0.5],"objs":[1.0],"live":1,"step_s":0.1}"#
                .replace(['\n', ' '], "").as_str()).unwrap();
        assert!(Response::from_json(&bad).is_err());
    }

    #[test]
    fn v1_conversations_see_the_v1_grammar() {
        // answers render at the request's version…
        let queued = Response::Queued { id: 4, position: 1 };
        assert_eq!(queued.to_json_for(1).to_string_compact(),
                   r#"{"v":1,"type":"queued","id":4,"position":1}"#);
        // …including the result PAYLOAD: a v1 result frame embeds the
        // flat legacy grammar (top-level batched/shards, no "plan"),
        // because a deployed v1 RunResult::from_json is strict about it
        let completed = Response::Completed {
            id: 4,
            cache_hit: false,
            result: Box::new(RunResult::new(spec(), vec![])
                .executed(Some(2))),
        };
        let v1_text = completed.to_json_for(1).to_string_compact();
        assert!(v1_text.contains("\"batched\":true"), "{}", v1_text);
        assert!(v1_text.contains("\"shards\":2"), "{}", v1_text);
        assert!(!v1_text.contains("\"plan\""), "{}", v1_text);
        let v2_text = completed.to_json_for(2).to_string_compact();
        assert!(v2_text.contains("\"plan\""), "{}", v2_text);
        // …and a v1 submit carrying the v2 'stream' key treats it as an
        // unknown key: ignored, never honored
        let line = format!(r#"{{"v":1,"type":"submit","stream":true,
                               "spec":{}}}"#, spec().to_json()
                               .to_string_compact())
            .replace(['\n', ' '], "");
        let v = Value::parse(&line).unwrap();
        match Request::from_json(&v).unwrap() {
            Request::Submit { stream, .. } => assert!(!stream),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn frame_numerics_are_strict() {
        // fractional protocol versions are not "close enough"
        let v19 = Value::parse(r#"{"v":1.9,"type":"status"}"#).unwrap();
        assert!(Request::from_json(&v19).is_err());
        // negative / fractional counters are corrupt frames, not data
        let neg = Value::parse(
            r#"{"v":1,"type":"busy","capacity":-3}"#).unwrap();
        assert!(Response::from_json(&neg).is_err());
        let frac = Value::parse(
            r#"{"v":1,"type":"queued","id":2.5,"position":1}"#).unwrap();
        assert!(Response::from_json(&frac).is_err());
    }

    #[test]
    fn frame_io_over_a_buffer() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Request::Status.to_json()).unwrap();
        write_frame(&mut buf, &Request::Shutdown.to_json()).unwrap();
        assert_eq!(buf.iter().filter(|&&b| b == b'\n').count(), 2);
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_frame(&mut r).unwrap().unwrap();
        assert!(matches!(Request::from_json(&a).unwrap(), Request::Status));
        let b = read_frame(&mut r).unwrap().unwrap();
        assert!(matches!(Request::from_json(&b).unwrap(),
                         Request::Shutdown));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn metrics_verb_is_v2_only_and_roundtrips() {
        assert!(matches!(roundtrip_req(&Request::Metrics),
                         Request::Metrics));
        // a v1 frame asking for metrics is a typed parse error — the
        // v1 grammar is frozen
        let v1 = Value::parse(r#"{"v":1,"type":"metrics"}"#).unwrap();
        let err = Request::from_json(&v1).unwrap_err();
        assert!(format!("{:#}", err).contains("protocol v2"), "{:#}", err);
        // the response frame carries the full snapshot
        let metrics = crate::service::metrics::ServiceMetrics::new();
        metrics.submits.add(5);
        metrics.queue_wait.observe(0.01);
        let snap = metrics.snapshot(0, 2, 1, 3, &Profiler::new());
        match roundtrip_resp(&Response::Metrics(snap.clone())) {
            Response::Metrics(back) => assert_eq!(back, snap),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn trace_stamp_is_v2_only_additive_grammar() {
        let trace = TraceId::from_hex("00000000000000ff").unwrap();
        // a v2 frame gains the trace key (appended, so the base
        // grammar's byte order is untouched)…
        let mut v2 = Response::Queued { id: 4, position: 1 }.to_json_for(2);
        stamp_trace(&mut v2, 2, trace);
        assert_eq!(
            v2.to_string_compact(),
            r#"{"v":2,"type":"queued","id":4,"position":1,"trace":"00000000000000ff"}"#);
        assert_eq!(frame_trace(&v2), Some(trace));
        // …the stamped frame still parses (unknown-key tolerance)…
        assert!(matches!(Response::from_json(&v2).unwrap(),
                         Response::Queued { id: 4, position: 1 }));
        // …and a v1 frame stays bit-identical
        let mut v1 = Response::Queued { id: 4, position: 1 }.to_json_for(1);
        stamp_trace(&mut v1, 1, trace);
        assert_eq!(v1.to_string_compact(),
                   r#"{"v":1,"type":"queued","id":4,"position":1}"#);
        assert_eq!(frame_trace(&v1), None);
    }
}
