//! Versioned JSON-lines wire protocol for `simopt serve` / `simopt submit`
//! (DESIGN.md §14 gives the full grammar).
//!
//! Framing: every frame is ONE line of compact JSON
//! (`Value::to_string_compact` never emits a newline) terminated by `\n`,
//! over a Unix-domain stream socket.  Every frame carries `"v": 1`; a
//! server answers an unknown version or a malformed line with a typed
//! `error` frame rather than dropping the connection, so clients always
//! have something to report.
//!
//! Conversation shape: one *request* per connection.  `submit` is answered
//! by an immediate `queued` ack (or `busy` / `error`), then — on the same
//! connection, once a worker finishes — the final `result` frame; `status`
//! and `shutdown` are answered by a single frame.  Specs travel in the
//! canonical [`ExperimentSpec::to_json`] encoding, results as
//! [`RunResult::to_json`].

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{ExperimentSpec, RunResult};
use crate::util::json::{num, obj, s, Value};

/// Bump on any frame-grammar change; the server rejects other versions.
pub const PROTOCOL_VERSION: u64 = 1;

/// Client → server frames.
#[derive(Debug)]
pub enum Request {
    /// Run (or answer from cache) one experiment spec.
    Submit(Box<ExperimentSpec>),
    /// Report queue/cache/worker counters.
    Status,
    /// Stop accepting, drain admitted work, exit.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Value {
        let head = |t: &str| vec![("v", num(PROTOCOL_VERSION as f64)),
                                  ("type", s(t))];
        match self {
            Request::Submit(spec) => {
                let mut kv = head("submit");
                kv.push(("spec", spec.to_json()));
                obj(kv)
            }
            Request::Status => obj(head("status")),
            Request::Shutdown => obj(head("shutdown")),
        }
    }

    pub fn from_json(v: &Value) -> Result<Request> {
        check_version(v)?;
        match frame_type(v)? {
            "submit" => {
                let spec = v.get("spec")
                    .context("submit frame is missing 'spec'")?;
                Ok(Request::Submit(Box::new(ExperimentSpec::from_json(spec)?)))
            }
            "status" => Ok(Request::Status),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown request type '{}'", other),
        }
    }
}

/// Server status counters (the `status` response payload).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StatusInfo {
    pub queue_depth: usize,
    pub capacity: usize,
    pub workers: usize,
    /// Experiments actually executed (cache hits excluded).
    pub executed: u64,
    pub cache_entries: usize,
    pub cache_hits: u64,
}

/// Server → client frames.
#[derive(Debug)]
pub enum Response {
    /// Submit ack: admitted at 1-based queue `position`.
    Queued { id: u64, position: usize },
    /// Terminal submit answer: the run's payload, `cache_hit` marking a
    /// result served from the content-addressed cache with no execution.
    Completed { id: u64, cache_hit: bool, result: Box<RunResult> },
    /// Typed backpressure: the admission queue holds `capacity` requests.
    Busy { capacity: usize },
    /// Parse/validation/execution failure, with the reason.
    Error { message: String },
    Status(StatusInfo),
    /// Shutdown ack; the server drains admitted work, then exits.
    ShuttingDown,
}

impl Response {
    pub fn to_json(&self) -> Value {
        let head = |t: &str| vec![("v", num(PROTOCOL_VERSION as f64)),
                                  ("type", s(t))];
        match self {
            Response::Queued { id, position } => {
                let mut kv = head("queued");
                kv.push(("id", num(*id as f64)));
                kv.push(("position", num(*position as f64)));
                obj(kv)
            }
            Response::Completed { id, cache_hit, result } => {
                let mut kv = head("result");
                kv.push(("id", num(*id as f64)));
                kv.push(("cache_hit", Value::Bool(*cache_hit)));
                kv.push(("result", result.to_json()));
                obj(kv)
            }
            Response::Busy { capacity } => {
                let mut kv = head("busy");
                kv.push(("capacity", num(*capacity as f64)));
                obj(kv)
            }
            Response::Error { message } => {
                let mut kv = head("error");
                kv.push(("error", s(message)));
                obj(kv)
            }
            Response::Status(st) => {
                let mut kv = head("status");
                kv.push(("queue_depth", num(st.queue_depth as f64)));
                kv.push(("capacity", num(st.capacity as f64)));
                kv.push(("workers", num(st.workers as f64)));
                kv.push(("executed", num(st.executed as f64)));
                kv.push(("cache_entries", num(st.cache_entries as f64)));
                kv.push(("cache_hits", num(st.cache_hits as f64)));
                obj(kv)
            }
            Response::ShuttingDown => obj(head("shutting_down")),
        }
    }

    pub fn from_json(v: &Value) -> Result<Response> {
        check_version(v)?;
        let get_u64 = |key: &str| -> Result<u64> { frame_u64(v, key) };
        match frame_type(v)? {
            "queued" => Ok(Response::Queued {
                id: get_u64("id")?,
                position: get_u64("position")? as usize,
            }),
            "result" => Ok(Response::Completed {
                id: get_u64("id")?,
                cache_hit: v.get("cache_hit")
                    .and_then(Value::as_bool)
                    .context("result frame is missing 'cache_hit'")?,
                result: Box::new(RunResult::from_json(
                    v.get("result")
                        .context("result frame is missing 'result'")?)?),
            }),
            "busy" => Ok(Response::Busy {
                capacity: get_u64("capacity")? as usize,
            }),
            "error" => Ok(Response::Error {
                message: v.get("error")
                    .and_then(Value::as_str)
                    .context("error frame is missing 'error'")?
                    .to_string(),
            }),
            "status" => Ok(Response::Status(StatusInfo {
                queue_depth: get_u64("queue_depth")? as usize,
                capacity: get_u64("capacity")? as usize,
                workers: get_u64("workers")? as usize,
                executed: get_u64("executed")?,
                cache_entries: get_u64("cache_entries")? as usize,
                cache_hits: get_u64("cache_hits")?,
            })),
            "shutting_down" => Ok(Response::ShuttingDown),
            other => bail!("unknown response type '{}'", other),
        }
    }
}

fn frame_type(v: &Value) -> Result<&str> {
    v.get("type")
        .and_then(Value::as_str)
        .context("frame is missing 'type'")
}

/// Strict frame-field integer (`Value::as_uint`: present, non-negative,
/// no fraction) — a corrupt frame becomes a typed error, never a
/// silently truncated value.
fn frame_u64(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_uint)
        .with_context(|| format!("frame '{}' must be a non-negative \
                                  integer", key))
}

fn check_version(v: &Value) -> Result<()> {
    let got = frame_u64(v, "v")
        .context("frame carries no valid protocol version 'v'")?;
    anyhow::ensure!(got == PROTOCOL_VERSION,
                    "unsupported protocol version {} (this build speaks {})",
                    got, PROTOCOL_VERSION);
    Ok(())
}

/// Write one frame as a single JSON line.
pub fn write_frame(w: &mut impl Write, frame: &Value) -> Result<()> {
    let mut line = frame.to_string_compact();
    line.push('\n');
    w.write_all(line.as_bytes()).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame line; `None` at clean EOF.
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<Value>> {
    let mut line = String::new();
    let n = r.read_line(&mut line).context("reading frame")?;
    if n == 0 {
        return Ok(None);
    }
    let trimmed = line.trim();
    if trimmed.is_empty() {
        bail!("empty frame line");
    }
    Ok(Some(Value::parse(trimmed)
        .map_err(|e| anyhow!("malformed frame: {}", e))?))
}

/// One-request-per-connection client for the service socket — what
/// `simopt submit` and the served conformance arm drive.
pub struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Client {
    pub fn connect(socket: impl AsRef<Path>) -> Result<Client> {
        let socket = socket.as_ref();
        let stream = UnixStream::connect(socket).with_context(|| {
            format!("connecting to service socket {} (is `simopt serve` \
                     running?)", socket.display())
        })?;
        let writer = stream.try_clone().context("cloning socket stream")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn send(&mut self, req: &Request) -> Result<()> {
        write_frame(&mut self.writer, &req.to_json())
    }

    /// Read the next frame; EOF before a frame is a protocol error here
    /// (callers only recv when an answer is owed).
    pub fn recv(&mut self) -> Result<Response> {
        let v = read_frame(&mut self.reader)?
            .context("server closed the connection mid-conversation")?;
        Response::from_json(&v)
    }

    /// Submit a spec and return the terminal answer (`Completed`, `Busy`,
    /// or `Error`), reporting interim `queued` acks through `on_queued`.
    pub fn submit_with(&mut self, spec: &ExperimentSpec,
                       mut on_queued: impl FnMut(u64, usize))
        -> Result<Response> {
        self.send(&Request::Submit(Box::new(spec.clone())))?;
        loop {
            match self.recv()? {
                Response::Queued { id, position } => on_queued(id, position),
                terminal => return Ok(terminal),
            }
        }
    }

    /// [`Client::submit_with`] without an ack observer.
    pub fn submit(&mut self, spec: &ExperimentSpec) -> Result<Response> {
        self.submit_with(spec, |_, _| {})
    }

    pub fn status(&mut self) -> Result<StatusInfo> {
        self.send(&Request::Status)?;
        match self.recv()? {
            Response::Status(info) => Ok(info),
            Response::Error { message } => bail!("server error: {}", message),
            other => bail!("expected a status frame, got {:?}", other),
        }
    }

    /// Request graceful shutdown; returns once the server acked it.
    pub fn shutdown(&mut self) -> Result<()> {
        self.send(&Request::Shutdown)?;
        match self.recv()? {
            Response::ShuttingDown => Ok(()),
            Response::Error { message } => bail!("server error: {}", message),
            other => bail!("expected a shutting_down frame, got {:?}", other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, TaskKind};

    fn spec() -> ExperimentSpec {
        ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
    }

    fn roundtrip_req(r: &Request) -> Request {
        let line = r.to_json().to_string_compact();
        assert!(!line.contains('\n'));
        Request::from_json(&Value::parse(&line).unwrap()).unwrap()
    }

    fn roundtrip_resp(r: &Response) -> Response {
        let line = r.to_json().to_string_compact();
        assert!(!line.contains('\n'));
        Response::from_json(&Value::parse(&line).unwrap()).unwrap()
    }

    #[test]
    fn request_frames_roundtrip() {
        match roundtrip_req(&Request::Submit(Box::new(spec()))) {
            Request::Submit(back) => {
                assert_eq!(back.to_json().to_string_compact(),
                           spec().to_json().to_string_compact());
            }
            other => panic!("{:?}", other),
        }
        assert!(matches!(roundtrip_req(&Request::Status), Request::Status));
        assert!(matches!(roundtrip_req(&Request::Shutdown),
                         Request::Shutdown));
    }

    #[test]
    fn response_frames_roundtrip() {
        match roundtrip_resp(&Response::Queued { id: 9, position: 2 }) {
            Response::Queued { id: 9, position: 2 } => {}
            other => panic!("{:?}", other),
        }
        match roundtrip_resp(&Response::Busy { capacity: 16 }) {
            Response::Busy { capacity: 16 } => {}
            other => panic!("{:?}", other),
        }
        match roundtrip_resp(&Response::Error {
            message: "no such task 'wat'".into(),
        }) {
            Response::Error { message } => {
                assert_eq!(message, "no such task 'wat'")
            }
            other => panic!("{:?}", other),
        }
        let info = StatusInfo {
            queue_depth: 1,
            capacity: 8,
            workers: 2,
            executed: 40,
            cache_entries: 3,
            cache_hits: 7,
        };
        match roundtrip_resp(&Response::Status(info.clone())) {
            Response::Status(back) => assert_eq!(back, info),
            other => panic!("{:?}", other),
        }
        assert!(matches!(roundtrip_resp(&Response::ShuttingDown),
                         Response::ShuttingDown));
    }

    #[test]
    fn result_frame_carries_the_payload() {
        let result = RunResult::new(spec(), vec![]);
        let frame = Response::Completed {
            id: 3,
            cache_hit: true,
            result: Box::new(result),
        };
        match roundtrip_resp(&frame) {
            Response::Completed { id: 3, cache_hit: true, result } => {
                assert_eq!(result.spec.task, TaskKind::MeanVariance);
                assert!(result.reps.is_empty());
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn version_and_type_are_enforced() {
        let bad = Value::parse(r#"{"v":2,"type":"status"}"#).unwrap();
        assert!(Request::from_json(&bad).is_err());
        assert!(Response::from_json(&bad).is_err());
        let none = Value::parse(r#"{"type":"status"}"#).unwrap();
        assert!(Request::from_json(&none).is_err());
        let unk = Value::parse(r#"{"v":1,"type":"dance"}"#).unwrap();
        assert!(Request::from_json(&unk).is_err());
        assert!(Response::from_json(&unk).is_err());
    }

    #[test]
    fn frame_numerics_are_strict() {
        // fractional protocol versions are not "close enough"
        let v19 = Value::parse(r#"{"v":1.9,"type":"status"}"#).unwrap();
        assert!(Request::from_json(&v19).is_err());
        // negative / fractional counters are corrupt frames, not data
        let neg = Value::parse(
            r#"{"v":1,"type":"busy","capacity":-3}"#).unwrap();
        assert!(Response::from_json(&neg).is_err());
        let frac = Value::parse(
            r#"{"v":1,"type":"queued","id":2.5,"position":1}"#).unwrap();
        assert!(Response::from_json(&frac).is_err());
    }

    #[test]
    fn frame_io_over_a_buffer() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Request::Status.to_json()).unwrap();
        write_frame(&mut buf, &Request::Shutdown.to_json()).unwrap();
        assert_eq!(buf.iter().filter(|&&b| b == b'\n').count(), 2);
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_frame(&mut r).unwrap().unwrap();
        assert!(matches!(Request::from_json(&a).unwrap(), Request::Status));
        let b = read_frame(&mut r).unwrap().unwrap();
        assert!(matches!(Request::from_json(&b).unwrap(),
                         Request::Shutdown));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }
}
