//! Content-addressed result cache (DESIGN.md §14).
//!
//! Entries are keyed by [`ExperimentSpec::spec_hash`] — the FNV-1a hash
//! of the spec's canonical JSON (delivery fields like `results_dir`
//! excluded), computed over the *validated* spec.  The canonical string
//! itself is stored next to each entry and compared on lookup, so a hash
//! collision degrades to a cache miss (the later spec recomputes and
//! takes the slot), never to returning another experiment's result.
//!
//! Every run in this repo is deterministic given its spec (that is the
//! whole §11/§13 invariant), which is what makes result caching *sound*:
//! a repeat submission's recomputation would be bit-identical to the
//! stored payload, so the service skips it and answers from the cache
//! with a `cache_hit` marker.
//!
//! The cache is bounded (`simopt serve --cache N` entries): payloads
//! carry full per-replication traces, and a long-lived server under
//! heavy traffic must not grow without limit.  Eviction is
//! insertion-order FIFO — the oldest entry leaves when the bound is hit;
//! an evicted spec simply recomputes on its next submission, so eviction
//! can never change an answer.  Capacity 0 disables caching entirely.
//!
//! [`ExperimentSpec::spec_hash`]: crate::coordinator::ExperimentSpec::spec_hash

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::util::json::Value;

struct Entry {
    /// The canonical spec string the key was hashed from.
    canonical: String,
    /// The stored `RunResult::to_json` payload.  Behind an `Arc` so a hit
    /// hands out a reference-count bump, not a deep clone of a full
    /// trace payload, while the cache mutex is held.
    result: Arc<Value>,
}

struct State {
    map: HashMap<u64, Entry>,
    /// Keys in insertion order (FIFO eviction victims from the front).
    order: VecDeque<u64>,
    hits: u64,
}

/// Shared across the server's handler and worker threads.
pub struct ResultCache {
    state: Mutex<State>,
    capacity: usize,
}

impl ResultCache {
    pub fn new(capacity: usize) -> Self {
        ResultCache {
            state: Mutex::new(State {
                map: HashMap::new(),
                order: VecDeque::new(),
                hits: 0,
            }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Stored payload for `(key, canonical)`, counting a hit.  A key match
    /// with a different canonical string is a collision → miss.
    pub fn get(&self, key: u64, canonical: &str) -> Option<Arc<Value>> {
        let mut st = self.state.lock().unwrap();
        match st.map.get(&key) {
            Some(e) if e.canonical == canonical => {
                let v = Arc::clone(&e.result);
                st.hits += 1;
                Some(v)
            }
            _ => None,
        }
    }

    /// Store (or replace) the payload for `key`, evicting the oldest
    /// entries past the capacity bound.
    pub fn insert(&self, key: u64, canonical: &str, result: Arc<Value>) {
        if self.capacity == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let entry = Entry { canonical: canonical.to_string(), result };
        if st.map.insert(key, entry).is_none() {
            st.order.push_back(key);
        }
        while st.map.len() > self.capacity {
            match st.order.pop_front() {
                Some(old) => {
                    st.map.remove(&old);
                }
                None => break,
            }
        }
    }

    pub fn entries(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    pub fn hits(&self) -> u64 {
        self.state.lock().unwrap().hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn miss_then_hit() {
        let c = ResultCache::new(8);
        assert!(c.get(7, "spec-a").is_none());
        assert_eq!(c.hits(), 0);
        c.insert(7, "spec-a", Arc::new(obj(vec![("x", num(1.0))])));
        assert_eq!(c.entries(), 1);
        let v = c.get(7, "spec-a").unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.entries(), 1);
    }

    #[test]
    fn collision_degrades_to_miss_not_wrong_result() {
        let c = ResultCache::new(8);
        c.insert(7, "spec-a", Arc::new(obj(vec![("x", num(1.0))])));
        // same key, different canonical content: NOT served
        assert!(c.get(7, "spec-b").is_none());
        assert_eq!(c.hits(), 0);
        // the later spec takes the slot
        c.insert(7, "spec-b", Arc::new(obj(vec![("x", num(2.0))])));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.get(7, "spec-b").unwrap().get("x").unwrap().as_f64(),
                   Some(2.0));
        assert!(c.get(7, "spec-a").is_none());
    }

    #[test]
    fn distinct_keys_coexist() {
        let c = ResultCache::new(8);
        c.insert(1, "a", Arc::new(num(1.0)));
        c.insert(2, "b", Arc::new(num(2.0)));
        assert_eq!(c.entries(), 2);
        assert_eq!(c.get(1, "a").unwrap().as_f64(), Some(1.0));
        assert_eq!(c.get(2, "b").unwrap().as_f64(), Some(2.0));
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn capacity_bound_evicts_oldest_first() {
        let c = ResultCache::new(2);
        c.insert(1, "a", Arc::new(num(1.0)));
        c.insert(2, "b", Arc::new(num(2.0)));
        c.insert(3, "c", Arc::new(num(3.0)));
        assert_eq!(c.entries(), 2, "bound holds");
        assert!(c.get(1, "a").is_none(), "oldest entry evicted");
        assert!(c.get(2, "b").is_some());
        assert!(c.get(3, "c").is_some());
        // replacing an existing key does not grow the cache or re-evict
        c.insert(2, "b2", Arc::new(num(4.0)));
        assert_eq!(c.entries(), 2);
        assert!(c.get(3, "c").is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = ResultCache::new(0);
        c.insert(1, "a", Arc::new(num(1.0)));
        assert_eq!(c.entries(), 0);
        assert!(c.get(1, "a").is_none());
        assert_eq!(c.hits(), 0);
    }
}
