//! Bounded FIFO admission queue with typed backpressure (DESIGN.md §14).
//!
//! The serving plane must never buffer unboundedly: a full queue answers
//! `try_push` with [`PushError::Full`] *immediately*, which the server
//! turns into a typed `busy` frame instead of a hung client.  Workers
//! drain with blocking [`Bounded::pop`]; [`Bounded::close`] flips the
//! queue into drain mode — pops keep returning queued items until the
//! queue is empty, then return `None` so workers exit, which is exactly
//! the graceful-shutdown order the server needs (admitted work always
//! gets an answer).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why an item was not admitted.  Both variants hand the item back so the
/// caller can still answer the client that carried it.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue holds `capacity` items — typed backpressure, not a wait.
    Full(T),
    /// [`Bounded::close`] ran; the service is draining toward shutdown.
    Closed(T),
}

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// A mutex/condvar bounded FIFO.  `capacity == 0` is legal and admits
/// nothing — every push answers `Full`, which the conformance suite uses
/// to exercise the busy path deterministically.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Self {
        Bounded {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queued (not yet popped) item count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit `item` if there is room; returns its 1-based queue position
    /// (how many pops until a worker holds it).
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.q.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.q.push_back(item);
        let pos = st.q.len();
        drop(st);
        self.cv.notify_one();
        Ok(pos)
    }

    /// Block until an item is available and return it; `None` once the
    /// queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.q.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop admitting; wake every waiting worker so the drain starts.
    /// Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_positions() {
        let q = Bounded::new(3);
        assert_eq!(q.try_push(10).unwrap(), 1);
        assert_eq!(q.try_push(11).unwrap(), 2);
        assert_eq!(q.try_push(12).unwrap(), 3);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.try_push(13).unwrap(), 2);
        assert_eq!(q.pop(), Some(12));
        assert_eq!(q.pop(), Some(13));
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_is_typed_backpressure() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {:?}", other),
        }
        // popping frees a slot
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(3).unwrap(), 2);
    }

    #[test]
    fn zero_capacity_admits_nothing() {
        let q: Bounded<u32> = Bounded::new(0);
        assert!(matches!(q.try_push(1), Err(PushError::Full(1))));
        assert_eq!(q.capacity(), 0);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        // admitted work survives the close…
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        // …new work does not
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert_eq!(q.pop(), None);
        // close is idempotent
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(1));
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop())
        };
        // the worker blocks on the empty queue until close() wakes it
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(worker.join().unwrap(), None);
    }

    #[test]
    fn cross_thread_handoff() {
        let q: Arc<Bounded<usize>> = Arc::new(Bounded::new(64));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        for i in 0..50 {
            // back off if the consumer falls behind the bound
            loop {
                match q.try_push(i) {
                    Ok(_) => break,
                    Err(PushError::Full(_)) => std::thread::yield_now(),
                    Err(PushError::Closed(_)) => unreachable!(),
                }
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }
}
