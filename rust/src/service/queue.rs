//! Bounded FIFO admission queue with typed backpressure (DESIGN.md §14).
//!
//! The serving plane must never buffer unboundedly: a full queue answers
//! `try_push` with [`PushError::Full`] *immediately*, which the server
//! turns into a typed `busy` frame instead of a hung client.  Workers
//! drain with blocking [`Bounded::pop`]; [`Bounded::close`] flips the
//! queue into drain mode — pops keep returning queued items until the
//! queue is empty, then return `None` so workers exit, which is exactly
//! the graceful-shutdown order the server needs (admitted work always
//! gets an answer).
//!
//! Observability (DESIGN.md §18): every push stamps the item with the
//! process-wide monotonic clock, so the queue-wait a [`Popped`] reports
//! is *measured per job*, never inferred from depth; the queue also
//! keeps its all-time high-water mark, which the metrics registry
//! exposes as a gauge next to the live depth.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::util::trace::now_us;

/// Why an item was not admitted.  Both variants hand the item back so the
/// caller can still answer the client that carried it.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue holds `capacity` items — typed backpressure, not a wait.
    Full(T),
    /// [`Bounded::close`] ran; the service is draining toward shutdown.
    Closed(T),
}

/// A popped item plus its measured admission-queue residence.
#[derive(Debug, Clone, PartialEq)]
pub struct Popped<T> {
    pub item: T,
    /// When the item was pushed, on the `util::trace::now_us` clock —
    /// the span recorder uses it as the `queue_wait` span's start.
    pub enqueued_us: u64,
    /// Seconds between push and this pop.
    pub wait_s: f64,
}

struct State<T> {
    q: VecDeque<(T, u64)>,
    closed: bool,
    /// Deepest the queue has ever been (post-push depth), for the
    /// `queue_depth_high_water` gauge.
    high_water: usize,
}

/// A mutex/condvar bounded FIFO.  `capacity == 0` is legal and admits
/// nothing — every push answers `Full`, which the conformance suite uses
/// to exercise the busy path deterministically.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Self {
        Bounded {
            state: Mutex::new(State {
                q: VecDeque::new(),
                closed: false,
                high_water: 0,
            }),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queued (not yet popped) item count — the live depth gauge.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deepest post-push depth ever observed — the
    /// `queue_depth_high_water` gauge.  Monotone; never resets.
    pub fn high_water(&self) -> usize {
        self.state.lock().unwrap().high_water
    }

    /// Admit `item` if there is room; returns its 1-based queue position
    /// (how many pops until a worker holds it).  The enqueue instant is
    /// stamped under the same lock, so wait measurement starts exactly
    /// at admission.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.q.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.q.push_back((item, now_us()));
        let pos = st.q.len();
        st.high_water = st.high_water.max(pos);
        drop(st);
        self.cv.notify_one();
        Ok(pos)
    }

    /// Block until an item is available and return it with its measured
    /// queue residence; `None` once the queue is closed AND drained.
    pub fn pop(&self) -> Option<Popped<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some((item, enqueued_us)) = st.q.pop_front() {
                let wait_s =
                    now_us().saturating_sub(enqueued_us) as f64 / 1e6;
                return Some(Popped { item, enqueued_us, wait_s });
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop admitting; wake every waiting worker so the drain starts.
    /// Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Item-only view of a pop, for the ordering assertions.
    fn pop_item<T>(q: &Bounded<T>) -> Option<T> {
        q.pop().map(|p| p.item)
    }

    #[test]
    fn fifo_order_and_positions() {
        let q = Bounded::new(3);
        assert_eq!(q.try_push(10).unwrap(), 1);
        assert_eq!(q.try_push(11).unwrap(), 2);
        assert_eq!(q.try_push(12).unwrap(), 3);
        assert_eq!(q.len(), 3);
        assert_eq!(pop_item(&q), Some(10));
        assert_eq!(pop_item(&q), Some(11));
        assert_eq!(q.try_push(13).unwrap(), 2);
        assert_eq!(pop_item(&q), Some(12));
        assert_eq!(pop_item(&q), Some(13));
        assert!(q.is_empty());
    }

    #[test]
    fn queue_wait_is_measured_not_inferred() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let popped = q.pop().unwrap();
        assert_eq!(popped.item, 1);
        // the 5ms sleep happened between push and pop, so the measured
        // wait must cover it (and the stamp must predate the pop)
        assert!(popped.wait_s >= 0.004, "wait_s={}", popped.wait_s);
        assert!(popped.enqueued_us <= crate::util::trace::now_us());
        // an instant pop measures (almost) nothing
        q.try_push(2).unwrap();
        let quick = q.pop().unwrap();
        assert!(quick.wait_s < 1.0, "wait_s={}", quick.wait_s);
        assert!(quick.enqueued_us >= popped.enqueued_us, "same clock");
    }

    #[test]
    fn high_water_tracks_the_deepest_push() {
        let q = Bounded::new(3);
        assert_eq!(q.high_water(), 0);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.high_water(), 2);
        // draining does not lower the mark…
        assert_eq!(pop_item(&q), Some(1));
        assert_eq!(pop_item(&q), Some(2));
        assert_eq!(q.len(), 0);
        assert_eq!(q.high_water(), 2);
        // …and only a deeper push raises it
        q.try_push(3).unwrap();
        assert_eq!(q.high_water(), 2);
        q.try_push(4).unwrap();
        q.try_push(5).unwrap();
        assert_eq!(q.high_water(), 3);
        // rejected pushes never count
        assert!(matches!(q.try_push(6), Err(PushError::Full(6))));
        assert_eq!(q.high_water(), 3);
    }

    #[test]
    fn full_queue_is_typed_backpressure() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {:?}", other),
        }
        // popping frees a slot
        assert_eq!(pop_item(&q), Some(1));
        assert_eq!(q.try_push(3).unwrap(), 2);
    }

    #[test]
    fn zero_capacity_admits_nothing() {
        let q: Bounded<u32> = Bounded::new(0);
        assert!(matches!(q.try_push(1), Err(PushError::Full(1))));
        assert_eq!(q.capacity(), 0);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        // admitted work survives the close…
        assert_eq!(pop_item(&q), Some(1));
        assert_eq!(pop_item(&q), Some(2));
        // …new work does not
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert!(q.pop().is_none());
        // close is idempotent
        q.close();
        assert!(q.pop().is_none());
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(1));
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop())
        };
        // the worker blocks on the empty queue until close() wakes it
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(worker.join().unwrap().is_none());
    }

    #[test]
    fn cross_thread_handoff() {
        let q: Arc<Bounded<usize>> = Arc::new(Bounded::new(64));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(p) = q.pop() {
                    got.push(p.item);
                }
                got
            })
        };
        for i in 0..50 {
            // back off if the consumer falls behind the bound
            loop {
                match q.try_push(i) {
                    Ok(_) => break,
                    Err(PushError::Full(_)) => std::thread::yield_now(),
                    Err(PushError::Closed(_)) => unreachable!(),
                }
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }
}
