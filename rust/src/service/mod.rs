//! The persistent experiment service (`simopt serve` / `simopt submit`,
//! DESIGN.md §14).
//!
//! PRs 1–4 built the execution stack — batched replication spine, task
//! registry, shard-aware panel plane — but its only entry point was a
//! one-shot CLI process that pays full startup (artifact load, engine
//! init, thread budget discovery) per experiment.  Lee et al. and
//! Zhou–Lange–Suchard both locate the accelerator speedup in amortizing
//! dispatch/setup across many concurrent requests; this module is that
//! amortization layer: a server that keeps [`Coordinator`] state warm
//! across requests, behind a small, versioned JSON-lines protocol over a
//! Unix-domain socket.
//!
//! * [`protocol`] — frame grammar + [`Client`] and its per-conversation
//!   [`Session`] handle; specs travel in their canonical
//!   [`ExperimentSpec::to_json`] encoding.  Protocol v2 adds streaming
//!   submits (`stream` → per-epoch `progress` frames) and typed
//!   `unsupported_version` answers; v1 conversations are still served
//!   verbatim, at their own version.
//! * [`queue`] — bounded FIFO admission with typed `busy` backpressure;
//!   per-job enqueue timestamps make queue-wait a measured quantity
//!   (DESIGN.md §18).
//! * [`cache`] — content-addressed results keyed by
//!   [`ExperimentSpec::spec_hash`]; repeat submissions re-execute nothing.
//! * [`metrics`] — the lock-cheap service metrics registry behind the
//!   v2-only `metrics` verb (counters, gauges, fixed-bucket histograms;
//!   JSON + Prometheus-style expositions; DESIGN.md §18).
//! * [`server`] — accept loop, warm per-worker coordinators, graceful
//!   drain on `shutdown`; mints a [`TraceId`] per conversation, stamps
//!   it on every v2 frame, and (with `--trace-out`) records the
//!   request's admission/cache/queue/execute/relay spans as
//!   Chrome-trace JSONL.
//!
//! [`TraceId`]: crate::util::trace::TraceId
//!
//! The serving path inherits the repo's core invariant unchanged: a
//! served result is bit-identical to a direct `simopt run` of the same
//! spec on every exec plan and legal shard count, enforced by
//! `tests/service_conformance.rs` and the CI service smoke.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator
//! [`ExperimentSpec::to_json`]: crate::coordinator::ExperimentSpec::to_json
//! [`ExperimentSpec::spec_hash`]:
//!     crate::coordinator::ExperimentSpec::spec_hash

pub mod cache;
pub mod metrics;
pub mod protocol;
pub mod queue;
pub mod server;

pub use cache::ResultCache;
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use protocol::{Client, ProgressInfo, Request, Response, Session,
                   StatusInfo, WorkerStats, MIN_PROTOCOL_VERSION,
                   PROTOCOL_VERSION};
pub use queue::{Bounded, Popped, PushError};
pub use server::{Server, ServerConfig, ServerStats};
