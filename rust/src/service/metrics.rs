//! The service metrics registry (DESIGN.md §18): lock-cheap counters
//! and fixed-bucket histograms the serving plane updates as requests
//! flow, snapshotted on demand by the v2-only `metrics` protocol verb.
//!
//! Everything on the hot path is a relaxed atomic — one `fetch_add` per
//! event, no locks, no allocation — and every update happens OUTSIDE
//! the timed regions (admission, relay, post-run bookkeeping), so the
//! §15/§18 invariance bar holds: a metered run is bitwise-identical to
//! an unmetered one.
//!
//! A [`MetricsSnapshot`] is the exposition surface, rendered two ways:
//! * JSON — what the `metrics` frame carries on the wire
//!   (`{"counters":…,"gauges":…,"histograms":…,"per_phase":…}`);
//! * Prometheus-style text ([`MetricsSnapshot::to_prometheus`]) — what
//!   `simopt submit --metrics` prints for scraping/grepping, every
//!   family prefixed `simopt_` with `# TYPE` headers, histograms in
//!   cumulative `_bucket{le=…}` / `_sum` / `_count` form.
//!
//! Gauges (queue depth / high-water mark, cache entries) and the
//! per-phase totals are *read at snapshot time* from their owners (the
//! queue, the cache, `Shared.phase_totals`) rather than duplicated as
//! registry state — one source of truth per number.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, Value};
use crate::util::profile::Profiler;

/// Monotone event counter.  Relaxed ordering: counters are statistics,
/// not synchronization.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket upper bounds (seconds) shared by the latency histograms —
/// spanning the sub-millisecond native smoke runs through multi-minute
/// sweeps.  An implicit `+Inf` bucket follows the last bound.
pub const LATENCY_BOUNDS_S: [f64; 8] =
    [0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0, 60.0];

/// Fixed-bucket histogram of seconds.  `observe` is two relaxed
/// `fetch_add`s plus one bounded scan of the 8 bounds; the sum is
/// accumulated in integer microseconds so it needs no float CAS loop.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; LATENCY_BOUNDS_S.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Default::default(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, seconds: f64) {
        let seconds = if seconds.is_finite() && seconds > 0.0 {
            seconds
        } else {
            0.0
        };
        let idx = LATENCY_BOUNDS_S
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(LATENCY_BOUNDS_S.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: LATENCY_BOUNDS_S.to_vec(),
            counts: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            sum_s: self.sum_us.load(Ordering::Relaxed) as f64 / 1e6,
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one histogram.  `counts` is per-bucket
/// (NON-cumulative; one extra overflow bucket past the last bound) —
/// the Prometheus renderer produces the cumulative form.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum_s: f64,
    pub count: u64,
}

impl HistogramSnapshot {
    /// Mean observed seconds (0 when empty) — what the trajectory
    /// tool's queue-wait trend row plots.
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    fn to_json(&self) -> Value {
        obj(vec![
            ("bounds", arr(self.bounds.iter().map(|&b| num(b)).collect())),
            ("counts",
             arr(self.counts.iter().map(|&c| num(c as f64)).collect())),
            ("sum_s", num(self.sum_s)),
            ("count", num(self.count as f64)),
        ])
    }

    fn from_json(v: &Value) -> Result<HistogramSnapshot> {
        let floats = |key: &str| -> Result<Vec<f64>> {
            v.get(key)
                .and_then(Value::as_arr)
                .with_context(|| format!("histogram missing '{}'", key))?
                .iter()
                .map(|x| {
                    x.as_f64().with_context(|| {
                        format!("'{}' entries must be numbers", key)
                    })
                })
                .collect()
        };
        let counts: Vec<u64> = v
            .get("counts")
            .and_then(Value::as_arr)
            .context("histogram missing 'counts'")?
            .iter()
            .map(|x| {
                x.as_uint()
                    .context("'counts' entries must be non-negative \
                              integers")
            })
            .collect::<Result<_>>()?;
        Ok(HistogramSnapshot {
            bounds: floats("bounds")?,
            counts,
            sum_s: v
                .get("sum_s")
                .and_then(Value::as_f64)
                .context("histogram missing 'sum_s'")?,
            count: v
                .get("count")
                .and_then(Value::as_uint)
                .context("histogram missing 'count'")?,
        })
    }
}

/// The live registry the server owns (one per `Server::run`).  Field
/// names ARE the metric names (suffixed `_total` in expositions).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Submit requests admitted for parsing (any outcome).
    pub submits: Counter,
    /// Experiments actually executed by a worker (cache hits excluded).
    pub runs_executed: Counter,
    /// Admission-time fast-path cache misses (submissions that had to
    /// queue); total hits come from the cache itself at snapshot time.
    pub cache_misses: Counter,
    /// Submits bounced with the typed `busy` frame (queue full).
    pub busy_rejections: Counter,
    /// Worker frames relayed onto submit conversations (progress +
    /// terminal) — the relay volume.  Admission acks and fast-path cache
    /// answers are handler-local writes, not relays.
    pub frames_relayed: Counter,
    /// Replication rows frozen by adaptive budgets, summed over runs.
    pub frozen_rows: Counter,
    /// Per-job admission-queue wait, measured from the queue's own
    /// enqueue timestamps (never inferred).
    pub queue_wait: Histogram,
    /// Worker wall-clock per executed run (outside-timed-region stamps
    /// around the run; the run's own §15 profile is untouched).
    pub run_latency: Histogram,
}

impl ServiceMetrics {
    pub fn new() -> ServiceMetrics {
        ServiceMetrics::default()
    }

    /// Freeze the registry plus the externally-owned gauges into one
    /// exposition value.
    pub fn snapshot(&self, queue_depth: usize, queue_high_water: usize,
                    cache_entries: usize, cache_hits: u64,
                    per_phase: &Profiler) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: vec![
                ("submits_total".into(), self.submits.get()),
                ("runs_executed_total".into(), self.runs_executed.get()),
                ("cache_hits_total".into(), cache_hits),
                ("cache_misses_total".into(), self.cache_misses.get()),
                ("busy_rejections_total".into(),
                 self.busy_rejections.get()),
                ("frames_relayed_total".into(), self.frames_relayed.get()),
                ("frozen_rows_total".into(), self.frozen_rows.get()),
            ],
            gauges: vec![
                ("queue_depth".into(), queue_depth as u64),
                ("queue_depth_high_water".into(), queue_high_water as u64),
                ("cache_entries".into(), cache_entries as u64),
            ],
            histograms: vec![
                ("queue_wait_seconds".into(), self.queue_wait.snapshot()),
                ("run_latency_seconds".into(),
                 self.run_latency.snapshot()),
            ],
            per_phase: *per_phase,
        }
    }
}

/// What the `metrics` verb answers (and `submit --metrics` renders):
/// ordered counters/gauges/histograms plus the server's aggregate
/// per-phase seconds (§15), as one wire value.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
    pub per_phase: Profiler,
}

impl MetricsSnapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("counters",
             Value::Obj(self.counters.iter()
                 .map(|(n, v)| (n.clone(), num(*v as f64)))
                 .collect())),
            ("gauges",
             Value::Obj(self.gauges.iter()
                 .map(|(n, v)| (n.clone(), num(*v as f64)))
                 .collect())),
            ("histograms",
             Value::Obj(self.histograms.iter()
                 .map(|(n, h)| (n.clone(), h.to_json()))
                 .collect())),
            ("per_phase", self.per_phase.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<MetricsSnapshot> {
        let uint_entries = |key: &str| -> Result<Vec<(String, u64)>> {
            v.get(key)
                .and_then(Value::as_obj)
                .with_context(|| format!("metrics missing '{}'", key))?
                .iter()
                .map(|(n, x)| {
                    x.as_uint()
                        .map(|u| (n.clone(), u))
                        .with_context(|| format!(
                            "metrics '{}.{}' must be a non-negative \
                             integer", key, n))
                })
                .collect()
        };
        let histograms = v
            .get("histograms")
            .and_then(Value::as_obj)
            .context("metrics missing 'histograms'")?
            .iter()
            .map(|(n, h)| {
                HistogramSnapshot::from_json(h)
                    .map(|s| (n.clone(), s))
                    .with_context(|| format!("parsing histogram '{}'", n))
            })
            .collect::<Result<_>>()?;
        Ok(MetricsSnapshot {
            counters: uint_entries("counters")?,
            gauges: uint_entries("gauges")?,
            histograms,
            per_phase: match v.get("per_phase") {
                None | Some(Value::Null) => Profiler::new(),
                Some(pp) => Profiler::from_json(pp)
                    .context("parsing metrics 'per_phase'")?,
            },
        })
    }

    /// Prometheus-style text exposition: `simopt_`-prefixed families
    /// with `# TYPE` headers; histograms in cumulative
    /// `_bucket{le="…"}` / `_sum` / `_count` form; per-phase seconds as
    /// one labeled counter family.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, value) in &self.counters {
            let _ = writeln!(out, "# TYPE simopt_{} counter", name);
            let _ = writeln!(out, "simopt_{} {}", name, value);
        }
        for (name, value) in &self.gauges {
            let _ = writeln!(out, "# TYPE simopt_{} gauge", name);
            let _ = writeln!(out, "simopt_{} {}", name, value);
        }
        for (name, h) in &self.histograms {
            let _ = writeln!(out, "# TYPE simopt_{} histogram", name);
            let mut cumulative = 0u64;
            for (i, bound) in h.bounds.iter().enumerate() {
                cumulative += h.counts.get(i).copied().unwrap_or(0);
                let _ = writeln!(out,
                                 "simopt_{}_bucket{{le=\"{}\"}} {}",
                                 name, bound, cumulative);
            }
            let _ = writeln!(out, "simopt_{}_bucket{{le=\"+Inf\"}} {}",
                             name, h.count);
            let _ = writeln!(out, "simopt_{}_sum {}", name, h.sum_s);
            let _ = writeln!(out, "simopt_{}_count {}", name, h.count);
        }
        let _ = writeln!(out, "# TYPE simopt_phase_seconds_total counter");
        for phase in crate::util::profile::Phase::ALL {
            let _ = writeln!(out,
                             "simopt_phase_seconds_total{{phase=\"{}\"}} {}",
                             phase.as_str(), self.per_phase.get(phase));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::profile::Phase;

    fn sample() -> MetricsSnapshot {
        let m = ServiceMetrics::new();
        m.submits.add(3);
        m.runs_executed.add(2);
        m.cache_misses.add(2);
        m.frames_relayed.add(7);
        m.queue_wait.observe(0.0004);
        m.queue_wait.observe(0.3);
        m.run_latency.observe(0.02);
        let mut pp = Profiler::new();
        pp.add(Phase::Compute, 1.25);
        m.snapshot(1, 4, 2, 1, &pp)
    }

    #[test]
    fn counters_and_gauges_land_in_the_snapshot() {
        let snap = sample();
        assert_eq!(snap.counter("submits_total"), Some(3));
        assert_eq!(snap.counter("runs_executed_total"), Some(2));
        assert_eq!(snap.counter("cache_hits_total"), Some(1));
        assert_eq!(snap.counter("cache_misses_total"), Some(2));
        assert_eq!(snap.counter("busy_rejections_total"), Some(0));
        assert_eq!(snap.counter("no_such"), None);
        assert_eq!(snap.gauge("queue_depth"), Some(1));
        assert_eq!(snap.gauge("queue_depth_high_water"), Some(4));
        assert_eq!(snap.gauge("cache_entries"), Some(2));
    }

    #[test]
    fn histogram_buckets_sums_and_mean() {
        let h = Histogram::default();
        h.observe(0.0005); // ≤ 0.001 → bucket 0
        h.observe(0.05); // ≤ 0.1 → bucket 3
        h.observe(120.0); // > 60 → overflow bucket
        h.observe(-1.0); // clamped to 0 → bucket 0
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.counts.len(), LATENCY_BOUNDS_S.len() + 1);
        assert_eq!(s.counts[0], 2);
        assert_eq!(s.counts[3], 1);
        assert_eq!(s.counts[LATENCY_BOUNDS_S.len()], 1);
        assert!((s.sum_s - 120.0505).abs() < 1e-3, "{}", s.sum_s);
        assert!((s.mean_s() - s.sum_s / 4.0).abs() < 1e-12);
        assert_eq!(HistogramSnapshot {
            bounds: vec![],
            counts: vec![],
            sum_s: 0.0,
            count: 0,
        }.mean_s(), 0.0);
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let snap = sample();
        let back =
            MetricsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        // corrupt counters are typed errors, not truncated data
        let mut bad = snap.to_json();
        if let Value::Obj(kv) = &mut bad {
            kv.retain(|(k, _)| k != "counters");
        }
        assert!(MetricsSnapshot::from_json(&bad).is_err());
    }

    #[test]
    fn prometheus_exposition_grammar() {
        let text = sample().to_prometheus();
        assert!(text.contains("# TYPE simopt_submits_total counter"));
        assert!(text.contains("\nsimopt_submits_total 3\n")
                    || text.starts_with("simopt_submits_total 3"),
                "{}", text);
        assert!(text.contains("simopt_runs_executed_total 2"));
        assert!(text.contains("# TYPE simopt_queue_depth gauge"));
        assert!(text.contains("simopt_queue_depth 1"));
        assert!(text.contains(
            "# TYPE simopt_queue_wait_seconds histogram"));
        // cumulative buckets: the 0.3s observation joins at le="0.5"
        assert!(text.contains(
            "simopt_queue_wait_seconds_bucket{le=\"0.001\"} 1"));
        assert!(text.contains(
            "simopt_queue_wait_seconds_bucket{le=\"0.5\"} 2"));
        assert!(text.contains(
            "simopt_queue_wait_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("simopt_queue_wait_seconds_count 2"));
        assert!(text.contains(
            "simopt_phase_seconds_total{phase=\"compute\"} 1.25"));
        // every line is header or sample — no blank or stray lines
        for line in text.lines() {
            assert!(line.starts_with("# TYPE simopt_")
                        || line.starts_with("simopt_"),
                    "stray line: {:?}", line);
        }
    }
}
