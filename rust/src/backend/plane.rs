//! The shard-aware panel execution plane (DESIGN.md §13).
//!
//! PR 1–3 fused the replication axis into ONE monolithic `[R × n]` panel
//! driven through one batch backend — which caps R at what a single
//! dispatch or thread pool can hold, and leaves no seam for multi-device
//! or multi-client execution.  This module splits that spine without
//! touching the math: a [`ShardMap`] partitions the R replication rows
//! into S *contiguous* shards, [`Panel`]/[`PanelMut`] views slice every
//! `[R × n]` buffer along that partition with zero copies, and
//! [`ShardedBatch`] wraps one inner batch backend per shard behind the
//! SAME `*BatchBackend` traits the drivers already consume — so
//! `opt::{run_mv_batch, run_nv_batch, run_sqn_batch}` are shard-agnostic
//! and no task owns sharding code.
//!
//! The refactor invariant: shard boundaries must not change per-row
//! arithmetic.  Every row keeps its own `StreamTree` subtree and runs the
//! same operations in the same order whatever S is, so `S = s` is
//! bit-identical to `S = 1` is bit-identical to sequential on the native
//! arm (`tests/batch_determinism.rs` enforces this for every registered
//! task, including `R % S ≠ 0` and `S = R`).  Only buffer ownership and
//! dispatch granularity move.
//!
//! Two [`ShardPolicy`] arms mirror the backend axis:
//! * [`Pooled`] (native) — shards advance concurrently on
//!   `util::pool` scoped workers, one worker per shard chunk;
//! * [`Serial`] (XLA) — shards advance in order on the caller's thread,
//!   one artifact dispatch per shard sized `[R/S × …]`, so a future
//!   multi-device PJRT build maps shard → device with no driver change.

use std::marker::PhantomData;
use std::ops::Range;
use std::sync::Mutex;

use anyhow::Result;

use crate::tasks::BatchMemView;
use crate::util::pool::parallel_map_chunks;
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::{LrBatchBackend, MvBatchBackend, NvBatchBackend};

// ---------------------------------------------------------------------------
// ShardMap: the one partition everything slices by
// ---------------------------------------------------------------------------

/// Balanced contiguous partition of `reps` replication rows into `shards`
/// ranges: the first `reps % shards` shards carry one extra row, so sizes
/// differ by at most one and concatenating the ranges in order recovers
/// `0..reps` exactly.
#[derive(Debug, Clone)]
pub struct ShardMap {
    reps: usize,
    ranges: Vec<Range<usize>>,
}

impl ShardMap {
    pub fn new(reps: usize, shards: usize) -> Result<Self> {
        anyhow::ensure!(reps > 0, "reps must be positive");
        anyhow::ensure!(shards > 0, "shards must be positive");
        anyhow::ensure!(shards <= reps,
                        "shards ({}) must not exceed replications ({})",
                        shards, reps);
        let base = reps / shards;
        let extra = reps % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0;
        for i in 0..shards {
            let len = base + usize::from(i < extra);
            ranges.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, reps);
        Ok(ShardMap { reps, ranges })
    }

    pub fn reps(&self) -> usize {
        self.reps
    }

    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }
}

/// Worker budget for one shard's inner backend.  The unsharded plan
/// (S = 1) keeps the whole budget — exactly the pre-shard engine; sharded
/// plans split it across shards so outer shard workers and inner row
/// chunks don't oversubscribe the machine.  Thread count never affects
/// per-row arithmetic (chunking only changes scheduling), so this is a
/// pure scheduling knob.
pub fn inner_threads(total: usize, shards: usize) -> usize {
    (total / shards.max(1)).max(1)
}

// ---------------------------------------------------------------------------
// Panel views: [rows × width] with shard slicing
// ---------------------------------------------------------------------------

/// Shared row-major `[rows × width]` view over a flat buffer — the shape
/// every batched iterate/gradient/key buffer in this repo has (row r =
/// replication r).
#[derive(Debug, Clone, Copy)]
pub struct Panel<'a, T> {
    data: &'a [T],
    rows: usize,
    width: usize,
}

impl<'a, T> Panel<'a, T> {
    pub fn new(data: &'a [T], rows: usize, width: usize) -> Self {
        assert_eq!(data.len(), rows * width, "panel is not [rows × width]");
        Panel { data, rows, width }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn row(&self, r: usize) -> &'a [T] {
        assert!(r < self.rows);
        &self.data[r * self.width..(r + 1) * self.width]
    }

    pub fn as_slice(&self) -> &'a [T] {
        self.data
    }

    /// One sub-panel per shard, in shard order (zero-copy: contiguous row
    /// ranges are contiguous slices of a row-major buffer).
    pub fn split_shards(self, map: &ShardMap) -> Vec<Panel<'a, T>> {
        assert_eq!(self.rows, map.reps(), "panel rows != shard map reps");
        map.ranges()
            .iter()
            .map(|range| Panel {
                data: &self.data[range.start * self.width
                    ..range.end * self.width],
                rows: range.len(),
                width: self.width,
            })
            .collect()
    }
}

/// Mutable row-major `[rows × width]` view with the same shard slicing;
/// [`Self::split_shards`] hands every shard its own disjoint `&mut`
/// sub-panel, which is what lets the [`Pooled`] policy advance shards
/// concurrently without aliasing.
#[derive(Debug)]
pub struct PanelMut<'a, T> {
    data: &'a mut [T],
    rows: usize,
    width: usize,
}

impl<'a, T> PanelMut<'a, T> {
    pub fn new(data: &'a mut [T], rows: usize, width: usize) -> Self {
        assert_eq!(data.len(), rows * width, "panel is not [rows × width]");
        PanelMut { data, rows, width }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        assert!(r < self.rows);
        &mut self.data[r * self.width..(r + 1) * self.width]
    }

    pub fn into_inner(self) -> &'a mut [T] {
        self.data
    }

    /// Disjoint mutable sub-panels, one per shard, in shard order.
    pub fn split_shards(self, map: &ShardMap) -> Vec<PanelMut<'a, T>> {
        assert_eq!(self.rows, map.reps(), "panel rows != shard map reps");
        let width = self.width;
        let mut rest = self.data;
        let mut out = Vec::with_capacity(map.shards());
        for range in map.ranges() {
            let (head, tail) = rest.split_at_mut(range.len() * width);
            out.push(PanelMut { data: head, rows: range.len(), width });
            rest = tail;
        }
        debug_assert!(rest.is_empty());
        out
    }
}

/// Tile one start iterate into a fresh `[rows × width]` panel buffer (the
/// generic panel loop's tiling step, `opt::panel::run_panel`).
pub fn tile_rows(x0: &[f32], rows: usize) -> Vec<f32> {
    let mut panel = Vec::with_capacity(rows * x0.len());
    for _ in 0..rows {
        panel.extend_from_slice(x0);
    }
    panel
}

// ---------------------------------------------------------------------------
// Shards and dispatch policies
// ---------------------------------------------------------------------------

/// One shard: an inner batch backend owning a contiguous replication-row
/// range of the experiment panel.
pub struct Shard<B> {
    pub backend: B,
    pub rows: Range<usize>,
}

/// How [`ShardedBatch`] advances its shards each step.  The policy is a
/// zero-sized type parameter so the `Send` requirement of concurrent
/// dispatch exists only where concurrency does: [`Pooled`] demands
/// `B: Send`, [`Serial`] works for single-thread-affine backends (the
/// PJRT handles inside the XLA arms are deliberately not `Send`).
pub trait ShardPolicy<B> {
    /// Whether shards advance concurrently.  Concurrent shard walls
    /// overlap, so a plane must NOT sum drained per-shard attributions
    /// into its own wall-clock — it books the plane-level wall instead
    /// (DESIGN.md §15).
    const CONCURRENT: bool;

    /// Apply `f` to every (shard, per-shard context) pair.  Contexts are
    /// produced by pre-splitting panels along the shard map, so shards
    /// never alias; the first error wins.
    fn for_each<C, F>(shards: &mut [Shard<B>], threads: usize, ctxs: Vec<C>,
                      f: F) -> Result<()>
    where
        C: Send,
        F: Fn(&mut Shard<B>, C) -> Result<()> + Sync;
}

/// Native arm: shards advance concurrently on `util::pool` scoped workers
/// (contiguous shard chunks per worker, mirroring the row-chunk discipline
/// of the inner batch backends).  Concurrency never touches per-row
/// arithmetic — each shard's rows are advanced by its own inner backend
/// exactly as in the unsharded plan.
pub struct Pooled;

impl<B: Send> ShardPolicy<B> for Pooled {
    const CONCURRENT: bool = true;

    fn for_each<C, F>(shards: &mut [Shard<B>], threads: usize, ctxs: Vec<C>,
                      f: F) -> Result<()>
    where
        C: Send,
        F: Fn(&mut Shard<B>, C) -> Result<()> + Sync,
    {
        assert_eq!(shards.len(), ctxs.len());
        // The Mutex exists only to hand the shared closure `&mut` access
        // to its own shard; chunks are disjoint, so locks are never
        // contended (same pattern as the native batch backends).
        let jobs: Vec<Mutex<Option<(&mut Shard<B>, C)>>> = shards
            .iter_mut()
            .zip(ctxs)
            .map(|pair| Mutex::new(Some(pair)))
            .collect();
        let parts = parallel_map_chunks(jobs.len(), threads, |range| {
            for i in range {
                let (shard, ctx) = jobs[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("each shard job is taken exactly once");
                f(shard, ctx)?;
            }
            Ok(())
        });
        for part in parts {
            part?;
        }
        Ok(())
    }
}

/// XLA arm: shards advance in shard order on the caller's thread — one
/// artifact dispatch per shard through the coordinator-owned PJRT engine
/// (its handles are thread-affine).  A multi-device PJRT build maps
/// shard → device here with no driver change.
pub struct Serial;

impl<B> ShardPolicy<B> for Serial {
    const CONCURRENT: bool = false;

    fn for_each<C, F>(shards: &mut [Shard<B>], _threads: usize,
                      ctxs: Vec<C>, f: F) -> Result<()>
    where
        C: Send,
        F: Fn(&mut Shard<B>, C) -> Result<()> + Sync,
    {
        assert_eq!(shards.len(), ctxs.len());
        for (shard, ctx) in shards.iter_mut().zip(ctxs) {
            f(shard, ctx)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ShardedBatch: the generic combinator
// ---------------------------------------------------------------------------

/// S contiguous shards of an R-replication panel, each advanced by its own
/// inner batch backend, behind the SAME batch-backend traits the drivers
/// consume.  Built from a factory closure (one inner backend per shard
/// range — the registry's `run_batch` implementations supply it), so the
/// drivers in `opt/` never see sharding at all.
pub struct ShardedBatch<B, P> {
    shards: Vec<Shard<B>>,
    map: ShardMap,
    /// Per-row iterate length (d for the FW tasks, d+1 for mean-CVaR's
    /// joint `[w, t]` rows, n features for SQN).
    width: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
    _policy: PhantomData<P>,
}

/// Fold one plane-level dispatch into `prof`.  `inner` is the merged
/// drained attribution of every shard: a serial policy's shard walls tile
/// the plane's wall, so the split is kept and the residual books as
/// dispatch; a concurrent policy's shard walls overlap (their sum exceeds
/// the wall), so the split is discarded and the plane books its own wall
/// under the call's dominant phase.
fn book_shard_call(prof: &mut Profiler, concurrent: bool, call_s: f64,
                   dominant: Phase, inner: Profiler) {
    if concurrent || inner.is_empty() {
        prof.add(dominant, call_s);
    } else {
        prof.merge(&inner);
        prof.add(Phase::Dispatch, call_s - inner.sum());
    }
}

impl<B, P> ShardedBatch<B, P> {
    fn build<F>(map: ShardMap, width: usize, threads: usize, mut make: F)
        -> Result<Self>
    where
        F: FnMut(Range<usize>) -> Result<B>,
    {
        anyhow::ensure!(width > 0, "row width must be positive");
        let mut shards = Vec::with_capacity(map.shards());
        for range in map.ranges() {
            shards.push(Shard {
                backend: make(range.clone())?,
                rows: range.clone(),
            });
        }
        Ok(ShardedBatch {
            shards,
            map,
            width,
            threads,
            prof: Profiler::new(),
            _policy: PhantomData,
        })
    }

    /// Drain every shard's attribution into one merged profiler.
    fn drain_shards<D>(&mut self, mut drain: D) -> Profiler
    where
        D: FnMut(&mut B) -> Option<Profiler>,
    {
        let mut inner = Profiler::new();
        for shard in &mut self.shards {
            if let Some(p) = drain(&mut shard.backend) {
                inner.merge(&p);
            }
        }
        inner
    }

    pub fn shards(&self) -> usize {
        self.map.shards()
    }

    pub fn shard_map(&self) -> &ShardMap {
        &self.map
    }

    /// Shared `[R × width]` shape check for the trait forwarding below.
    fn ensure_panel(&self, len: usize, what: &str) -> Result<()> {
        anyhow::ensure!(len == self.map.reps() * self.width,
                        "{} panel {} != {}×{}", what, len,
                        self.map.reps(), self.width);
        Ok(())
    }
}

impl<B> ShardedBatch<B, Pooled> {
    /// Native-arm plane: shards advance concurrently over `threads` scoped
    /// workers.  `make` receives each shard's row range and must build an
    /// inner backend for exactly `range.len()` replications.
    pub fn pooled<F>(reps: usize, shards: usize, width: usize,
                     threads: usize, make: F) -> Result<Self>
    where
        F: FnMut(Range<usize>) -> Result<B>,
    {
        Self::build(ShardMap::new(reps, shards)?, width, threads, make)
    }
}

impl<B> ShardedBatch<B, Serial> {
    /// XLA-arm plane: shards advance in order on the caller's thread, one
    /// dispatch per shard (shard-sized `[R/S × …]` artifacts).
    pub fn serial<F>(reps: usize, shards: usize, width: usize, make: F)
        -> Result<Self>
    where
        F: FnMut(Range<usize>) -> Result<B>,
    {
        Self::build(ShardMap::new(reps, shards)?, width, 1, make)
    }
}

impl<B: MvBatchBackend, P: ShardPolicy<B>> MvBatchBackend
    for ShardedBatch<B, P>
{
    fn name(&self) -> &'static str {
        self.shards
            .first()
            .map(|s| s.backend.name())
            .unwrap_or("sharded_batch")
    }

    fn batch_reps(&self) -> usize {
        self.map.reps()
    }

    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()> {
        let r = self.map.reps();
        self.ensure_panel(w.len(), "iterate")?;
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        anyhow::ensure!(objs.len() == r,
                        "need one objective slot per replication");
        let t_split = Timer::start();
        let ctxs: Vec<_> = {
            let w_parts =
                PanelMut::new(w, r, self.width).split_shards(&self.map);
            let key_parts = Panel::new(keys, r, 1).split_shards(&self.map);
            let obj_parts =
                PanelMut::new(objs, r, 1).split_shards(&self.map);
            w_parts
                .into_iter()
                .zip(key_parts)
                .zip(obj_parts)
                .map(|((w_s, k_s), o_s)| (w_s, k_s, o_s))
                .collect()
        };
        self.prof.add(Phase::Dispatch, t_split.elapsed_s());
        let t_call = Timer::start();
        P::for_each(&mut self.shards, self.threads, ctxs,
                    |shard, (w_s, k_s, o_s)| {
            // each shard writes its own objective window — no copy-back
            shard.backend.epoch_batch(w_s.into_inner(), k_epoch,
                                      k_s.as_slice(), o_s.into_inner())
        })?;
        let call_s = t_call.elapsed_s();
        let inner = self.drain_shards(|b| b.take_profile());
        book_shard_call(&mut self.prof, P::CONCURRENT, call_s,
                        Phase::Compute, inner);
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

impl<B: NvBatchBackend, P: ShardPolicy<B>> NvBatchBackend
    for ShardedBatch<B, P>
{
    fn name(&self) -> &'static str {
        self.shards
            .first()
            .map(|s| s.backend.name())
            .unwrap_or("sharded_batch")
    }

    fn batch_reps(&self) -> usize {
        self.map.reps()
    }

    fn grad_obj_batch(&mut self, x: &[f32], keys: &[[u32; 2]],
                      g: &mut [f32], objs: &mut [f64]) -> Result<()> {
        let r = self.map.reps();
        self.ensure_panel(x.len(), "iterate")?;
        self.ensure_panel(g.len(), "gradient")?;
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        anyhow::ensure!(objs.len() == r,
                        "need one objective slot per replication");
        let t_split = Timer::start();
        let ctxs: Vec<_> = {
            let x_parts = Panel::new(x, r, self.width).split_shards(&self.map);
            let key_parts = Panel::new(keys, r, 1).split_shards(&self.map);
            let g_parts =
                PanelMut::new(g, r, self.width).split_shards(&self.map);
            let obj_parts =
                PanelMut::new(objs, r, 1).split_shards(&self.map);
            x_parts
                .into_iter()
                .zip(key_parts)
                .zip(g_parts)
                .zip(obj_parts)
                .map(|(((x_s, k_s), g_s), o_s)| (x_s, k_s, g_s, o_s))
                .collect()
        };
        self.prof.add(Phase::Dispatch, t_split.elapsed_s());
        let t_call = Timer::start();
        P::for_each(&mut self.shards, self.threads, ctxs,
                    |shard, (x_s, k_s, g_s, o_s)| {
            shard.backend.grad_obj_batch(x_s.as_slice(), k_s.as_slice(),
                                         g_s.into_inner(), o_s.into_inner())
        })?;
        let call_s = t_call.elapsed_s();
        let inner = self.drain_shards(|b| b.take_profile());
        book_shard_call(&mut self.prof, P::CONCURRENT, call_s,
                        Phase::Compute, inner);
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

impl<B: LrBatchBackend, P: ShardPolicy<B>> LrBatchBackend
    for ShardedBatch<B, P>
{
    fn name(&self) -> &'static str {
        self.shards
            .first()
            .map(|s| s.backend.name())
            .unwrap_or("sharded_batch")
    }

    fn batch_reps(&self) -> usize {
        self.map.reps()
    }

    fn grad_batch(&mut self, w: &[f32], data: &crate::sim::ClassifyData,
                  idx: &[Vec<usize>], g: &mut [f32], losses: &mut [f64])
        -> Result<()> {
        let r = self.map.reps();
        self.ensure_panel(w.len(), "iterate")?;
        self.ensure_panel(g.len(), "gradient")?;
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        anyhow::ensure!(losses.len() == r,
                        "need one loss slot per replication");
        let t_split = Timer::start();
        let ctxs: Vec<_> = {
            let w_parts = Panel::new(w, r, self.width).split_shards(&self.map);
            let idx_parts = Panel::new(idx, r, 1).split_shards(&self.map);
            let g_parts =
                PanelMut::new(g, r, self.width).split_shards(&self.map);
            let loss_parts =
                PanelMut::new(losses, r, 1).split_shards(&self.map);
            w_parts
                .into_iter()
                .zip(idx_parts)
                .zip(g_parts)
                .zip(loss_parts)
                .map(|(((w_s, i_s), g_s), l_s)| (w_s, i_s, g_s, l_s))
                .collect()
        };
        self.prof.add(Phase::Dispatch, t_split.elapsed_s());
        let t_call = Timer::start();
        P::for_each(&mut self.shards, self.threads, ctxs,
                    |shard, (w_s, i_s, g_s, l_s)| {
            shard.backend.grad_batch(w_s.as_slice(), data, i_s.as_slice(),
                                     g_s.into_inner(), l_s.into_inner())
        })?;
        let call_s = t_call.elapsed_s();
        let inner = self.drain_shards(|b| b.take_profile());
        book_shard_call(&mut self.prof, P::CONCURRENT, call_s,
                        Phase::Compute, inner);
        Ok(())
    }

    fn hvp_batch(&mut self, wbar: &[f32], s: &[f32],
                 data: &crate::sim::ClassifyData, idx: &[Vec<usize>],
                 y: &mut [f32]) -> Result<()> {
        let r = self.map.reps();
        self.ensure_panel(wbar.len(), "ω̄")?;
        self.ensure_panel(s.len(), "s")?;
        self.ensure_panel(y.len(), "output")?;
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        let t_split = Timer::start();
        let ctxs: Vec<_> = {
            let wb_parts =
                Panel::new(wbar, r, self.width).split_shards(&self.map);
            let s_parts = Panel::new(s, r, self.width).split_shards(&self.map);
            let idx_parts = Panel::new(idx, r, 1).split_shards(&self.map);
            let y_parts =
                PanelMut::new(y, r, self.width).split_shards(&self.map);
            wb_parts
                .into_iter()
                .zip(s_parts)
                .zip(idx_parts)
                .zip(y_parts)
                .map(|(((wb_s, s_s), i_s), y_s)| (wb_s, s_s, i_s, y_s))
                .collect()
        };
        self.prof.add(Phase::Dispatch, t_split.elapsed_s());
        let t_call = Timer::start();
        P::for_each(&mut self.shards, self.threads, ctxs,
                    |shard, (wb_s, s_s, i_s, y_s)| {
            shard.backend.hvp_batch(wb_s.as_slice(), s_s.as_slice(), data,
                                    i_s.as_slice(), y_s.into_inner())
        })?;
        let call_s = t_call.elapsed_s();
        let inner = self.drain_shards(|b| b.take_profile());
        book_shard_call(&mut self.prof, P::CONCURRENT, call_s,
                        Phase::Compute, inner);
        Ok(())
    }

    fn direction_batch(&mut self, mem: BatchMemView<'_>, g: &[f32],
                       out: &mut [f32]) -> Result<()> {
        let r = self.map.reps();
        anyhow::ensure!(mem.reps() == r && mem.dim() == self.width,
                        "correction panels are {}×{}, plane is {}×{}",
                        mem.reps(), mem.dim(), r, self.width);
        self.ensure_panel(g.len(), "gradient")?;
        self.ensure_panel(out.len(), "output")?;
        let t_split = Timer::start();
        let ctxs: Vec<_> = {
            let g_parts = Panel::new(g, r, self.width).split_shards(&self.map);
            let out_parts =
                PanelMut::new(out, r, self.width).split_shards(&self.map);
            self.map
                .ranges()
                .iter()
                .zip(g_parts)
                .zip(out_parts)
                .map(|((range, g_s), o_s)| {
                    (mem.shard(range.clone()), g_s, o_s)
                })
                .collect()
        };
        self.prof.add(Phase::Dispatch, t_split.elapsed_s());
        let t_call = Timer::start();
        P::for_each(&mut self.shards, self.threads, ctxs,
                    |shard, (m_s, g_s, o_s)| {
            shard.backend.direction_batch(m_s, g_s.as_slice(),
                                          o_s.into_inner())
        })?;
        let call_s = t_call.elapsed_s();
        let inner = self.drain_shards(|b| b.take_profile());
        book_shard_call(&mut self.prof, P::CONCURRENT, call_s,
                        Phase::Direction, inner);
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_map_balanced_contiguous() {
        let map = ShardMap::new(7, 3).unwrap();
        assert_eq!(map.reps(), 7);
        assert_eq!(map.shards(), 3);
        // sizes differ by at most one, larger shards first
        assert_eq!(map.ranges(), &[0..3, 3..5, 5..7]);
        // degenerate-but-legal extremes
        assert_eq!(ShardMap::new(4, 1).unwrap().ranges(), &[0..4]);
        assert_eq!(ShardMap::new(3, 3).unwrap().ranges(),
                   &[0..1, 1..2, 2..3]);
    }

    #[test]
    fn shard_map_rejects_degenerate_cells() {
        assert!(ShardMap::new(0, 1).is_err());
        assert!(ShardMap::new(4, 0).is_err());
        assert!(ShardMap::new(2, 3).is_err(), "shards > reps");
    }

    #[test]
    fn inner_threads_splits_the_budget() {
        assert_eq!(inner_threads(8, 1), 8, "unsharded keeps the budget");
        assert_eq!(inner_threads(8, 2), 4);
        assert_eq!(inner_threads(2, 5), 1, "never drops to zero");
        assert_eq!(inner_threads(0, 0), 1);
    }

    #[test]
    fn panel_views_slice_along_the_map() {
        let map = ShardMap::new(5, 2).unwrap();
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let parts = Panel::new(&data, 5, 2).split_shards(&map);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].rows(), 3);
        assert_eq!(parts[0].as_slice(), &data[..6]);
        assert_eq!(parts[1].row(0), &data[6..8]);

        let mut buf = data.clone();
        let mut mut_parts = PanelMut::new(&mut buf, 5, 2).split_shards(&map);
        mut_parts[1].row_mut(1)[0] = -1.0;
        assert_eq!(buf[8], -1.0);
    }

    #[test]
    fn tile_rows_repeats_the_iterate() {
        assert_eq!(tile_rows(&[1.0, 2.0], 3),
                   vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        assert!(tile_rows(&[1.0], 0).is_empty());
    }

    // -- ShardedBatch routing: a marker backend records which rows each
    // shard advanced, so we can assert the partition end to end ----------

    struct MarkerBackend {
        rows: Range<usize>,
        calls: usize,
    }

    impl MvBatchBackend for MarkerBackend {
        fn name(&self) -> &'static str {
            "marker"
        }

        fn batch_reps(&self) -> usize {
            self.rows.len()
        }

        fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                       keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()> {
            self.calls += 1;
            let d = w.len() / keys.len();
            for (i, row) in w.chunks_mut(d).enumerate() {
                // stamp each row with its global index (shard start + i)
                // and the key it was handed, proving slices line up
                let global = self.rows.start + i;
                anyhow::ensure!(keys[i][0] as usize == global,
                                "key routed to wrong shard row");
                for v in row.iter_mut() {
                    *v += (global * 100 + k_epoch) as f32;
                }
                objs[i] = keys[i][0] as f64;
            }
            Ok(())
        }
    }

    fn routed_panel<P: ShardPolicy<MarkerBackend>>(
        plane: &mut ShardedBatch<MarkerBackend, P>, reps: usize, d: usize)
        -> (Vec<f32>, Vec<f64>) {
        let keys: Vec<[u32; 2]> = (0..reps as u32).map(|i| [i, 0]).collect();
        let mut w = vec![0.0f32; reps * d];
        let mut objs = vec![0.0f64; reps];
        plane.epoch_batch(&mut w, 7, &keys, &mut objs).unwrap();
        (w, objs)
    }

    #[test]
    fn sharded_batch_routes_rows_identically_under_any_policy() {
        let (reps, d) = (5usize, 2usize);
        let make =
            |rows: Range<usize>| Ok(MarkerBackend { rows, calls: 0 });
        let mut pooled =
            ShardedBatch::pooled(reps, 2, d, 3, make).unwrap();
        let mut serial = ShardedBatch::serial(reps, 2, d, make).unwrap();
        assert_eq!(MvBatchBackend::batch_reps(&pooled), reps);
        assert_eq!(pooled.shards(), 2);

        let (w_p, o_p) = routed_panel(&mut pooled, reps, d);
        let (w_s, o_s) = routed_panel(&mut serial, reps, d);
        assert_eq!(w_p, w_s, "policy must not change results");
        assert_eq!(o_p, o_s);
        for r in 0..reps {
            assert_eq!(w_p[r * d], (r * 100 + 7) as f32, "row {}", r);
            assert_eq!(o_p[r], r as f64);
        }
        // every shard advanced exactly once per step
        for shard in &pooled.shards {
            assert_eq!(shard.backend.calls, 1);
        }
    }

    #[test]
    fn sharded_batch_shape_checked_and_errors_propagate() {
        let make =
            |rows: Range<usize>| Ok(MarkerBackend { rows, calls: 0 });
        let mut plane = ShardedBatch::pooled(3, 3, 2, 2, make).unwrap();
        let mut objs = vec![0.0f64; 3];
        let mut wrong = vec![0.0f32; 2]; // 1 row, 3 expected
        assert!(plane
            .epoch_batch(&mut wrong, 0, &[[0, 0]; 3], &mut objs)
            .is_err());
        let mut ok = vec![0.0f32; 6];
        assert!(plane
            .epoch_batch(&mut ok, 0, &[[0, 0]; 2], &mut objs)
            .is_err());
        assert!(plane
            .epoch_batch(&mut ok, 0, &[[0, 0]; 3], &mut objs[..1])
            .is_err());
        // a mis-routed key surfaces the shard's error, first error wins
        let err = plane
            .epoch_batch(&mut ok, 0, &[[9, 0], [9, 0], [9, 0]], &mut objs)
            .unwrap_err();
        assert!(format!("{:#}", err).contains("wrong shard row"));
    }
}
