//! Native CPU backends: sequential scalar execution (the paper's CPU arm)
//! plus the thread-pooled variant used by ablation A3.
//!
//! The sequential arm deliberately mirrors the paper's §2.2 description of
//! CPU execution — "processing each sample individually" — while remaining
//! idiomatic Rust (no artificial slowdowns): row-by-row matvecs, per-sample
//! indicator counting, per-sample sigmoid accumulation.

use anyhow::Result;

use crate::linalg::matrix::Mat;
use crate::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use crate::tasks::classification as lr;
use crate::tasks::cvar as cv;
use crate::tasks::mean_variance as mv;
use crate::tasks::newsvendor as nv;
use crate::tasks::{BatchMemView, CorrectionMemory};
use crate::util::pool::{chunk_len, parallel_map_chunks, parallel_try_jobs};
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::{
    HessianMode, LrBackend, LrBatchBackend, MvBackend, MvBatchBackend,
    NvBackend, NvBatchBackend,
};

/// Degree of intra-gradient parallelism for the `native_par` ablation.
#[derive(Debug, Clone, Copy)]
pub enum NativeMode {
    /// Pure sequential (the paper's CPU arm).
    Sequential,
    /// Panel split across `threads` OS threads + blocked kernels (A3).
    Parallel { threads: usize },
}

// ---------------------------------------------------------------------------
// Task 1
// ---------------------------------------------------------------------------

/// Sequential/parallel mean-variance epochs over a sampled return panel.
pub struct NativeMv {
    universe: AssetUniverse,
    n_samples: usize,
    m_inner: usize,
    mode: NativeMode,
    // scratch (reused across epochs)
    panel: Mat,
    rbar: Vec<f32>,
    scratch: mv::MvScratch,
}

impl NativeMv {
    pub fn new(universe: AssetUniverse, n_samples: usize, m_inner: usize,
               mode: NativeMode) -> Self {
        let d = universe.dim();
        NativeMv {
            universe,
            n_samples,
            m_inner,
            mode,
            panel: Mat::zeros(n_samples, d),
            rbar: vec![0.0; d],
            scratch: mv::MvScratch::new(n_samples, d),
        }
    }

    fn resample(&mut self, key: [u32; 2]) {
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.universe.sample_panel(&mut sampler, self.n_samples,
                                   &mut self.panel.data);
        self.panel.col_means_into(&mut self.rbar);
        self.panel.center_rows(&self.rbar);
    }

    /// Cᵀ(Cw)/(n−1) into `scratch.g` (no R̄ subtraction — the epoch loop
    /// finishes the gradient).
    fn grad_dispatch(&mut self, w: &[f32]) {
        match self.mode {
            NativeMode::Sequential => {
                let n = self.n_samples;
                self.panel.matvec(w, &mut self.scratch.u);
                self.panel.matvec_t(&self.scratch.u, &mut self.scratch.g);
                let inv = 1.0 / (n as f32 - 1.0);
                for v in self.scratch.g.iter_mut() {
                    *v *= inv;
                }
            }
            NativeMode::Parallel { threads } => {
                // split the sample axis: u = C w in parallel chunks, then
                // the reduction g = Cᵀu in parallel column chunks
                let d = self.universe.dim();
                let n = self.n_samples;
                let panel = &self.panel;
                let u: Vec<f32> = parallel_map_chunks(n, threads, |r| {
                    let mut part = Vec::with_capacity(r.len());
                    for i in r {
                        part.push(crate::linalg::blocked::dot4(panel.row(i), w));
                    }
                    part
                })
                .into_iter()
                .flatten()
                .collect();
                let g_parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for i in 0..n {
                        let ui = u[i];
                        let row = panel.row(i);
                        for (o, j) in cols.clone().enumerate() {
                            part[o] += ui * row[j];
                        }
                    }
                    (cols.start, part)
                });
                for (start, part) in g_parts {
                    self.scratch.g[start..start + part.len()]
                        .copy_from_slice(&part);
                }
                self.scratch.u.copy_from_slice(&u);
                let inv = 1.0 / (n as f32 - 1.0);
                for v in self.scratch.g.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
}

impl MvBackend for NativeMv {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let mut w = w.to_vec();
        let obj = self.epoch_into(&mut w, k_epoch, key)?;
        Ok((w, obj))
    }

    /// Allocation-free epoch: every temporary (return panel, R̄, matvec
    /// scratch) lives in `self` and `w` advances where it lies
    /// (DESIGN.md §16) — the entry point the batched arm steps each panel
    /// row through.
    fn epoch_into(&mut self, w: &mut [f32], k_epoch: usize, key: [u32; 2])
        -> Result<f64> {
        self.resample(key);
        let m_inner = self.m_inner;
        for m in 0..m_inner {
            self.grad_dispatch(w);
            // grad_dispatch leaves Cᵀ(Cw)/(n−1) — finish the gradient:
            for j in 0..w.len() {
                self.scratch.g[j] -= self.rbar[j];
            }
            let s = mv::simplex_lmo(&self.scratch.g);
            let gamma = crate::opt::schedule::fw_gamma(k_epoch, m, m_inner);
            mv::fw_vertex_update(w, s, gamma);
        }
        Ok(mv::objective(&self.panel, &self.rbar, w, &mut self.scratch))
    }
}

// ---------------------------------------------------------------------------
// Task 4 — mean-CVaR portfolio (registry extension, DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Smoothed mean-CVaR Frank-Wolfe epochs over a sampled return panel.  The
/// iterate is the joint `[w, t]` vector (length d+1, see `tasks::cvar`),
/// which lets the task implement the Task-1 epoch contract ([`MvBackend`])
/// and ride the same drivers and batch arms.
pub struct NativeCvar {
    universe: AssetUniverse,
    n_samples: usize,
    m_inner: usize,
    mode: NativeMode,
    // scratch (reused across epochs)
    panel: Mat,
    rbar: Vec<f32>,
    scratch: cv::CvScratch,
}

impl NativeCvar {
    pub fn new(universe: AssetUniverse, n_samples: usize, m_inner: usize,
               mode: NativeMode) -> Self {
        let d = universe.dim();
        NativeCvar {
            universe,
            n_samples,
            m_inner,
            mode,
            panel: Mat::zeros(n_samples, d),
            rbar: vec![0.0; d],
            scratch: cv::CvScratch::new(n_samples, d),
        }
    }

    /// Resample the raw return panel (NOT centered — the CVaR tail term
    /// works on the losses themselves) and cache its column means.
    fn resample(&mut self, key: [u32; 2]) {
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.universe.sample_panel(&mut sampler, self.n_samples,
                                   &mut self.panel.data);
        self.panel.col_means_into(&mut self.rbar);
    }

    /// ∇f(w, t) into `scratch.g`.
    fn grad_dispatch(&mut self, x: &[f32]) {
        match self.mode {
            NativeMode::Sequential => {
                cv::grad(&self.panel, &self.rbar, x, &mut self.scratch);
            }
            NativeMode::Parallel { threads } => {
                // split the sample axis for the loss matvec, then the
                // product axis for the Rᵀσ reduction (mirrors NativeMv's
                // A3 decomposition)
                let d = self.universe.dim();
                let n = self.n_samples;
                let panel = &self.panel;
                let w = &x[..d];
                let t = x[d];
                let losses: Vec<f32> = parallel_map_chunks(n, threads, |r| {
                    let mut part = Vec::with_capacity(r.len());
                    for i in r {
                        part.push(-crate::linalg::blocked::dot4(
                            panel.row(i), w));
                    }
                    part
                })
                .into_iter()
                .flatten()
                .collect();
                let mut sig_sum = 0.0f32;
                for (s, &l) in losses.iter().enumerate() {
                    let sg = cv::sigmoid_eta(l - t);
                    self.scratch.sig[s] = sg;
                    sig_sum += sg;
                }
                let sig = &self.scratch.sig;
                let g_parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for i in 0..n {
                        let si = sig[i];
                        let row = panel.row(i);
                        for (o, j) in cols.clone().enumerate() {
                            part[o] += si * row[j];
                        }
                    }
                    (cols.start, part)
                });
                let c = cv::tail_scale(n);
                for (start, part) in g_parts {
                    for (o, v) in part.into_iter().enumerate() {
                        let j = start + o;
                        self.scratch.g[j] =
                            -self.rbar[j] - cv::LAMBDA * c * v;
                    }
                }
                self.scratch.g[d] = cv::LAMBDA * (1.0 - c * sig_sum);
                self.scratch.losses.copy_from_slice(&losses);
            }
        }
    }
}

impl MvBackend for NativeCvar {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn epoch(&mut self, x: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let mut x = x.to_vec();
        let obj = self.epoch_into(&mut x, k_epoch, key)?;
        Ok((x, obj))
    }

    /// Allocation-free epoch on the joint `[w, t]` row in place (see
    /// [`NativeMv::epoch_into`]; DESIGN.md §16).
    fn epoch_into(&mut self, x: &mut [f32], k_epoch: usize, key: [u32; 2])
        -> Result<f64> {
        anyhow::ensure!(x.len() == self.universe.dim() + 1,
                        "iterate must be [w, t] of length d+1");
        self.resample(key);
        let m_inner = self.m_inner;
        for m in 0..m_inner {
            self.grad_dispatch(x);
            let (vertex, t_vertex) = cv::product_lmo(&self.scratch.g);
            let gamma = crate::opt::schedule::fw_gamma(k_epoch, m, m_inner);
            cv::fw_product_update(x, vertex, t_vertex, gamma);
        }
        Ok(cv::objective(&self.panel, &self.rbar, x, &mut self.scratch))
    }
}

// ---------------------------------------------------------------------------
// Task 2
// ---------------------------------------------------------------------------

pub struct NativeNv {
    inst: NewsvendorInstance,
    s_samples: usize,
    mode: NativeMode,
    panel: Vec<f32>,
    panel_key: Option<[u32; 2]>,
}

impl NativeNv {
    pub fn new(inst: NewsvendorInstance, s_samples: usize, mode: NativeMode)
        -> Self {
        let d = inst.dim();
        NativeNv {
            inst,
            s_samples,
            mode,
            panel: vec![0.0; s_samples * d],
            panel_key: None,
        }
    }

    pub fn instance(&self) -> &NewsvendorInstance {
        &self.inst
    }

    fn ensure_panel(&mut self, key: [u32; 2]) {
        if self.panel_key == Some(key) {
            return; // same epoch key ⇒ same panel (counter-based RNG)
        }
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.inst.sample_panel(&mut sampler, self.s_samples, &mut self.panel);
        self.panel_key = Some(key);
    }
}

impl NvBackend for NativeNv {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let mut g = vec![0.0f32; self.inst.dim()];
        let obj = self.grad_obj_into(x, key, &mut g)?;
        Ok((g, obj))
    }

    /// Allocation-free gradient: the Monte-Carlo panel is cached per key
    /// and the gradient lands in the caller's row (DESIGN.md §16).
    fn grad_obj_into(&mut self, x: &[f32], key: [u32; 2], g: &mut [f32])
        -> Result<f64> {
        self.ensure_panel(key);
        let d = self.inst.dim();
        anyhow::ensure!(g.len() == d, "gradient row {} != {}", g.len(), d);
        match self.mode {
            NativeMode::Sequential => {
                nv::grad(&self.inst, &self.panel, self.s_samples, x, g);
            }
            NativeMode::Parallel { threads } => {
                let inst = &self.inst;
                let panel = &self.panel;
                let s = self.s_samples;
                let parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for (o, j) in cols.clone().enumerate() {
                        let mut count = 0u32;
                        for r in 0..s {
                            if panel[r * d + j] <= x[j] {
                                count += 1;
                            }
                        }
                        let cdf = count as f32 / s as f32;
                        part[o] = inst.k[j] - inst.v[j]
                            + (inst.h[j] + inst.v[j]) * cdf;
                    }
                    (cols.start, part)
                });
                for (start, part) in parts {
                    g[start..start + part.len()].copy_from_slice(&part);
                }
            }
        }
        Ok(nv::objective(&self.inst, &self.panel, self.s_samples, x))
    }
}

// ---------------------------------------------------------------------------
// Task 3
// ---------------------------------------------------------------------------

pub struct NativeLr {
    n: usize,
    mode: NativeMode,
    pub hessian_mode: HessianMode,
    // gather scratch (reused, no allocation in the iteration loop)
    xb: Vec<f32>,
    zb: Vec<f32>,
    // Algorithm 4 cache: H_t is rebuilt only when the correction memory
    // changes (every L iterations), then applied as a matvec per step —
    // the same schedule the paper's Algorithm 3 line 11 implies.
    h_cache: Option<(u64, Mat)>,
    mem_generation: u64,
    // Algorithm-4 arenas (DESIGN.md §16): H-rebuild matvec scratch and
    // two-loop temporaries, reused across rebuilds/steps.
    hy: Vec<f32>,
    two_loop: lr::TwoLoopScratch,
}

impl NativeLr {
    pub fn new(data: &ClassifyData, mode: NativeMode,
               hessian_mode: HessianMode) -> Self {
        Self::with_dim(data.n_features, mode, hessian_mode)
    }

    pub fn with_dim(n: usize, mode: NativeMode, hessian_mode: HessianMode)
        -> Self {
        NativeLr {
            n,
            mode,
            hessian_mode,
            xb: Vec::new(),
            zb: Vec::new(),
            h_cache: None,
            mem_generation: 0,
            hy: Vec::new(),
            two_loop: lr::TwoLoopScratch::default(),
        }
    }

    /// Allocation-free minibatch gradient: gather scratch and the output
    /// row are caller/arena-owned (DESIGN.md §16).
    pub fn grad_into(&mut self, w: &[f32], data: &ClassifyData,
                     idx: &[usize], g: &mut [f32]) -> Result<f64> {
        let n = self.n;
        anyhow::ensure!(w.len() == n, "w dim {} != {}", w.len(), n);
        anyhow::ensure!(g.len() == n, "gradient row {} != {}", g.len(), n);
        anyhow::ensure!(data.n_features == n, "dataset feature mismatch");
        data.gather(idx, &mut self.xb, &mut self.zb);
        let (xb, zb) = (&self.xb, &self.zb);
        let loss = match self.mode {
            NativeMode::Sequential => lr::grad(w, xb, zb, g),
            NativeMode::Parallel { threads } => {
                let b = zb.len();
                let parts = parallel_map_chunks(b, threads, |rows| {
                    let mut gp = vec![0.0f32; n];
                    let mut lp = 0.0f64;
                    for i in rows {
                        let row = &xb[i * n..(i + 1) * n];
                        let u = crate::linalg::blocked::dot4(row, w);
                        let c = lr::sigmoid(u);
                        let r = c - zb[i];
                        for j in 0..n {
                            gp[j] += r * row[j];
                        }
                        lp += lr::bce(u, zb[i]) as f64;
                    }
                    (gp, lp)
                });
                g.fill(0.0);
                let mut loss = 0.0f64;
                for (gp, lp) in parts {
                    for j in 0..n {
                        g[j] += gp[j];
                    }
                    loss += lp;
                }
                let inv = 1.0 / b as f32;
                g.iter_mut().for_each(|v| *v *= inv);
                loss / b as f64
            }
        };
        Ok(loss)
    }

    /// Allocation-free sub-sampled HVP (13) into a caller-owned row.
    pub fn hvp_into(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
                    idx: &[usize], out: &mut [f32]) -> Result<()> {
        // a new correction pair is about to land ⇒ H_t will change
        self.mem_generation += 1;
        anyhow::ensure!(out.len() == self.n, "output row {} != {}",
                        out.len(), self.n);
        data.gather(idx, &mut self.xb, &mut self.zb);
        lr::hvp(wbar, s, &self.xb, out);
        Ok(())
    }

    /// Allocation-free Algorithm-4 direction: the explicit-H cache is
    /// rebuilt IN PLACE on the sequential cadence and the two-loop
    /// recursion runs on arena temporaries (DESIGN.md §16).
    pub fn direction_into(&mut self, mem: &CorrectionMemory, g: &[f32],
                          out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(out.len() == g.len(), "direction row {} != {}",
                        out.len(), g.len());
        match self.hessian_mode {
            HessianMode::Explicit => {
                let rebuild = match &self.h_cache {
                    Some((generation, _)) => {
                        *generation != self.mem_generation
                    }
                    None => true,
                };
                if rebuild {
                    if self.h_cache.is_none() {
                        self.h_cache = Some((0, Mat::zeros(0, 0)));
                    }
                    let cache = self.h_cache.as_mut().unwrap();
                    cache.0 = self.mem_generation;
                    lr::hbuild_explicit_into(mem.view(), &mut cache.1,
                                             &mut self.hy);
                }
                let (_, h) = self.h_cache.as_ref().unwrap();
                h.matvec(g, out);
            }
            HessianMode::TwoLoop => {
                lr::hdir_twoloop_into(mem.view(), g, &mut self.two_loop,
                                      out);
            }
        }
        Ok(())
    }
}

impl LrBackend for NativeLr {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn grad(&mut self, w: &[f32], data: &ClassifyData, idx: &[usize])
        -> Result<(Vec<f32>, f64)> {
        let mut g = vec![0.0f32; self.n];
        let loss = self.grad_into(w, data, idx, &mut g)?;
        Ok((g, loss))
    }

    fn hvp(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
           idx: &[usize]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.n];
        self.hvp_into(wbar, s, data, idx, &mut out)?;
        Ok(out)
    }

    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; g.len()];
        self.direction_into(mem, g, &mut out)?;
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Replication-batched arms (DESIGN.md §11, §16)
// ---------------------------------------------------------------------------
//
// Each batch backend holds one per-replication backend per row so every
// row runs the *bit-identical* arithmetic of the sequential path.  The
// replication axis is spread over `pool::parallel_try_jobs`: the backend
// list, the output panel and the scalar row are split into the SAME
// contiguous chunks (`split_at_mut` via `chunks_mut`, boundaries
// identical to `parallel_map_chunks` — pinned in util::pool's tests) and
// each job receives exclusive `&mut` slices.  No `Mutex`, no owned row
// vectors, no merge copy: every worker writes its rows where they live,
// through the sequential backends' `_into` entry points, whose scratch
// lives in per-backend arenas reused across epochs.

/// Generic epoch-task batch arm (Tasks 1 and 4): one sequential-mode
/// per-replication backend per row — ANY [`MvBackend`] — with contiguous
/// row chunks spread over the thread pool.  Registering a new
/// epoch-structured scenario costs one `from_rows` constructor, not a new
/// batch backend (DESIGN.md §12).
pub struct NativeEpochBatch<B> {
    reps: Vec<B>,
    /// Per-row iterate length (d for Task 1, d+1 for Task 4's `[w, t]`).
    d: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

impl<B: MvBackend + Send> NativeEpochBatch<B> {
    /// Build from one per-replication row backend per replication;
    /// `row_dim` is the iterate length of one row.
    pub fn from_rows(rows: Vec<B>, row_dim: usize, threads: usize) -> Self {
        NativeEpochBatch {
            reps: rows,
            d: row_dim,
            threads,
            prof: Profiler::new(),
        }
    }
}

/// Task 1 batched: all R replications advance one fused epoch per call.
pub type NativeMvBatch = NativeEpochBatch<NativeMv>;

impl NativeEpochBatch<NativeMv> {
    pub fn new(universe: &AssetUniverse, n_samples: usize, m_inner: usize,
               r_reps: usize, threads: usize) -> Self {
        let d = universe.dim();
        Self::from_rows(
            (0..r_reps)
                .map(|_| {
                    NativeMv::new(universe.clone(), n_samples, m_inner,
                                  NativeMode::Sequential)
                })
                .collect(),
            d,
            threads,
        )
    }
}

/// Task 4 batched: identical machinery over the joint `[w, t]` rows.
pub type NativeCvarBatch = NativeEpochBatch<NativeCvar>;

impl NativeEpochBatch<NativeCvar> {
    pub fn new(universe: &AssetUniverse, n_samples: usize, m_inner: usize,
               r_reps: usize, threads: usize) -> Self {
        let d = universe.dim();
        Self::from_rows(
            (0..r_reps)
                .map(|_| {
                    NativeCvar::new(universe.clone(), n_samples, m_inner,
                                    NativeMode::Sequential)
                })
                .collect(),
            d + 1,
            threads,
        )
    }
}

impl<B: MvBackend + Send> MvBatchBackend for NativeEpochBatch<B> {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()> {
        let (r, d) = (self.reps.len(), self.d);
        anyhow::ensure!(w.len() == r * d, "iterate panel {} != {}×{}",
                        w.len(), r, d);
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        anyhow::ensure!(objs.len() == r,
                        "need one objective slot per replication");
        let chunk = chunk_len(r, self.threads);
        let t_par = Timer::start();
        parallel_try_jobs(
            self.reps
                .chunks_mut(chunk)
                .zip(w.chunks_mut(chunk * d))
                .zip(objs.chunks_mut(chunk))
                .enumerate()
                .map(|(c, ((reps, w_rows), obj_rows))| {
                    let base = c * chunk;
                    move || -> Result<()> {
                        for (o, rep) in reps.iter_mut().enumerate() {
                            let row = &mut w_rows[o * d..(o + 1) * d];
                            obj_rows[o] =
                                rep.epoch_into(row, k_epoch, keys[base + o])?;
                        }
                        Ok(())
                    }
                }),
        )?;
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        // No reduce phase: rows and objectives are written in place by
        // the jobs themselves (DESIGN.md §16).
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 2 batched: one Monte-Carlo gradient panel per call.
pub struct NativeNvBatch {
    reps: Vec<NativeNv>,
    d: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

impl NativeNvBatch {
    pub fn new(inst: &NewsvendorInstance, s_samples: usize, r_reps: usize,
               threads: usize) -> Self {
        let d = inst.dim();
        let reps = (0..r_reps)
            .map(|_| {
                NativeNv::new(inst.clone(), s_samples,
                              NativeMode::Sequential)
            })
            .collect();
        NativeNvBatch { reps, d, threads, prof: Profiler::new() }
    }
}

impl NvBatchBackend for NativeNvBatch {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn grad_obj_batch(&mut self, x: &[f32], keys: &[[u32; 2]],
                      g: &mut [f32], objs: &mut [f64]) -> Result<()> {
        let (r, d) = (self.reps.len(), self.d);
        anyhow::ensure!(x.len() == r * d, "iterate panel {} != {}×{}",
                        x.len(), r, d);
        anyhow::ensure!(g.len() == r * d, "gradient panel shape mismatch");
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        anyhow::ensure!(objs.len() == r,
                        "need one objective slot per replication");
        let chunk = chunk_len(r, self.threads);
        let t_par = Timer::start();
        parallel_try_jobs(
            self.reps
                .chunks_mut(chunk)
                .zip(g.chunks_mut(chunk * d))
                .zip(objs.chunks_mut(chunk))
                .enumerate()
                .map(|(c, ((reps, g_rows), obj_rows))| {
                    let base = c * chunk;
                    move || -> Result<()> {
                        for (o, rep) in reps.iter_mut().enumerate() {
                            let i = base + o;
                            let g_row = &mut g_rows[o * d..(o + 1) * d];
                            obj_rows[o] = rep.grad_obj_into(
                                &x[i * d..(i + 1) * d], keys[i], g_row)?;
                        }
                        Ok(())
                    }
                }),
        )?;
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 3 batched: SQN kernels for all R replications per call.  Gradients
/// and HVPs run through per-row sequential backends (bit-identical
/// arithmetic); Algorithm-4 directions run directly on the driver's padded
/// `[R × mem × n]` correction panels through the same [`MemView`] recursion
/// cores the ragged path uses — one `direction_batch` call covers every
/// row, with per-row explicit-H caches rebuilt on the sequential cadence
/// (only when that row's memory generation moves, i.e. every L iterations).
pub struct NativeLrBatch {
    reps: Vec<NativeLr>,
    hessian_mode: HessianMode,
    /// Per-row Algorithm-4 arenas (explicit-H cache + two-loop scratch);
    /// handed to the fan-out jobs as disjoint `&mut` chunks, so no lock
    /// is needed.
    ///
    /// Cache validity leans on the SQN driver protocol: correction pairs
    /// only land via `hvp_batch` (which bumps the generation) followed by
    /// `push_row` — so `(generation, count)` moves whenever a row's
    /// memory content can have changed.  Handing `direction_batch` two
    /// unrelated `BatchCorrectionMemory` values at the same generation
    /// AND per-row counts (impossible through `run_sqn_batch`) would
    /// reuse a stale H.
    dir_arenas: Vec<RowDirArena>,
    /// Bumped by [`Self::hvp_batch`] — a correction pair is about to land,
    /// so every row's H_t goes stale (mirrors `NativeLr::hvp`).
    mem_generation: u64,
    n: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

/// One replication row's Algorithm-4 arena: the `(generation, count)`
/// stamp its explicit H was built at, the H itself (rebuilt IN PLACE via
/// [`lr::hbuild_explicit_into`]), and the rebuild/two-loop scratch.
#[derive(Debug, Default)]
struct RowDirArena {
    built: Option<(u64, usize)>,
    h: Mat,
    hy: Vec<f32>,
    two_loop: lr::TwoLoopScratch,
}

impl NativeLrBatch {
    pub fn new(data: &ClassifyData, r_reps: usize, threads: usize,
               hessian_mode: HessianMode) -> Self {
        let reps = (0..r_reps)
            .map(|_| {
                NativeLr::new(data, NativeMode::Sequential, hessian_mode)
            })
            .collect();
        NativeLrBatch {
            reps,
            hessian_mode,
            dir_arenas: (0..r_reps).map(|_| RowDirArena::default())
                .collect(),
            mem_generation: 0,
            n: data.n_features,
            threads,
            prof: Profiler::new(),
        }
    }
}

impl LrBatchBackend for NativeLrBatch {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn grad_batch(&mut self, w: &[f32], data: &ClassifyData,
                  idx: &[Vec<usize>], g: &mut [f32], losses: &mut [f64])
        -> Result<()> {
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(w.len() == r * n, "iterate panel {} != {}×{}",
                        w.len(), r, n);
        anyhow::ensure!(g.len() == r * n, "gradient panel shape mismatch");
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        anyhow::ensure!(losses.len() == r,
                        "need one loss slot per replication");
        let chunk = chunk_len(r, self.threads);
        let t_par = Timer::start();
        parallel_try_jobs(
            self.reps
                .chunks_mut(chunk)
                .zip(g.chunks_mut(chunk * n))
                .zip(losses.chunks_mut(chunk))
                .enumerate()
                .map(|(c, ((reps, g_rows), loss_rows))| {
                    let base = c * chunk;
                    move || -> Result<()> {
                        for (o, rep) in reps.iter_mut().enumerate() {
                            let i = base + o;
                            let g_row = &mut g_rows[o * n..(o + 1) * n];
                            loss_rows[o] = rep.grad_into(
                                &w[i * n..(i + 1) * n], data, &idx[i],
                                g_row)?;
                        }
                        Ok(())
                    }
                }),
        )?;
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        Ok(())
    }

    fn hvp_batch(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
                 idx: &[Vec<usize>], y: &mut [f32]) -> Result<()> {
        // a new correction pair is about to land ⇒ every row's H_t changes
        self.mem_generation += 1;
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(wbar.len() == r * n && s.len() == r * n,
                        "ω̄/s panel shape mismatch");
        anyhow::ensure!(y.len() == r * n, "output panel shape mismatch");
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        let chunk = chunk_len(r, self.threads);
        let t_par = Timer::start();
        parallel_try_jobs(
            self.reps
                .chunks_mut(chunk)
                .zip(y.chunks_mut(chunk * n))
                .enumerate()
                .map(|(c, (reps, y_rows))| {
                    let base = c * chunk;
                    move || -> Result<()> {
                        for (o, rep) in reps.iter_mut().enumerate() {
                            let i = base + o;
                            let y_row = &mut y_rows[o * n..(o + 1) * n];
                            rep.hvp_into(&wbar[i * n..(i + 1) * n],
                                         &s[i * n..(i + 1) * n], data,
                                         &idx[i], y_row)?;
                        }
                        Ok(())
                    }
                }),
        )?;
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        Ok(())
    }

    fn direction_batch(&mut self, mem: BatchMemView<'_>, g: &[f32],
                       out: &mut [f32]) -> Result<()> {
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(mem.reps() == r && mem.dim() == n,
                        "correction panels are {}×{}, backend is {}×{}",
                        mem.reps(), mem.dim(), r, n);
        anyhow::ensure!(g.len() == r * n && out.len() == r * n,
                        "gradient/output panel shape mismatch");
        let hessian_mode = self.hessian_mode;
        let generation = self.mem_generation;
        let chunk = chunk_len(r, self.threads);
        let mem = &mem;
        let t_dir = Timer::start();
        parallel_try_jobs(
            self.dir_arenas
                .chunks_mut(chunk)
                .zip(out.chunks_mut(chunk * n))
                .enumerate()
                .map(|(c, (arenas, out_rows))| {
                    let base = c * chunk;
                    move || -> Result<()> {
                        for (o, arena) in arenas.iter_mut().enumerate() {
                            let i = base + o;
                            if !mem.is_active(i) {
                                // the driver steps with the plain gradient
                                // here, as the sequential path does before
                                // the memory fills
                                continue;
                            }
                            let g_row = &g[i * n..(i + 1) * n];
                            let out_row =
                                &mut out_rows[o * n..(o + 1) * n];
                            match hessian_mode {
                                HessianMode::Explicit => {
                                    // rebuild row i's H only when its
                                    // generation or fill level moved
                                    // (every L iterations) — the
                                    // sequential cadence
                                    let stamp = (generation, mem.count(i));
                                    if arena.built != Some(stamp) {
                                        lr::hbuild_explicit_into(
                                            mem.row(i), &mut arena.h,
                                            &mut arena.hy);
                                        arena.built = Some(stamp);
                                    }
                                    arena.h.matvec(g_row, out_row);
                                }
                                HessianMode::TwoLoop => {
                                    lr::hdir_twoloop_into(
                                        mem.row(i), g_row,
                                        &mut arena.two_loop, out_row);
                                }
                            }
                        }
                        Ok(())
                    }
                }),
        )?;
        self.prof.add(Phase::Direction, t_dir.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StreamTree;
    use crate::tasks::BatchCorrectionMemory;

    #[test]
    fn mv_epoch_feasible_and_deterministic() {
        let u = AssetUniverse::generate(&StreamTree::new(1), 32);
        let mut b = NativeMv::new(u.clone(), 16, 5, NativeMode::Sequential);
        let w0 = vec![1.0 / 32.0; 32];
        let (w1, o1) = b.epoch(&w0, 0, [1, 2]).unwrap();
        assert!(crate::tasks::mean_variance::in_simplex(&w1, 1e-5));
        let mut b2 = NativeMv::new(u, 16, 5, NativeMode::Sequential);
        let (w2, o2) = b2.epoch(&w0, 0, [1, 2]).unwrap();
        assert_eq!(w1, w2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn mv_parallel_matches_sequential() {
        let u = AssetUniverse::generate(&StreamTree::new(2), 24);
        let w0 = vec![1.0 / 24.0; 24];
        let mut seq = NativeMv::new(u.clone(), 16, 4, NativeMode::Sequential);
        let mut par =
            NativeMv::new(u, 16, 4, NativeMode::Parallel { threads: 3 });
        let (w1, o1) = seq.epoch(&w0, 1, [3, 4]).unwrap();
        let (w2, o2) = par.epoch(&w0, 1, [3, 4]).unwrap();
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
        assert!((o1 - o2).abs() < 1e-4);
    }

    #[test]
    fn nv_panel_cached_per_key() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(3), 16, 2, 0.6);
        let x = inst.feasible_start();
        let mut b = NativeNv::new(inst, 8, NativeMode::Sequential);
        let (g1, o1) = b.grad_obj(&x, [7, 7]).unwrap();
        let (g2, o2) = b.grad_obj(&x, [7, 7]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(o1, o2);
        let (g3, _) = b.grad_obj(&x, [7, 8]).unwrap();
        assert_ne!(g1, g3); // different epoch key ⇒ different panel
    }

    #[test]
    fn nv_parallel_matches_sequential() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(4), 32, 3, 0.6);
        let x = inst.feasible_start();
        let mut seq = NativeNv::new(inst.clone(), 16, NativeMode::Sequential);
        let mut par =
            NativeNv::new(inst, 16, NativeMode::Parallel { threads: 4 });
        let (g1, o1) = seq.grad_obj(&x, [1, 1]).unwrap();
        let (g2, o2) = par.grad_obj(&x, [1, 1]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn lr_parallel_matches_sequential() {
        let data = ClassifyData::generate(&StreamTree::new(5), 16);
        let mut seq = NativeLr::new(&data, NativeMode::Sequential,
                                    HessianMode::Explicit);
        let mut par = NativeLr::new(&data,
                                    NativeMode::Parallel { threads: 3 },
                                    HessianMode::Explicit);
        let w = vec![0.05f32; 16];
        let idx: Vec<usize> = (0..64).collect();
        let (g1, l1) = seq.grad(&w, &data, &idx).unwrap();
        let (g2, l2) = par.grad(&w, &data, &idx).unwrap();
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!((l1 - l2).abs() < 1e-9);
    }

    #[test]
    fn lr_bad_shapes_rejected() {
        let data = ClassifyData::generate(&StreamTree::new(6), 8);
        let mut b = NativeLr::with_dim(16, NativeMode::Sequential,
                                       HessianMode::TwoLoop);
        // backend dimension disagrees with both w and the dataset
        let w = vec![0.0f32; 16];
        assert!(b.grad(&w, &data, &[0, 1]).is_err());
        assert!(b.grad(&[0.0; 8], &data, &[0, 1]).is_err());
    }

    // -- batched arms: bit-identical to the per-replication path -----------

    #[test]
    fn mv_batch_epoch_bitwise_matches_per_rep() {
        let (d, n, m, r) = (16usize, 8usize, 4usize, 5usize);
        let u = AssetUniverse::generate(&StreamTree::new(31), d);
        let w0 = vec![1.0f32 / d as f32; d];
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [i as u32 + 1, 2 * i as u32 + 7]).collect();

        let mut batch = NativeMvBatch::new(&u, n, m, r, 3);
        let mut panel: Vec<f32> = Vec::new();
        for _ in 0..r {
            panel.extend_from_slice(&w0);
        }
        let mut objs = vec![0.0f64; r];
        batch.epoch_batch(&mut panel, 2, &keys, &mut objs).unwrap();

        for i in 0..r {
            let mut single =
                NativeMv::new(u.clone(), n, m, NativeMode::Sequential);
            let (w1, o1) = single.epoch(&w0, 2, keys[i]).unwrap();
            assert_eq!(&panel[i * d..(i + 1) * d], w1.as_slice(), "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
        // distinct keys ⇒ distinct rows
        assert_ne!(&panel[..d], &panel[d..2 * d]);
    }

    #[test]
    fn cvar_epoch_feasible_and_deterministic() {
        let u = AssetUniverse::generate(&StreamTree::new(41), 16);
        let x0 = cv::start_iterate(16);
        let mut b = NativeCvar::new(u.clone(), 12, 4, NativeMode::Sequential);
        let (x1, o1) = b.epoch(&x0, 0, [5, 6]).unwrap();
        assert_eq!(x1.len(), 17);
        assert!(cv::in_product(&x1, 1e-5));
        assert!(o1.is_finite());
        let mut b2 = NativeCvar::new(u, 12, 4, NativeMode::Sequential);
        let (x2, o2) = b2.epoch(&x0, 0, [5, 6]).unwrap();
        assert_eq!(x1, x2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn cvar_parallel_matches_sequential() {
        let u = AssetUniverse::generate(&StreamTree::new(42), 12);
        let x0 = cv::start_iterate(12);
        let mut seq = NativeCvar::new(u.clone(), 16, 4,
                                      NativeMode::Sequential);
        let mut par =
            NativeCvar::new(u, 16, 4, NativeMode::Parallel { threads: 3 });
        let (x1, o1) = seq.epoch(&x0, 1, [3, 4]).unwrap();
        let (x2, o2) = par.epoch(&x0, 1, [3, 4]).unwrap();
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
        assert!((o1 - o2).abs() < 1e-4);
    }

    #[test]
    fn cvar_batch_epoch_bitwise_matches_per_rep() {
        let (d, n, m, r) = (10usize, 8usize, 3usize, 4usize);
        let u = AssetUniverse::generate(&StreamTree::new(43), d);
        let x0 = cv::start_iterate(d);
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [i as u32 + 9, 3 * i as u32 + 1]).collect();

        let mut batch = NativeCvarBatch::new(&u, n, m, r, 3);
        let mut panel: Vec<f32> = Vec::new();
        for _ in 0..r {
            panel.extend_from_slice(&x0);
        }
        let mut objs = vec![0.0f64; r];
        batch.epoch_batch(&mut panel, 1, &keys, &mut objs).unwrap();

        let row = d + 1;
        for i in 0..r {
            let mut single =
                NativeCvar::new(u.clone(), n, m, NativeMode::Sequential);
            let (x1, o1) = single.epoch(&x0, 1, keys[i]).unwrap();
            assert_eq!(&panel[i * row..(i + 1) * row], x1.as_slice(),
                       "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
        assert_ne!(&panel[..row], &panel[row..2 * row]);
    }

    #[test]
    fn mv_batch_shape_checked() {
        let u = AssetUniverse::generate(&StreamTree::new(32), 8);
        let mut batch = NativeMvBatch::new(&u, 4, 2, 3, 2);
        let mut objs = vec![0.0f64; 3];
        let mut wrong = vec![0.0f32; 8]; // 1 row, 3 expected
        assert!(batch
            .epoch_batch(&mut wrong, 0, &[[0, 0]; 3], &mut objs)
            .is_err());
        let mut ok = vec![0.1f32; 3 * 8];
        assert!(batch
            .epoch_batch(&mut ok, 0, &[[0, 0]; 2], &mut objs)
            .is_err());
        // objective slot count must match the replication count too
        assert!(batch
            .epoch_batch(&mut ok, 0, &[[0, 0]; 3], &mut objs[..2])
            .is_err());
        assert_eq!(batch.batch_reps(), 3);
    }

    #[test]
    fn nv_batch_grad_bitwise_matches_per_rep() {
        let (d, s, r) = (12usize, 8usize, 4usize);
        let inst =
            NewsvendorInstance::generate(&StreamTree::new(33), d, 2, 0.6);
        let x0 = inst.feasible_start();
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [9, i as u32]).collect();
        let mut x = Vec::new();
        for _ in 0..r {
            x.extend_from_slice(&x0);
        }
        let mut g = vec![0.0f32; r * d];
        let mut batch = NativeNvBatch::new(&inst, s, r, 3);
        let mut objs = vec![0.0f64; r];
        batch.grad_obj_batch(&x, &keys, &mut g, &mut objs).unwrap();
        for i in 0..r {
            let mut single =
                NativeNv::new(inst.clone(), s, NativeMode::Sequential);
            let (g1, o1) = single.grad_obj(&x0, keys[i]).unwrap();
            assert_eq!(&g[i * d..(i + 1) * d], g1.as_slice(), "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
    }

    #[test]
    fn lr_batch_kernels_bitwise_match_per_rep() {
        let (n, r) = (10usize, 3usize);
        let data = ClassifyData::generate(&StreamTree::new(34), n);
        let mut batch =
            NativeLrBatch::new(&data, r, 2, HessianMode::Explicit);
        let mut singles: Vec<NativeLr> = (0..r)
            .map(|_| {
                NativeLr::new(&data, NativeMode::Sequential,
                              HessianMode::Explicit)
            })
            .collect();

        // per-replication iterates + minibatches
        let w: Vec<f32> = (0..r * n).map(|j| (j as f32 * 0.01).sin()).collect();
        let idx: Vec<Vec<usize>> = (0..r)
            .map(|i| (0..16).map(|j| (i * 7 + j * 3) % data.n_samples)
                .collect())
            .collect();

        let mut g = vec![0.0f32; r * n];
        let mut losses = vec![0.0f64; r];
        batch.grad_batch(&w, &data, &idx, &mut g, &mut losses).unwrap();
        for i in 0..r {
            let (g1, l1) = singles[i]
                .grad(&w[i * n..(i + 1) * n], &data, &idx[i])
                .unwrap();
            assert_eq!(&g[i * n..(i + 1) * n], g1.as_slice(), "rep {}", i);
            assert_eq!(losses[i], l1, "rep {}", i);
        }

        // hvp + direction through populated (padded + ragged) memories
        let s_panel: Vec<f32> =
            (0..r * n).map(|j| (j as f32 * 0.02).cos() * 0.1).collect();
        let mut y = vec![0.0f32; r * n];
        batch.hvp_batch(&w, &s_panel, &data, &idx, &mut y).unwrap();
        let mut mems: Vec<CorrectionMemory> = Vec::new();
        let mut batch_mem = BatchCorrectionMemory::new(r, 4, n);
        for i in 0..r {
            let y1 = singles[i]
                .hvp(&w[i * n..(i + 1) * n], &s_panel[i * n..(i + 1) * n],
                     &data, &idx[i])
                .unwrap();
            assert_eq!(&y[i * n..(i + 1) * n], y1.as_slice(), "rep {}", i);
            let mut mem = CorrectionMemory::new(4, n);
            mem.push(&s_panel[i * n..(i + 1) * n], &y1);
            batch_mem.push_row(i, &s_panel[i * n..(i + 1) * n], &y1);
            mems.push(mem);
        }
        let mut dirs = vec![0.0f32; r * n];
        batch.direction_batch(batch_mem.view(), &g, &mut dirs).unwrap();
        for i in 0..r {
            if !batch_mem.is_active(i) {
                continue;
            }
            let d1 = singles[i]
                .direction(&mems[i], &g[i * n..(i + 1) * n])
                .unwrap();
            assert_eq!(&dirs[i * n..(i + 1) * n], d1.as_slice(), "rep {}", i);
        }
    }
}
