//! Native CPU backends: sequential scalar execution (the paper's CPU arm)
//! plus the thread-pooled variant used by ablation A3.
//!
//! The sequential arm deliberately mirrors the paper's §2.2 description of
//! CPU execution — "processing each sample individually" — while remaining
//! idiomatic Rust (no artificial slowdowns): row-by-row matvecs, per-sample
//! indicator counting, per-sample sigmoid accumulation.

use std::sync::Mutex;

use anyhow::Result;

use crate::linalg::matrix::Mat;
use crate::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use crate::tasks::classification as lr;
use crate::tasks::cvar as cv;
use crate::tasks::mean_variance as mv;
use crate::tasks::newsvendor as nv;
use crate::tasks::{BatchMemView, CorrectionMemory};
use crate::util::pool::parallel_map_chunks;
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::{
    HessianMode, LrBackend, LrBatchBackend, MvBackend, MvBatchBackend,
    NvBackend, NvBatchBackend,
};

/// Degree of intra-gradient parallelism for the `native_par` ablation.
#[derive(Debug, Clone, Copy)]
pub enum NativeMode {
    /// Pure sequential (the paper's CPU arm).
    Sequential,
    /// Panel split across `threads` OS threads + blocked kernels (A3).
    Parallel { threads: usize },
}

// ---------------------------------------------------------------------------
// Task 1
// ---------------------------------------------------------------------------

/// Sequential/parallel mean-variance epochs over a sampled return panel.
pub struct NativeMv {
    universe: AssetUniverse,
    n_samples: usize,
    m_inner: usize,
    mode: NativeMode,
    // scratch (reused across epochs)
    panel: Mat,
    scratch: mv::MvScratch,
}

impl NativeMv {
    pub fn new(universe: AssetUniverse, n_samples: usize, m_inner: usize,
               mode: NativeMode) -> Self {
        let d = universe.dim();
        NativeMv {
            universe,
            n_samples,
            m_inner,
            mode,
            panel: Mat::zeros(n_samples, d),
            scratch: mv::MvScratch::new(n_samples, d),
        }
    }

    fn resample(&mut self, key: [u32; 2]) -> Vec<f32> {
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.universe.sample_panel(&mut sampler, self.n_samples,
                                   &mut self.panel.data);
        let rbar = self.panel.col_means();
        self.panel.center_rows(&rbar);
        rbar
    }

    /// Cᵀ(Cw)/(n−1) into `scratch.g` (no R̄ subtraction — the epoch loop
    /// finishes the gradient).
    fn grad_dispatch(&mut self, w: &[f32]) {
        match self.mode {
            NativeMode::Sequential => {
                let n = self.n_samples;
                self.panel.matvec(w, &mut self.scratch.u);
                self.panel.matvec_t(&self.scratch.u, &mut self.scratch.g);
                let inv = 1.0 / (n as f32 - 1.0);
                for v in self.scratch.g.iter_mut() {
                    *v *= inv;
                }
            }
            NativeMode::Parallel { threads } => {
                // split the sample axis: u = C w in parallel chunks, then
                // the reduction g = Cᵀu in parallel column chunks
                let d = self.universe.dim();
                let n = self.n_samples;
                let panel = &self.panel;
                let u: Vec<f32> = parallel_map_chunks(n, threads, |r| {
                    let mut part = Vec::with_capacity(r.len());
                    for i in r {
                        part.push(crate::linalg::blocked::dot4(panel.row(i), w));
                    }
                    part
                })
                .into_iter()
                .flatten()
                .collect();
                let g_parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for i in 0..n {
                        let ui = u[i];
                        let row = panel.row(i);
                        for (o, j) in cols.clone().enumerate() {
                            part[o] += ui * row[j];
                        }
                    }
                    (cols.start, part)
                });
                for (start, part) in g_parts {
                    self.scratch.g[start..start + part.len()]
                        .copy_from_slice(&part);
                }
                self.scratch.u.copy_from_slice(&u);
                let inv = 1.0 / (n as f32 - 1.0);
                for v in self.scratch.g.iter_mut() {
                    *v *= inv;
                }
            }
        }
    }
}

impl MvBackend for NativeMv {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let rbar = self.resample(key);
        let mut w = w.to_vec();
        let m_inner = self.m_inner;
        for m in 0..m_inner {
            self.grad_dispatch(&w);
            // grad_dispatch leaves Cᵀ(Cw)/(n−1) (sequential path already
            // subtracted nothing since rbar slice was empty) — finish:
            for j in 0..w.len() {
                self.scratch.g[j] -= rbar[j];
            }
            let s = mv::simplex_lmo(&self.scratch.g);
            let gamma = crate::opt::schedule::fw_gamma(k_epoch, m, m_inner);
            mv::fw_vertex_update(&mut w, s, gamma);
        }
        let obj = mv::objective(&self.panel, &rbar, &w, &mut self.scratch);
        Ok((w, obj))
    }
}

// ---------------------------------------------------------------------------
// Task 4 — mean-CVaR portfolio (registry extension, DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Smoothed mean-CVaR Frank-Wolfe epochs over a sampled return panel.  The
/// iterate is the joint `[w, t]` vector (length d+1, see `tasks::cvar`),
/// which lets the task implement the Task-1 epoch contract ([`MvBackend`])
/// and ride the same drivers and batch arms.
pub struct NativeCvar {
    universe: AssetUniverse,
    n_samples: usize,
    m_inner: usize,
    mode: NativeMode,
    // scratch (reused across epochs)
    panel: Mat,
    rbar: Vec<f32>,
    scratch: cv::CvScratch,
}

impl NativeCvar {
    pub fn new(universe: AssetUniverse, n_samples: usize, m_inner: usize,
               mode: NativeMode) -> Self {
        let d = universe.dim();
        NativeCvar {
            universe,
            n_samples,
            m_inner,
            mode,
            panel: Mat::zeros(n_samples, d),
            rbar: vec![0.0; d],
            scratch: cv::CvScratch::new(n_samples, d),
        }
    }

    /// Resample the raw return panel (NOT centered — the CVaR tail term
    /// works on the losses themselves) and cache its column means.
    fn resample(&mut self, key: [u32; 2]) {
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.universe.sample_panel(&mut sampler, self.n_samples,
                                   &mut self.panel.data);
        self.rbar = self.panel.col_means();
    }

    /// ∇f(w, t) into `scratch.g`.
    fn grad_dispatch(&mut self, x: &[f32]) {
        match self.mode {
            NativeMode::Sequential => {
                cv::grad(&self.panel, &self.rbar, x, &mut self.scratch);
            }
            NativeMode::Parallel { threads } => {
                // split the sample axis for the loss matvec, then the
                // product axis for the Rᵀσ reduction (mirrors NativeMv's
                // A3 decomposition)
                let d = self.universe.dim();
                let n = self.n_samples;
                let panel = &self.panel;
                let w = &x[..d];
                let t = x[d];
                let losses: Vec<f32> = parallel_map_chunks(n, threads, |r| {
                    let mut part = Vec::with_capacity(r.len());
                    for i in r {
                        part.push(-crate::linalg::blocked::dot4(
                            panel.row(i), w));
                    }
                    part
                })
                .into_iter()
                .flatten()
                .collect();
                let mut sig_sum = 0.0f32;
                for (s, &l) in losses.iter().enumerate() {
                    let sg = cv::sigmoid_eta(l - t);
                    self.scratch.sig[s] = sg;
                    sig_sum += sg;
                }
                let sig = &self.scratch.sig;
                let g_parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for i in 0..n {
                        let si = sig[i];
                        let row = panel.row(i);
                        for (o, j) in cols.clone().enumerate() {
                            part[o] += si * row[j];
                        }
                    }
                    (cols.start, part)
                });
                let c = cv::tail_scale(n);
                for (start, part) in g_parts {
                    for (o, v) in part.into_iter().enumerate() {
                        let j = start + o;
                        self.scratch.g[j] =
                            -self.rbar[j] - cv::LAMBDA * c * v;
                    }
                }
                self.scratch.g[d] = cv::LAMBDA * (1.0 - c * sig_sum);
                self.scratch.losses.copy_from_slice(&losses);
            }
        }
    }
}

impl MvBackend for NativeCvar {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn epoch(&mut self, x: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        anyhow::ensure!(x.len() == self.universe.dim() + 1,
                        "iterate must be [w, t] of length d+1");
        self.resample(key);
        let mut x = x.to_vec();
        let m_inner = self.m_inner;
        for m in 0..m_inner {
            self.grad_dispatch(&x);
            let (vertex, t_vertex) = cv::product_lmo(&self.scratch.g);
            let gamma = crate::opt::schedule::fw_gamma(k_epoch, m, m_inner);
            cv::fw_product_update(&mut x, vertex, t_vertex, gamma);
        }
        let obj = cv::objective(&self.panel, &self.rbar, &x,
                                &mut self.scratch);
        Ok((x, obj))
    }
}

// ---------------------------------------------------------------------------
// Task 2
// ---------------------------------------------------------------------------

pub struct NativeNv {
    inst: NewsvendorInstance,
    s_samples: usize,
    mode: NativeMode,
    panel: Vec<f32>,
    panel_key: Option<[u32; 2]>,
}

impl NativeNv {
    pub fn new(inst: NewsvendorInstance, s_samples: usize, mode: NativeMode)
        -> Self {
        let d = inst.dim();
        NativeNv {
            inst,
            s_samples,
            mode,
            panel: vec![0.0; s_samples * d],
            panel_key: None,
        }
    }

    pub fn instance(&self) -> &NewsvendorInstance {
        &self.inst
    }

    fn ensure_panel(&mut self, key: [u32; 2]) {
        if self.panel_key == Some(key) {
            return; // same epoch key ⇒ same panel (counter-based RNG)
        }
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.inst.sample_panel(&mut sampler, self.s_samples, &mut self.panel);
        self.panel_key = Some(key);
    }
}

impl NvBackend for NativeNv {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        self.ensure_panel(key);
        let d = self.inst.dim();
        let mut g = vec![0.0f32; d];
        match self.mode {
            NativeMode::Sequential => {
                nv::grad(&self.inst, &self.panel, self.s_samples, x, &mut g);
            }
            NativeMode::Parallel { threads } => {
                let inst = &self.inst;
                let panel = &self.panel;
                let s = self.s_samples;
                let parts = parallel_map_chunks(d, threads, |cols| {
                    let mut part = vec![0.0f32; cols.len()];
                    for (o, j) in cols.clone().enumerate() {
                        let mut count = 0u32;
                        for r in 0..s {
                            if panel[r * d + j] <= x[j] {
                                count += 1;
                            }
                        }
                        let cdf = count as f32 / s as f32;
                        part[o] = inst.k[j] - inst.v[j]
                            + (inst.h[j] + inst.v[j]) * cdf;
                    }
                    (cols.start, part)
                });
                for (start, part) in parts {
                    g[start..start + part.len()].copy_from_slice(&part);
                }
            }
        }
        let obj = nv::objective(&self.inst, &self.panel, self.s_samples, x);
        Ok((g, obj))
    }
}

// ---------------------------------------------------------------------------
// Task 3
// ---------------------------------------------------------------------------

pub struct NativeLr {
    n: usize,
    mode: NativeMode,
    pub hessian_mode: HessianMode,
    // gather scratch (reused, no allocation in the iteration loop)
    xb: Vec<f32>,
    zb: Vec<f32>,
    // Algorithm 4 cache: H_t is rebuilt only when the correction memory
    // changes (every L iterations), then applied as a matvec per step —
    // the same schedule the paper's Algorithm 3 line 11 implies.
    h_cache: Option<(u64, Mat)>,
    mem_generation: u64,
}

impl NativeLr {
    pub fn new(data: &ClassifyData, mode: NativeMode,
               hessian_mode: HessianMode) -> Self {
        Self::with_dim(data.n_features, mode, hessian_mode)
    }

    pub fn with_dim(n: usize, mode: NativeMode, hessian_mode: HessianMode)
        -> Self {
        NativeLr {
            n,
            mode,
            hessian_mode,
            xb: Vec::new(),
            zb: Vec::new(),
            h_cache: None,
            mem_generation: 0,
        }
    }
}

impl LrBackend for NativeLr {
    fn name(&self) -> &'static str {
        match self.mode {
            NativeMode::Sequential => "native",
            NativeMode::Parallel { .. } => "native_par",
        }
    }

    fn grad(&mut self, w: &[f32], data: &ClassifyData, idx: &[usize])
        -> Result<(Vec<f32>, f64)> {
        let n = self.n;
        anyhow::ensure!(w.len() == n, "w dim {} != {}", w.len(), n);
        anyhow::ensure!(data.n_features == n, "dataset feature mismatch");
        data.gather(idx, &mut self.xb, &mut self.zb);
        let (xb, zb) = (&self.xb, &self.zb);
        let mut g = vec![0.0f32; n];
        let loss = match self.mode {
            NativeMode::Sequential => lr::grad(w, xb, zb, &mut g),
            NativeMode::Parallel { threads } => {
                let b = zb.len();
                let parts = parallel_map_chunks(b, threads, |rows| {
                    let mut gp = vec![0.0f32; n];
                    let mut lp = 0.0f64;
                    for i in rows {
                        let row = &xb[i * n..(i + 1) * n];
                        let u = crate::linalg::blocked::dot4(row, w);
                        let c = lr::sigmoid(u);
                        let r = c - zb[i];
                        for j in 0..n {
                            gp[j] += r * row[j];
                        }
                        lp += lr::bce(u, zb[i]) as f64;
                    }
                    (gp, lp)
                });
                let mut loss = 0.0f64;
                for (gp, lp) in parts {
                    for j in 0..n {
                        g[j] += gp[j];
                    }
                    loss += lp;
                }
                let inv = 1.0 / b as f32;
                g.iter_mut().for_each(|v| *v *= inv);
                loss / b as f64
            }
        };
        Ok((g, loss))
    }

    fn hvp(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
           idx: &[usize]) -> Result<Vec<f32>> {
        // a new correction pair is about to land ⇒ H_t will change
        self.mem_generation += 1;
        data.gather(idx, &mut self.xb, &mut self.zb);
        let mut out = vec![0.0f32; self.n];
        lr::hvp(wbar, s, &self.xb, &mut out);
        Ok(out)
    }

    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>> {
        Ok(match self.hessian_mode {
            HessianMode::Explicit => {
                let rebuild = match &self.h_cache {
                    Some((generation, _)) => *generation != self.mem_generation,
                    None => true,
                };
                if rebuild {
                    self.h_cache = Some((self.mem_generation,
                                         lr::hbuild_explicit(mem)));
                }
                let (_, h) = self.h_cache.as_ref().unwrap();
                let mut d = vec![0.0f32; g.len()];
                h.matvec(g, &mut d);
                d
            }
            HessianMode::TwoLoop => lr::hdir_twoloop(mem, g),
        })
    }
}

// ---------------------------------------------------------------------------
// Replication-batched arms (DESIGN.md §11)
// ---------------------------------------------------------------------------
//
// Each batch backend holds one per-replication backend per row so every
// row runs the *bit-identical* arithmetic of the sequential path, and
// spreads the replication axis over `parallel_map_chunks` (contiguous
// row chunks per OS thread).  The `Mutex` per row exists only to hand the
// shared closure `&mut` access to its own rows; chunks are disjoint, so
// the locks are never contended.

/// First-error helper for the chunked merge loops below.
fn merge_rows(parts: Vec<(usize, Result<Vec<(Vec<f32>, f64)>>)>,
              row_len: usize, out: &mut [f32]) -> Result<Vec<f64>> {
    let mut scalars = vec![0.0f64; out.len() / row_len.max(1)];
    for (start, part) in parts {
        for (offset, (row, scalar)) in part?.into_iter().enumerate() {
            let i = start + offset;
            out[i * row_len..(i + 1) * row_len].copy_from_slice(&row);
            scalars[i] = scalar;
        }
    }
    Ok(scalars)
}

/// Generic epoch-task batch arm (Tasks 1 and 4): one sequential-mode
/// per-replication backend per row — ANY [`MvBackend`] — with contiguous
/// row chunks spread over the thread pool.  Registering a new
/// epoch-structured scenario costs one `from_rows` constructor, not a new
/// batch backend (DESIGN.md §12).
pub struct NativeEpochBatch<B> {
    reps: Vec<Mutex<B>>,
    /// Per-row iterate length (d for Task 1, d+1 for Task 4's `[w, t]`).
    d: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

impl<B: MvBackend + Send> NativeEpochBatch<B> {
    /// Build from one per-replication row backend per replication;
    /// `row_dim` is the iterate length of one row.
    pub fn from_rows(rows: Vec<B>, row_dim: usize, threads: usize) -> Self {
        NativeEpochBatch {
            reps: rows.into_iter().map(Mutex::new).collect(),
            d: row_dim,
            threads,
            prof: Profiler::new(),
        }
    }
}

/// Task 1 batched: all R replications advance one fused epoch per call.
pub type NativeMvBatch = NativeEpochBatch<NativeMv>;

impl NativeEpochBatch<NativeMv> {
    pub fn new(universe: &AssetUniverse, n_samples: usize, m_inner: usize,
               r_reps: usize, threads: usize) -> Self {
        let d = universe.dim();
        Self::from_rows(
            (0..r_reps)
                .map(|_| {
                    NativeMv::new(universe.clone(), n_samples, m_inner,
                                  NativeMode::Sequential)
                })
                .collect(),
            d,
            threads,
        )
    }
}

/// Task 4 batched: identical machinery over the joint `[w, t]` rows.
pub type NativeCvarBatch = NativeEpochBatch<NativeCvar>;

impl NativeEpochBatch<NativeCvar> {
    pub fn new(universe: &AssetUniverse, n_samples: usize, m_inner: usize,
               r_reps: usize, threads: usize) -> Self {
        let d = universe.dim();
        Self::from_rows(
            (0..r_reps)
                .map(|_| {
                    NativeCvar::new(universe.clone(), n_samples, m_inner,
                                    NativeMode::Sequential)
                })
                .collect(),
            d + 1,
            threads,
        )
    }
}

impl<B: MvBackend + Send> MvBatchBackend for NativeEpochBatch<B> {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]]) -> Result<Vec<f64>> {
        let (r, d) = (self.reps.len(), self.d);
        anyhow::ensure!(w.len() == r * d, "iterate panel {} != {}×{}",
                        w.len(), r, d);
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        let reps = &self.reps;
        let w_in: &[f32] = w;
        let t_par = Timer::start();
        let parts = parallel_map_chunks(r, self.threads, |range| {
            let start = range.start;
            let mut rows = Vec::with_capacity(range.len());
            for i in range {
                let mut rep = reps[i].lock().unwrap();
                match rep.epoch(&w_in[i * d..(i + 1) * d], k_epoch, keys[i]) {
                    Ok((w_next, obj)) => rows.push((w_next, obj)),
                    Err(e) => return (start, Err(e)),
                }
            }
            (start, Ok(rows))
        });
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        let t_red = Timer::start();
        let out = merge_rows(parts, d, w);
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        out
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 2 batched: one Monte-Carlo gradient panel per call.
pub struct NativeNvBatch {
    reps: Vec<Mutex<NativeNv>>,
    d: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

impl NativeNvBatch {
    pub fn new(inst: &NewsvendorInstance, s_samples: usize, r_reps: usize,
               threads: usize) -> Self {
        let d = inst.dim();
        let reps = (0..r_reps)
            .map(|_| {
                Mutex::new(NativeNv::new(inst.clone(), s_samples,
                                         NativeMode::Sequential))
            })
            .collect();
        NativeNvBatch { reps, d, threads, prof: Profiler::new() }
    }
}

impl NvBatchBackend for NativeNvBatch {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn grad_obj_batch(&mut self, x: &[f32], keys: &[[u32; 2]],
                      g: &mut [f32]) -> Result<Vec<f64>> {
        let (r, d) = (self.reps.len(), self.d);
        anyhow::ensure!(x.len() == r * d, "iterate panel {} != {}×{}",
                        x.len(), r, d);
        anyhow::ensure!(g.len() == r * d, "gradient panel shape mismatch");
        anyhow::ensure!(keys.len() == r, "need one key per replication");
        let reps = &self.reps;
        let t_par = Timer::start();
        let parts = parallel_map_chunks(r, self.threads, |range| {
            let start = range.start;
            let mut rows = Vec::with_capacity(range.len());
            for i in range {
                let mut rep = reps[i].lock().unwrap();
                match rep.grad_obj(&x[i * d..(i + 1) * d], keys[i]) {
                    Ok((g_row, obj)) => rows.push((g_row, obj)),
                    Err(e) => return (start, Err(e)),
                }
            }
            (start, Ok(rows))
        });
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        let t_red = Timer::start();
        let out = merge_rows(parts, d, g);
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        out
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 3 batched: SQN kernels for all R replications per call.  Gradients
/// and HVPs run through per-row sequential backends (bit-identical
/// arithmetic); Algorithm-4 directions run directly on the driver's padded
/// `[R × mem × n]` correction panels through the same [`MemView`] recursion
/// cores the ragged path uses — one `direction_batch` call covers every
/// row, with per-row explicit-H caches rebuilt on the sequential cadence
/// (only when that row's memory generation moves, i.e. every L iterations).
pub struct NativeLrBatch {
    reps: Vec<Mutex<NativeLr>>,
    hessian_mode: HessianMode,
    /// Per-row Algorithm-4 cache: ((generation, row count) it was built
    /// at, H).  The `Mutex` exists only to hand the chunked closure
    /// `&mut` access to its own rows; chunks are disjoint, so locks are
    /// never contended.
    ///
    /// Cache validity leans on the SQN driver protocol: correction pairs
    /// only land via `hvp_batch` (which bumps the generation) followed by
    /// `push_row` — so `(generation, count)` moves whenever a row's
    /// memory content can have changed.  Handing `direction_batch` two
    /// unrelated `BatchCorrectionMemory` values at the same generation
    /// AND per-row counts (impossible through `run_sqn_batch`) would
    /// reuse a stale H.
    h_caches: Vec<Mutex<Option<((u64, usize), Mat)>>>,
    /// Bumped by [`Self::hvp_batch`] — a correction pair is about to land,
    /// so every row's H_t goes stale (mirrors `NativeLr::hvp`).
    mem_generation: u64,
    n: usize,
    threads: usize,
    /// Per-phase attribution since the last drain (DESIGN.md §15).
    prof: Profiler,
}

impl NativeLrBatch {
    pub fn new(data: &ClassifyData, r_reps: usize, threads: usize,
               hessian_mode: HessianMode) -> Self {
        let reps = (0..r_reps)
            .map(|_| {
                Mutex::new(NativeLr::new(data, NativeMode::Sequential,
                                         hessian_mode))
            })
            .collect();
        NativeLrBatch {
            reps,
            hessian_mode,
            h_caches: (0..r_reps).map(|_| Mutex::new(None)).collect(),
            mem_generation: 0,
            n: data.n_features,
            threads,
            prof: Profiler::new(),
        }
    }
}

impl LrBatchBackend for NativeLrBatch {
    fn name(&self) -> &'static str {
        "native_batch"
    }

    fn batch_reps(&self) -> usize {
        self.reps.len()
    }

    fn grad_batch(&mut self, w: &[f32], data: &ClassifyData,
                  idx: &[Vec<usize>], g: &mut [f32]) -> Result<Vec<f64>> {
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(w.len() == r * n, "iterate panel {} != {}×{}",
                        w.len(), r, n);
        anyhow::ensure!(g.len() == r * n, "gradient panel shape mismatch");
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        let reps = &self.reps;
        let t_par = Timer::start();
        let parts = parallel_map_chunks(r, self.threads, |range| {
            let start = range.start;
            let mut rows = Vec::with_capacity(range.len());
            for i in range {
                let mut rep = reps[i].lock().unwrap();
                match rep.grad(&w[i * n..(i + 1) * n], data, &idx[i]) {
                    Ok((g_row, loss)) => rows.push((g_row, loss)),
                    Err(e) => return (start, Err(e)),
                }
            }
            (start, Ok(rows))
        });
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        let t_red = Timer::start();
        let out = merge_rows(parts, n, g);
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        out
    }

    fn hvp_batch(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
                 idx: &[Vec<usize>], y: &mut [f32]) -> Result<()> {
        // a new correction pair is about to land ⇒ every row's H_t changes
        self.mem_generation += 1;
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(wbar.len() == r * n && s.len() == r * n,
                        "ω̄/s panel shape mismatch");
        anyhow::ensure!(y.len() == r * n, "output panel shape mismatch");
        anyhow::ensure!(idx.len() == r, "need one index set per replication");
        let reps = &self.reps;
        let t_par = Timer::start();
        let parts = parallel_map_chunks(r, self.threads, |range| {
            let start = range.start;
            let mut rows = Vec::with_capacity(range.len());
            for i in range {
                let mut rep = reps[i].lock().unwrap();
                match rep.hvp(&wbar[i * n..(i + 1) * n],
                              &s[i * n..(i + 1) * n], data, &idx[i]) {
                    Ok(y_row) => rows.push((y_row, 0.0)),
                    Err(e) => return (start, Err(e)),
                }
            }
            (start, Ok(rows))
        });
        self.prof.add(Phase::Compute, t_par.elapsed_s());
        let t_red = Timer::start();
        merge_rows(parts, n, y)?;
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn direction_batch(&mut self, mem: BatchMemView<'_>, g: &[f32],
                       out: &mut [f32]) -> Result<()> {
        let (r, n) = (self.reps.len(), self.n);
        anyhow::ensure!(mem.reps() == r && mem.dim() == n,
                        "correction panels are {}×{}, backend is {}×{}",
                        mem.reps(), mem.dim(), r, n);
        anyhow::ensure!(g.len() == r * n && out.len() == r * n,
                        "gradient/output panel shape mismatch");
        let hessian_mode = self.hessian_mode;
        let generation = self.mem_generation;
        let caches = &self.h_caches;
        let t_dir = Timer::start();
        let parts = parallel_map_chunks(r, self.threads, |range| {
            let mut rows: Vec<(usize, Vec<f32>)> =
                Vec::with_capacity(range.len());
            for i in range {
                if !mem.is_active(i) {
                    // the driver steps with the plain gradient here, as the
                    // sequential path does before the memory fills
                    continue;
                }
                let g_row = &g[i * n..(i + 1) * n];
                let d_row = match hessian_mode {
                    HessianMode::Explicit => {
                        // rebuild row i's H only when its generation or
                        // fill level moved (every L iterations) — the
                        // sequential cadence
                        let stamp = (generation, mem.count(i));
                        let mut cache = caches[i].lock().unwrap();
                        let rebuild = match &*cache {
                            Some((built, _)) => *built != stamp,
                            None => true,
                        };
                        if rebuild {
                            *cache = Some((stamp,
                                           lr::hbuild_explicit_view(
                                               mem.row(i))));
                        }
                        let (_, h) = cache.as_ref().unwrap();
                        let mut d = vec![0.0f32; n];
                        h.matvec(g_row, &mut d);
                        d
                    }
                    HessianMode::TwoLoop => {
                        lr::hdir_twoloop_view(mem.row(i), g_row)
                    }
                };
                rows.push((i, d_row));
            }
            rows
        });
        for part in parts {
            for (i, row) in part {
                out[i * n..(i + 1) * n].copy_from_slice(&row);
            }
        }
        self.prof.add(Phase::Direction, t_dir.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StreamTree;
    use crate::tasks::BatchCorrectionMemory;

    #[test]
    fn mv_epoch_feasible_and_deterministic() {
        let u = AssetUniverse::generate(&StreamTree::new(1), 32);
        let mut b = NativeMv::new(u.clone(), 16, 5, NativeMode::Sequential);
        let w0 = vec![1.0 / 32.0; 32];
        let (w1, o1) = b.epoch(&w0, 0, [1, 2]).unwrap();
        assert!(crate::tasks::mean_variance::in_simplex(&w1, 1e-5));
        let mut b2 = NativeMv::new(u, 16, 5, NativeMode::Sequential);
        let (w2, o2) = b2.epoch(&w0, 0, [1, 2]).unwrap();
        assert_eq!(w1, w2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn mv_parallel_matches_sequential() {
        let u = AssetUniverse::generate(&StreamTree::new(2), 24);
        let w0 = vec![1.0 / 24.0; 24];
        let mut seq = NativeMv::new(u.clone(), 16, 4, NativeMode::Sequential);
        let mut par =
            NativeMv::new(u, 16, 4, NativeMode::Parallel { threads: 3 });
        let (w1, o1) = seq.epoch(&w0, 1, [3, 4]).unwrap();
        let (w2, o2) = par.epoch(&w0, 1, [3, 4]).unwrap();
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
        assert!((o1 - o2).abs() < 1e-4);
    }

    #[test]
    fn nv_panel_cached_per_key() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(3), 16, 2, 0.6);
        let x = inst.feasible_start();
        let mut b = NativeNv::new(inst, 8, NativeMode::Sequential);
        let (g1, o1) = b.grad_obj(&x, [7, 7]).unwrap();
        let (g2, o2) = b.grad_obj(&x, [7, 7]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(o1, o2);
        let (g3, _) = b.grad_obj(&x, [7, 8]).unwrap();
        assert_ne!(g1, g3); // different epoch key ⇒ different panel
    }

    #[test]
    fn nv_parallel_matches_sequential() {
        let inst = NewsvendorInstance::generate(&StreamTree::new(4), 32, 3, 0.6);
        let x = inst.feasible_start();
        let mut seq = NativeNv::new(inst.clone(), 16, NativeMode::Sequential);
        let mut par =
            NativeNv::new(inst, 16, NativeMode::Parallel { threads: 4 });
        let (g1, o1) = seq.grad_obj(&x, [1, 1]).unwrap();
        let (g2, o2) = par.grad_obj(&x, [1, 1]).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn lr_parallel_matches_sequential() {
        let data = ClassifyData::generate(&StreamTree::new(5), 16);
        let mut seq = NativeLr::new(&data, NativeMode::Sequential,
                                    HessianMode::Explicit);
        let mut par = NativeLr::new(&data,
                                    NativeMode::Parallel { threads: 3 },
                                    HessianMode::Explicit);
        let w = vec![0.05f32; 16];
        let idx: Vec<usize> = (0..64).collect();
        let (g1, l1) = seq.grad(&w, &data, &idx).unwrap();
        let (g2, l2) = par.grad(&w, &data, &idx).unwrap();
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
        assert!((l1 - l2).abs() < 1e-9);
    }

    #[test]
    fn lr_bad_shapes_rejected() {
        let data = ClassifyData::generate(&StreamTree::new(6), 8);
        let mut b = NativeLr::with_dim(16, NativeMode::Sequential,
                                       HessianMode::TwoLoop);
        // backend dimension disagrees with both w and the dataset
        let w = vec![0.0f32; 16];
        assert!(b.grad(&w, &data, &[0, 1]).is_err());
        assert!(b.grad(&[0.0; 8], &data, &[0, 1]).is_err());
    }

    // -- batched arms: bit-identical to the per-replication path -----------

    #[test]
    fn mv_batch_epoch_bitwise_matches_per_rep() {
        let (d, n, m, r) = (16usize, 8usize, 4usize, 5usize);
        let u = AssetUniverse::generate(&StreamTree::new(31), d);
        let w0 = vec![1.0f32 / d as f32; d];
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [i as u32 + 1, 2 * i as u32 + 7]).collect();

        let mut batch = NativeMvBatch::new(&u, n, m, r, 3);
        let mut panel: Vec<f32> = Vec::new();
        for _ in 0..r {
            panel.extend_from_slice(&w0);
        }
        let objs = batch.epoch_batch(&mut panel, 2, &keys).unwrap();

        for i in 0..r {
            let mut single =
                NativeMv::new(u.clone(), n, m, NativeMode::Sequential);
            let (w1, o1) = single.epoch(&w0, 2, keys[i]).unwrap();
            assert_eq!(&panel[i * d..(i + 1) * d], w1.as_slice(), "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
        // distinct keys ⇒ distinct rows
        assert_ne!(&panel[..d], &panel[d..2 * d]);
    }

    #[test]
    fn cvar_epoch_feasible_and_deterministic() {
        let u = AssetUniverse::generate(&StreamTree::new(41), 16);
        let x0 = cv::start_iterate(16);
        let mut b = NativeCvar::new(u.clone(), 12, 4, NativeMode::Sequential);
        let (x1, o1) = b.epoch(&x0, 0, [5, 6]).unwrap();
        assert_eq!(x1.len(), 17);
        assert!(cv::in_product(&x1, 1e-5));
        assert!(o1.is_finite());
        let mut b2 = NativeCvar::new(u, 12, 4, NativeMode::Sequential);
        let (x2, o2) = b2.epoch(&x0, 0, [5, 6]).unwrap();
        assert_eq!(x1, x2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn cvar_parallel_matches_sequential() {
        let u = AssetUniverse::generate(&StreamTree::new(42), 12);
        let x0 = cv::start_iterate(12);
        let mut seq = NativeCvar::new(u.clone(), 16, 4,
                                      NativeMode::Sequential);
        let mut par =
            NativeCvar::new(u, 16, 4, NativeMode::Parallel { threads: 3 });
        let (x1, o1) = seq.epoch(&x0, 1, [3, 4]).unwrap();
        let (x2, o2) = par.epoch(&x0, 1, [3, 4]).unwrap();
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-4, "{} vs {}", a, b);
        }
        assert!((o1 - o2).abs() < 1e-4);
    }

    #[test]
    fn cvar_batch_epoch_bitwise_matches_per_rep() {
        let (d, n, m, r) = (10usize, 8usize, 3usize, 4usize);
        let u = AssetUniverse::generate(&StreamTree::new(43), d);
        let x0 = cv::start_iterate(d);
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [i as u32 + 9, 3 * i as u32 + 1]).collect();

        let mut batch = NativeCvarBatch::new(&u, n, m, r, 3);
        let mut panel: Vec<f32> = Vec::new();
        for _ in 0..r {
            panel.extend_from_slice(&x0);
        }
        let objs = batch.epoch_batch(&mut panel, 1, &keys).unwrap();

        let row = d + 1;
        for i in 0..r {
            let mut single =
                NativeCvar::new(u.clone(), n, m, NativeMode::Sequential);
            let (x1, o1) = single.epoch(&x0, 1, keys[i]).unwrap();
            assert_eq!(&panel[i * row..(i + 1) * row], x1.as_slice(),
                       "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
        assert_ne!(&panel[..row], &panel[row..2 * row]);
    }

    #[test]
    fn mv_batch_shape_checked() {
        let u = AssetUniverse::generate(&StreamTree::new(32), 8);
        let mut batch = NativeMvBatch::new(&u, 4, 2, 3, 2);
        let mut wrong = vec![0.0f32; 8]; // 1 row, 3 expected
        assert!(batch.epoch_batch(&mut wrong, 0, &[[0, 0]; 3]).is_err());
        let mut ok = vec![0.1f32; 3 * 8];
        assert!(batch.epoch_batch(&mut ok, 0, &[[0, 0]; 2]).is_err());
        assert_eq!(batch.batch_reps(), 3);
    }

    #[test]
    fn nv_batch_grad_bitwise_matches_per_rep() {
        let (d, s, r) = (12usize, 8usize, 4usize);
        let inst =
            NewsvendorInstance::generate(&StreamTree::new(33), d, 2, 0.6);
        let x0 = inst.feasible_start();
        let keys: Vec<[u32; 2]> =
            (0..r).map(|i| [9, i as u32]).collect();
        let mut x = Vec::new();
        for _ in 0..r {
            x.extend_from_slice(&x0);
        }
        let mut g = vec![0.0f32; r * d];
        let mut batch = NativeNvBatch::new(&inst, s, r, 3);
        let objs = batch.grad_obj_batch(&x, &keys, &mut g).unwrap();
        for i in 0..r {
            let mut single =
                NativeNv::new(inst.clone(), s, NativeMode::Sequential);
            let (g1, o1) = single.grad_obj(&x0, keys[i]).unwrap();
            assert_eq!(&g[i * d..(i + 1) * d], g1.as_slice(), "rep {}", i);
            assert_eq!(objs[i], o1, "rep {}", i);
        }
    }

    #[test]
    fn lr_batch_kernels_bitwise_match_per_rep() {
        let (n, r) = (10usize, 3usize);
        let data = ClassifyData::generate(&StreamTree::new(34), n);
        let mut batch =
            NativeLrBatch::new(&data, r, 2, HessianMode::Explicit);
        let mut singles: Vec<NativeLr> = (0..r)
            .map(|_| {
                NativeLr::new(&data, NativeMode::Sequential,
                              HessianMode::Explicit)
            })
            .collect();

        // per-replication iterates + minibatches
        let w: Vec<f32> = (0..r * n).map(|j| (j as f32 * 0.01).sin()).collect();
        let idx: Vec<Vec<usize>> = (0..r)
            .map(|i| (0..16).map(|j| (i * 7 + j * 3) % data.n_samples)
                .collect())
            .collect();

        let mut g = vec![0.0f32; r * n];
        let losses = batch.grad_batch(&w, &data, &idx, &mut g).unwrap();
        for i in 0..r {
            let (g1, l1) = singles[i]
                .grad(&w[i * n..(i + 1) * n], &data, &idx[i])
                .unwrap();
            assert_eq!(&g[i * n..(i + 1) * n], g1.as_slice(), "rep {}", i);
            assert_eq!(losses[i], l1, "rep {}", i);
        }

        // hvp + direction through populated (padded + ragged) memories
        let s_panel: Vec<f32> =
            (0..r * n).map(|j| (j as f32 * 0.02).cos() * 0.1).collect();
        let mut y = vec![0.0f32; r * n];
        batch.hvp_batch(&w, &s_panel, &data, &idx, &mut y).unwrap();
        let mut mems: Vec<CorrectionMemory> = Vec::new();
        let mut batch_mem = BatchCorrectionMemory::new(r, 4, n);
        for i in 0..r {
            let y1 = singles[i]
                .hvp(&w[i * n..(i + 1) * n], &s_panel[i * n..(i + 1) * n],
                     &data, &idx[i])
                .unwrap();
            assert_eq!(&y[i * n..(i + 1) * n], y1.as_slice(), "rep {}", i);
            let mut mem = CorrectionMemory::new(4, n);
            mem.push(&s_panel[i * n..(i + 1) * n], &y1);
            batch_mem.push_row(i, &s_panel[i * n..(i + 1) * n], &y1);
            mems.push(mem);
        }
        let mut dirs = vec![0.0f32; r * n];
        batch.direction_batch(batch_mem.view(), &g, &mut dirs).unwrap();
        for i in 0..r {
            if !batch_mem.is_active(i) {
                continue;
            }
            let d1 = singles[i]
                .direction(&mems[i], &g[i * n..(i + 1) * n])
                .unwrap();
            assert_eq!(&dirs[i * n..(i + 1) * n], d1.as_slice(), "rep {}", i);
        }
    }
}
