//! XLA backends: the vectorized "GPU-style" arm.  Each backend holds
//! compiled artifact handles from the [`crate::runtime::Engine`] and turns
//! trait calls into PJRT dispatches.
//!
//! Task 1 is the showcase: one `mv_epoch` dispatch covers the panel
//! resampling *and* all M Frank-Wolfe steps (sampling + LMO + updates fused
//! into a single XLA program), so the host↔device boundary is crossed once
//! per epoch (ablation A1 measures the alternative).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::runtime::exec::DeviceBuf;
use crate::runtime::{exec, Arg, BufArg, Engine, Exec};
use crate::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use crate::tasks::{BatchMemView, CorrectionMemory};
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::{
    HessianMode, LrBackend, LrBatchBackend, MvBackend, MvBatchBackend,
    NvBackend, NvBatchBackend,
};

// ---------------------------------------------------------------------------
// Task 1
// ---------------------------------------------------------------------------

pub struct XlaMv {
    exec: Rc<Exec>,
    mu: Vec<f32>,
    sigma: Vec<f32>,
}

impl XlaMv {
    /// Loads the `mv_epoch` artifact matching the universe's dimension and
    /// the requested panel shape.
    pub fn new(engine: &Engine, universe: &AssetUniverse, n_samples: usize,
               m_inner: usize) -> Result<Self> {
        let d = universe.dim() as i64;
        let exec = engine
            .load_by_params(
                "mv_epoch",
                &[("d", d), ("n", n_samples as i64), ("m", m_inner as i64)],
            )
            .context("loading mv_epoch artifact")?;
        Ok(XlaMv { exec, mu: universe.mu.clone(), sigma: universe.sigma.clone() })
    }
}

impl MvBackend for XlaMv {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let outs = self.exec.call(&[
            Arg::F32(w),
            Arg::F32(&self.mu),
            Arg::F32(&self.sigma),
            Arg::U32(&key),
            Arg::ScalarI32(k_epoch as i32),
        ])?;
        let w_out = exec::f32_vec(&outs[0])?;
        let obj = exec::f32_scalar(&outs[1])? as f64;
        Ok((w_out, obj))
    }
}

/// Per-iteration dispatch variant (ablation A1): the host owns the panel
/// and pays a dispatch + panel transfer per FW step.
pub struct XlaMvStepwise {
    exec: Rc<Exec>,
    universe: AssetUniverse,
    n_samples: usize,
    m_inner: usize,
    // host-side panel staging
    panel: Vec<f32>,
    rbar: Vec<f32>,
}

impl XlaMvStepwise {
    pub fn new(engine: &Engine, universe: &AssetUniverse, n_samples: usize,
               m_inner: usize) -> Result<Self> {
        let d = universe.dim() as i64;
        let exec = engine.load_by_params(
            "mv_grad_step",
            &[("d", d), ("n", n_samples as i64), ("m", m_inner as i64)],
        )?;
        let d = universe.dim();
        Ok(XlaMvStepwise {
            exec,
            universe: universe.clone(),
            n_samples,
            m_inner,
            panel: vec![0.0; n_samples * d],
            rbar: vec![0.0; d],
        })
    }
}

impl MvBackend for XlaMvStepwise {
    fn name(&self) -> &'static str {
        "xla_stepwise"
    }

    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        // Host-side resample + centering (mirrors the native arm), then one
        // dispatch per FW step.
        let d = self.universe.dim();
        let seed = (key[0] as u64) << 32 | key[1] as u64;
        let mut sampler = crate::rng::NormalSampler::from_seed(seed);
        self.universe.sample_panel(&mut sampler, self.n_samples, &mut self.panel);
        // column means
        self.rbar.iter_mut().for_each(|v| *v = 0.0);
        for s in 0..self.n_samples {
            for j in 0..d {
                self.rbar[j] += self.panel[s * d + j];
            }
        }
        let inv = 1.0 / self.n_samples as f32;
        self.rbar.iter_mut().for_each(|v| *v *= inv);
        for s in 0..self.n_samples {
            for j in 0..d {
                self.panel[s * d + j] -= self.rbar[j];
            }
        }
        let mut w = w.to_vec();
        let mut obj = 0.0f32;
        for m in 0..self.m_inner {
            let outs = self.exec.call(&[
                Arg::F32(&self.panel),
                Arg::F32(&self.rbar),
                Arg::F32(&w),
                Arg::ScalarI32(k_epoch as i32),
                Arg::ScalarI32(m as i32),
            ])?;
            w = exec::f32_vec(&outs[0])?;
            obj = exec::f32_scalar(&outs[1])?;
        }
        Ok((w, obj as f64))
    }
}

// ---------------------------------------------------------------------------
// Task 4 — mean-CVaR portfolio (registry extension, DESIGN.md §12)
// ---------------------------------------------------------------------------

/// One `cv_epoch` dispatch covers the raw-panel resampling and all M
/// smoothed-CVaR Frank-Wolfe steps on the joint `[w, t]` iterate — the
/// same fused-epoch discipline as [`XlaMv`], over the `MvBackend`
/// contract, so the CVaR task rides the Task-1 drivers unchanged.
pub struct XlaCvar {
    exec: Rc<Exec>,
    mu: Vec<f32>,
    sigma: Vec<f32>,
}

impl XlaCvar {
    pub fn new(engine: &Engine, universe: &AssetUniverse, n_samples: usize,
               m_inner: usize) -> Result<Self> {
        let d = universe.dim() as i64;
        let exec = engine
            .load_by_params(
                "cv_epoch",
                &[("d", d), ("n", n_samples as i64), ("m", m_inner as i64)],
            )
            .context("loading cv_epoch artifact")?;
        Ok(XlaCvar {
            exec,
            mu: universe.mu.clone(),
            sigma: universe.sigma.clone(),
        })
    }
}

impl MvBackend for XlaCvar {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn epoch(&mut self, x: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        anyhow::ensure!(x.len() == self.mu.len() + 1,
                        "iterate must be [w, t] of length d+1");
        let outs = self.exec.call(&[
            Arg::F32(x),
            Arg::F32(&self.mu),
            Arg::F32(&self.sigma),
            Arg::U32(&key),
            Arg::ScalarI32(k_epoch as i32),
        ])?;
        let x_out = exec::f32_vec(&outs[0])?;
        let obj = exec::f32_scalar(&outs[1])? as f64;
        Ok((x_out, obj))
    }
}

// ---------------------------------------------------------------------------
// Task 2
// ---------------------------------------------------------------------------

/// Device-resident newsvendor backend (§Perf): per epoch, `nv_panel`
/// samples the demand panel ONCE into a PJRT buffer that never leaves the
/// device; each of the M inner iterations runs `nv_grad_panel` against it
/// (per-call host traffic: one d-vector up, one d-vector + scalar down).
/// Cost vectors are uploaded once at construction.
pub struct XlaNv {
    panel_exec: Rc<Exec>,
    grad_exec: Rc<Exec>,
    mu_buf: DeviceBuf,
    sigma_buf: DeviceBuf,
    kc_buf: DeviceBuf,
    h_buf: DeviceBuf,
    v_buf: DeviceBuf,
    panel: Option<([u32; 2], DeviceBuf)>,
}

impl XlaNv {
    pub fn new(engine: &Engine, inst: &NewsvendorInstance, s_samples: usize)
        -> Result<Self> {
        let req = [("d", inst.dim() as i64), ("s", s_samples as i64)];
        let panel_exec = engine.load_by_params("nv_panel", &req)?;
        let grad_exec = engine.load_by_params("nv_grad_panel", &req)?;
        // nv_panel inputs: (mu, sigma, key); nv_grad_panel: (x, panel, kc, h, v)
        let mu_buf = panel_exec.upload(0, Arg::F32(&inst.mu))?;
        let sigma_buf = panel_exec.upload(1, Arg::F32(&inst.sigma))?;
        let kc_buf = grad_exec.upload(2, Arg::F32(&inst.k))?;
        let h_buf = grad_exec.upload(3, Arg::F32(&inst.h))?;
        let v_buf = grad_exec.upload(4, Arg::F32(&inst.v))?;
        Ok(XlaNv {
            panel_exec,
            grad_exec,
            mu_buf,
            sigma_buf,
            kc_buf,
            h_buf,
            v_buf,
            panel: None,
        })
    }

    fn ensure_panel(&mut self, key: [u32; 2]) -> Result<()> {
        if matches!(&self.panel, Some((k, _)) if *k == key) {
            return Ok(());
        }
        // Sample on device, round-trip the panel through the host once per
        // epoch, and park it as a buffer for the M inner iterations.  (A
        // fully device-side chain needs untupled outputs, which this
        // xla_extension build mis-sizes under execute_b — see runtime docs.)
        let outs = self.panel_exec.call_b(&[
            BufArg::Dev(&self.mu_buf),
            BufArg::Dev(&self.sigma_buf),
            BufArg::Host(Arg::U32(&key)),
        ])?;
        let panel_host = exec::f32_vec(&outs[0])?;
        let buf = self.grad_exec.upload(1, Arg::F32(&panel_host))?;
        self.panel = Some((key, buf));
        Ok(())
    }
}

impl NvBackend for XlaNv {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        self.ensure_panel(key)?;
        let (_, panel) = self.panel.as_ref().unwrap();
        let outs = self.grad_exec.call_b(&[
            BufArg::Host(Arg::F32(x)),
            BufArg::Dev(panel),
            BufArg::Dev(&self.kc_buf),
            BufArg::Dev(&self.h_buf),
            BufArg::Dev(&self.v_buf),
        ])?;
        let g = exec::f32_vec(&outs[0])?;
        let obj = exec::f32_scalar(&outs[1])? as f64;
        Ok((g, obj))
    }
}

/// Per-call variant (ablation A5): the original `nv_grad` artifact that
/// resamples the panel in-graph on EVERY gradient call and ships all cost
/// vectors per dispatch — the naive offload pattern.
pub struct XlaNvPerCall {
    exec: Rc<Exec>,
    inst: NewsvendorInstance,
}

impl XlaNvPerCall {
    pub fn new(engine: &Engine, inst: &NewsvendorInstance, s_samples: usize)
        -> Result<Self> {
        let exec = engine.load_by_params(
            "nv_grad",
            &[("d", inst.dim() as i64), ("s", s_samples as i64)],
        )?;
        Ok(XlaNvPerCall { exec, inst: inst.clone() })
    }
}

impl NvBackend for XlaNvPerCall {
    fn name(&self) -> &'static str {
        "xla_percall"
    }

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)> {
        let outs = self.exec.call(&[
            Arg::F32(x),
            Arg::F32(&self.inst.mu),
            Arg::F32(&self.inst.sigma),
            Arg::F32(&self.inst.k),
            Arg::F32(&self.inst.h),
            Arg::F32(&self.inst.v),
            Arg::U32(&key),
        ])?;
        let g = exec::f32_vec(&outs[0])?;
        let obj = exec::f32_scalar(&outs[1])? as f64;
        Ok((g, obj))
    }
}

// ---------------------------------------------------------------------------
// Task 3
// ---------------------------------------------------------------------------

/// Device-resident SQN backend (§Perf):
/// * the full (N×n) design matrix + labels are uploaded ONCE at
///   construction and gathered in-graph per minibatch (`lr_grad_ds` /
///   `lr_hvp_ds`) — per-iteration host traffic is (w, idx) up, (g, loss)
///   down;
/// * in explicit-H mode the Algorithm-4 matrix is built on device
///   (`lr_hbuild`, untupled) and stays a PJRT buffer; `lr_happly` consumes
///   it directly — the n×n matrix never crosses the host boundary.
pub struct XlaLr {
    grad_exec: Rc<Exec>,
    hvp_exec: Rc<Exec>,
    hbuild_exec: Option<Rc<Exec>>,
    happly_exec: Option<Rc<Exec>>,
    twoloop_exec: Option<Rc<Exec>>,
    pub hessian_mode: HessianMode,
    n: usize,
    memory: usize,
    x_buf: DeviceBuf,
    z_buf: DeviceBuf,
    /// Device-resident H: (memory generation it was built from, buffer).
    h_buf: Option<(u64, DeviceBuf)>,
    mem_generation: u64,
    /// Scratch for i32 index conversion.
    idx_i32: Vec<i32>,
}

/// Pad a correction memory into the fixed `(capacity × n)` layout the
/// `lr_hbuild` / `lr_dir_twoloop` artifacts expect (rows `[0, count)`
/// valid, zero-padded tail).
fn padded_mem(mem: &CorrectionMemory, capacity: usize, n: usize)
    -> (Vec<f32>, Vec<f32>, i32) {
    let mut s = vec![0.0f32; capacity * n];
    let mut y = vec![0.0f32; capacity * n];
    let count = mem.count.min(capacity);
    let take = count * n;
    s[..take].copy_from_slice(&mem.s_mem[..take]);
    y[..take].copy_from_slice(&mem.y_mem[..take]);
    (s, y, count as i32)
}

impl XlaLr {
    pub fn new(engine: &Engine, data: &ClassifyData, batch: usize,
               hbatch: usize, memory: usize, hessian_mode: HessianMode)
        -> Result<Self> {
        let n = data.n_features as i64;
        let rows = data.n_samples as i64;
        let grad_exec = engine.load_by_params(
            "lr_grad_ds", &[("n", n), ("b", batch as i64), ("rows", rows)])
            .context("lr_grad_ds artifact (rows must equal 30·n)")?;
        let hvp_exec = engine.load_by_params(
            "lr_hvp_ds", &[("n", n), ("bh", hbatch as i64), ("rows", rows)])?;
        let (hbuild_exec, happly_exec, twoloop_exec) = match hessian_mode {
            HessianMode::Explicit => (
                Some(engine.load_by_params(
                    "lr_hbuild", &[("n", n), ("mem", memory as i64)])?),
                Some(engine.load_by_params("lr_happly", &[("n", n)])?),
                None,
            ),
            HessianMode::TwoLoop => (
                None,
                None,
                Some(engine.load_by_params(
                    "lr_dir_twoloop", &[("n", n), ("mem", memory as i64)])?),
            ),
        };
        // lr_grad_ds inputs: (w, x_full, z_full, idx)
        let x_buf = grad_exec.upload(1, Arg::F32(&data.x))?;
        let z_buf = grad_exec.upload(2, Arg::F32(&data.z))?;
        Ok(XlaLr {
            grad_exec,
            hvp_exec,
            hbuild_exec,
            happly_exec,
            twoloop_exec,
            hessian_mode,
            n: data.n_features,
            memory,
            x_buf,
            z_buf,
            h_buf: None,
            mem_generation: 0,
            idx_i32: Vec::new(),
        })
    }

    /// Pad the correction memory into the fixed (mem × n) artifact layout.
    fn padded_mem(&self, mem: &CorrectionMemory) -> (Vec<f32>, Vec<f32>, i32) {
        padded_mem(mem, self.memory, self.n)
    }

    fn idx_arg(&mut self, idx: &[usize]) {
        self.idx_i32.clear();
        self.idx_i32.extend(idx.iter().map(|&i| i as i32));
    }
}

impl LrBackend for XlaLr {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn grad(&mut self, w: &[f32], _data: &ClassifyData, idx: &[usize])
        -> Result<(Vec<f32>, f64)> {
        self.idx_arg(idx);
        let outs = self.grad_exec.call_b(&[
            BufArg::Host(Arg::F32(w)),
            BufArg::Dev(&self.x_buf),
            BufArg::Dev(&self.z_buf),
            BufArg::Host(Arg::I32(&self.idx_i32)),
        ])?;
        let g = exec::f32_vec(&outs[0])?;
        let loss = exec::f32_scalar(&outs[1])? as f64;
        Ok((g, loss))
    }

    fn hvp(&mut self, wbar: &[f32], s: &[f32], _data: &ClassifyData,
           idx: &[usize]) -> Result<Vec<f32>> {
        // memory contents changed ⇒ invalidate the resident H
        self.mem_generation += 1;
        self.idx_arg(idx);
        let outs = self.hvp_exec.call_b(&[
            BufArg::Host(Arg::F32(wbar)),
            BufArg::Host(Arg::F32(s)),
            BufArg::Dev(&self.x_buf),
            BufArg::Host(Arg::I32(&self.idx_i32)),
        ])?;
        exec::f32_vec(&outs[0])
    }

    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>> {
        match self.hessian_mode {
            HessianMode::Explicit => {
                // Algorithm 4: H_t changes only when a new pair arrives
                // (every L iterations) — rebuild on device then, reuse the
                // buffer between.
                let rebuild = match &self.h_buf {
                    Some((generation, _)) => *generation != self.mem_generation,
                    None => true,
                };
                if rebuild {
                    let (s, y, count) = self.padded_mem(mem);
                    let outs = self.hbuild_exec.as_ref().unwrap().call(&[
                        Arg::F32(&s),
                        Arg::F32(&y),
                        Arg::ScalarI32(count),
                    ])?;
                    // one n×n round-trip per rebuild (every L iterations),
                    // then the matrix stays device-resident for the L
                    // direction applications
                    let h_host = exec::f32_vec(&outs[0])?;
                    let h = self.happly_exec
                        .as_ref()
                        .unwrap()
                        .upload(0, Arg::F32(&h_host))?;
                    self.h_buf = Some((self.mem_generation, h));
                }
                let (_, h) = self.h_buf.as_ref().unwrap();
                let outs = self.happly_exec.as_ref().unwrap().call_b(&[
                    BufArg::Dev(h),
                    BufArg::Host(Arg::F32(g)),
                ])?;
                exec::f32_vec(&outs[0])
            }
            HessianMode::TwoLoop => {
                let (s, y, count) = self.padded_mem(mem);
                let outs = self.twoloop_exec.as_ref().unwrap().call(&[
                    Arg::F32(&s),
                    Arg::F32(&y),
                    Arg::ScalarI32(count),
                    Arg::F32(g),
                ])?;
                exec::f32_vec(&outs[0])
            }
        }
    }
}

/// Per-call SQN variant (ablation A5): ships the gathered minibatch on
/// every gradient call and the full n×n Hessian across the boundary twice
/// per direction — the naive offload pattern the resident path replaces.
pub struct XlaLrPerCall {
    grad_exec: Rc<Exec>,
    hvp_exec: Rc<Exec>,
    twoloop_exec: Rc<Exec>,
    memory: usize,
    n: usize,
    xb: Vec<f32>,
    zb: Vec<f32>,
}

impl XlaLrPerCall {
    pub fn new(engine: &Engine, data: &ClassifyData, batch: usize,
               hbatch: usize, memory: usize) -> Result<Self> {
        let n = data.n_features as i64;
        Ok(XlaLrPerCall {
            grad_exec: engine.load_by_params(
                "lr_grad", &[("n", n), ("b", batch as i64)])?,
            hvp_exec: engine.load_by_params(
                "lr_hvp", &[("n", n), ("bh", hbatch as i64)])?,
            twoloop_exec: engine.load_by_params(
                "lr_dir_twoloop", &[("n", n), ("mem", memory as i64)])?,
            memory,
            n: data.n_features,
            xb: Vec::new(),
            zb: Vec::new(),
        })
    }
}

impl LrBackend for XlaLrPerCall {
    fn name(&self) -> &'static str {
        "xla_percall"
    }

    fn grad(&mut self, w: &[f32], data: &ClassifyData, idx: &[usize])
        -> Result<(Vec<f32>, f64)> {
        data.gather(idx, &mut self.xb, &mut self.zb);
        let outs = self.grad_exec.call(&[
            Arg::F32(w),
            Arg::F32(&self.xb),
            Arg::F32(&self.zb),
        ])?;
        let g = exec::f32_vec(&outs[0])?;
        let loss = exec::f32_scalar(&outs[1])? as f64;
        Ok((g, loss))
    }

    fn hvp(&mut self, wbar: &[f32], s: &[f32], data: &ClassifyData,
           idx: &[usize]) -> Result<Vec<f32>> {
        data.gather(idx, &mut self.xb, &mut self.zb);
        let outs = self
            .hvp_exec
            .call(&[Arg::F32(wbar), Arg::F32(s), Arg::F32(&self.xb)])?;
        exec::f32_vec(&outs[0])
    }

    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>> {
        let (s, y, count) = padded_mem(mem, self.memory, self.n);
        let outs = self.twoloop_exec.call(&[
            Arg::F32(&s),
            Arg::F32(&y),
            Arg::ScalarI32(count),
            Arg::F32(g),
        ])?;
        exec::f32_vec(&outs[0])
    }
}

// ---------------------------------------------------------------------------
// Replication-batched arms (DESIGN.md §11)
// ---------------------------------------------------------------------------
//
// One batched artifact dispatch advances ALL R replications per epoch —
// the fusion Zhou, Lange & Suchard apply to independent chains — instead
// of R per-replication dispatches through `runtime::exec`.  The batched
// artifacts are jax.vmap lowerings of the per-replication graphs
// (python/compile/aot.py `--reps`), so each row computes the same math as
// the unbatched artifact on its own threefry key.

fn flatten_keys(keys: &[[u32; 2]], out: &mut Vec<u32>) {
    out.clear();
    for k in keys {
        out.push(k[0]);
        out.push(k[1]);
    }
}

/// Task 1 batched: `mv_epoch_batch` runs panel resampling + all M FW steps
/// for every replication in ONE device dispatch per epoch.
pub struct XlaMvBatch {
    exec: Rc<Exec>,
    mu: Vec<f32>,
    sigma: Vec<f32>,
    r: usize,
    d: usize,
    keys_flat: Vec<u32>,
    /// Per-phase attribution since the last drain (DESIGN.md §15):
    /// key/index staging → dispatch, the artifact call → compute, output
    /// decode + copy-out → reduce.
    prof: Profiler,
}

impl XlaMvBatch {
    pub fn new(engine: &Engine, universe: &AssetUniverse, n_samples: usize,
               m_inner: usize, r_reps: usize) -> Result<Self> {
        let d = universe.dim();
        let exec = engine
            .load_by_params(
                "mv_epoch_batch",
                &[("d", d as i64), ("n", n_samples as i64),
                  ("m", m_inner as i64), ("r", r_reps as i64)],
            )
            .context(
                "loading mv_epoch_batch artifact (regenerate with \
                 `python -m compile.aot --reps R`)",
            )?;
        Ok(XlaMvBatch {
            exec,
            mu: universe.mu.clone(),
            sigma: universe.sigma.clone(),
            r: r_reps,
            d,
            keys_flat: Vec::with_capacity(2 * r_reps),
            prof: Profiler::new(),
        })
    }
}

impl MvBatchBackend for XlaMvBatch {
    fn name(&self) -> &'static str {
        "xla_batch"
    }

    fn batch_reps(&self) -> usize {
        self.r
    }

    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()> {
        anyhow::ensure!(w.len() == self.r * self.d,
                        "iterate panel {} != {}×{}", w.len(), self.r, self.d);
        anyhow::ensure!(keys.len() == self.r, "need one key per replication");
        anyhow::ensure!(objs.len() == self.r,
                        "need one objective slot per replication");
        let t_stage = Timer::start();
        flatten_keys(keys, &mut self.keys_flat);
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let t_exec = Timer::start();
        let outs = self.exec.call(&[
            Arg::F32(w),
            Arg::F32(&self.mu),
            Arg::F32(&self.sigma),
            Arg::U32(&self.keys_flat),
            Arg::ScalarI32(k_epoch as i32),
        ])?;
        self.prof.add(Phase::Compute, t_exec.elapsed_s());
        let t_red = Timer::start();
        let w_out = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(w_out.len() == w.len(),
                        "mv_epoch_batch returned wrong panel shape");
        w.copy_from_slice(&w_out);
        let obj_out = exec::f32_vec(&outs[1])?;
        anyhow::ensure!(obj_out.len() == self.r,
                        "mv_epoch_batch returned {} objectives for {} \
                         replications", obj_out.len(), self.r);
        for (slot, o) in objs.iter_mut().zip(obj_out) {
            *slot = o as f64;
        }
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 4 batched: `cv_epoch_batch` advances every replication's joint
/// `[w, t]` row by one fused smoothed-CVaR epoch in ONE device dispatch —
/// the Task-1 batched discipline over the registry's fourth scenario.
pub struct XlaCvarBatch {
    exec: Rc<Exec>,
    mu: Vec<f32>,
    sigma: Vec<f32>,
    r: usize,
    /// Per-row iterate length d+1.
    row: usize,
    keys_flat: Vec<u32>,
    /// Per-phase attribution (see [`XlaMvBatch`]).
    prof: Profiler,
}

impl XlaCvarBatch {
    pub fn new(engine: &Engine, universe: &AssetUniverse, n_samples: usize,
               m_inner: usize, r_reps: usize) -> Result<Self> {
        let d = universe.dim();
        let exec = engine
            .load_by_params(
                "cv_epoch_batch",
                &[("d", d as i64), ("n", n_samples as i64),
                  ("m", m_inner as i64), ("r", r_reps as i64)],
            )
            .context(
                "loading cv_epoch_batch artifact (regenerate with \
                 `python -m compile.aot --reps R`)",
            )?;
        Ok(XlaCvarBatch {
            exec,
            mu: universe.mu.clone(),
            sigma: universe.sigma.clone(),
            r: r_reps,
            row: d + 1,
            keys_flat: Vec::with_capacity(2 * r_reps),
            prof: Profiler::new(),
        })
    }
}

impl MvBatchBackend for XlaCvarBatch {
    fn name(&self) -> &'static str {
        "xla_batch"
    }

    fn batch_reps(&self) -> usize {
        self.r
    }

    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()> {
        anyhow::ensure!(w.len() == self.r * self.row,
                        "iterate panel {} != {}×{}", w.len(), self.r,
                        self.row);
        anyhow::ensure!(keys.len() == self.r, "need one key per replication");
        anyhow::ensure!(objs.len() == self.r,
                        "need one objective slot per replication");
        let t_stage = Timer::start();
        flatten_keys(keys, &mut self.keys_flat);
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let t_exec = Timer::start();
        let outs = self.exec.call(&[
            Arg::F32(w),
            Arg::F32(&self.mu),
            Arg::F32(&self.sigma),
            Arg::U32(&self.keys_flat),
            Arg::ScalarI32(k_epoch as i32),
        ])?;
        self.prof.add(Phase::Compute, t_exec.elapsed_s());
        let t_red = Timer::start();
        let w_out = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(w_out.len() == w.len(),
                        "cv_epoch_batch returned wrong panel shape");
        w.copy_from_slice(&w_out);
        let obj_out = exec::f32_vec(&outs[1])?;
        anyhow::ensure!(obj_out.len() == self.r,
                        "cv_epoch_batch returned {} objectives for {} \
                         replications", obj_out.len(), self.r);
        for (slot, o) in objs.iter_mut().zip(obj_out) {
            *slot = o as f64;
        }
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 2 batched, device-resident (the batched analogue of [`XlaNv`]):
/// `nv_panel_batch` samples every replication's demand panel ONCE per
/// epoch into a PJRT buffer that stays on device; each of the M inner
/// iterations runs `nv_grad_panel_batch` against it in ONE dispatch for
/// all R replications.  Cost vectors are uploaded once at construction —
/// per-call host traffic is one `[R × d]` iterate panel up, one
/// `[R × d]` gradient panel + R objectives down.
pub struct XlaNvBatch {
    panel_exec: Rc<Exec>,
    grad_exec: Rc<Exec>,
    mu_buf: DeviceBuf,
    sigma_buf: DeviceBuf,
    kc_buf: DeviceBuf,
    h_buf: DeviceBuf,
    v_buf: DeviceBuf,
    /// (keys it was sampled from, resident `[R × S × d]` panel).
    panel: Option<(Vec<[u32; 2]>, DeviceBuf)>,
    r: usize,
    d: usize,
    keys_flat: Vec<u32>,
    /// Per-phase attribution (see [`XlaMvBatch`]); the once-per-epoch
    /// panel (re)sampling + upload books as dispatch — it stages the
    /// resident buffer the M inner iterations consume.
    prof: Profiler,
}

impl XlaNvBatch {
    pub fn new(engine: &Engine, inst: &NewsvendorInstance, s_samples: usize,
               r_reps: usize) -> Result<Self> {
        let req = [("d", inst.dim() as i64), ("s", s_samples as i64),
                   ("r", r_reps as i64)];
        let panel_exec = engine
            .load_by_params("nv_panel_batch", &req)
            .context(
                "loading nv_panel_batch artifact (regenerate with \
                 `python -m compile.aot --reps R`)",
            )?;
        let grad_exec = engine.load_by_params("nv_grad_panel_batch", &req)?;
        // nv_panel_batch inputs: (mu, sigma, keys);
        // nv_grad_panel_batch: (x, panel, kc, h, v)
        let mu_buf = panel_exec.upload(0, Arg::F32(&inst.mu))?;
        let sigma_buf = panel_exec.upload(1, Arg::F32(&inst.sigma))?;
        let kc_buf = grad_exec.upload(2, Arg::F32(&inst.k))?;
        let h_buf = grad_exec.upload(3, Arg::F32(&inst.h))?;
        let v_buf = grad_exec.upload(4, Arg::F32(&inst.v))?;
        Ok(XlaNvBatch {
            panel_exec,
            grad_exec,
            mu_buf,
            sigma_buf,
            kc_buf,
            h_buf,
            v_buf,
            panel: None,
            r: r_reps,
            d: inst.dim(),
            keys_flat: Vec::with_capacity(2 * r_reps),
            prof: Profiler::new(),
        })
    }

    fn ensure_panel(&mut self, keys: &[[u32; 2]]) -> Result<()> {
        if matches!(&self.panel, Some((k, _)) if k.as_slice() == keys) {
            return Ok(()); // same epoch keys ⇒ same panels (counter-based)
        }
        // One sampling dispatch per epoch; like XlaNv the panel round-trips
        // the host once and parks as a buffer for the M inner iterations.
        flatten_keys(keys, &mut self.keys_flat);
        let outs = self.panel_exec.call_b(&[
            BufArg::Dev(&self.mu_buf),
            BufArg::Dev(&self.sigma_buf),
            BufArg::Host(Arg::U32(&self.keys_flat)),
        ])?;
        let panel_host = exec::f32_vec(&outs[0])?;
        let buf = self.grad_exec.upload(1, Arg::F32(&panel_host))?;
        self.panel = Some((keys.to_vec(), buf));
        Ok(())
    }
}

impl NvBatchBackend for XlaNvBatch {
    fn name(&self) -> &'static str {
        "xla_batch"
    }

    fn batch_reps(&self) -> usize {
        self.r
    }

    fn grad_obj_batch(&mut self, x: &[f32], keys: &[[u32; 2]],
                      g: &mut [f32], objs: &mut [f64]) -> Result<()> {
        anyhow::ensure!(x.len() == self.r * self.d,
                        "iterate panel {} != {}×{}", x.len(), self.r, self.d);
        anyhow::ensure!(g.len() == x.len(), "gradient panel shape mismatch");
        anyhow::ensure!(keys.len() == self.r, "need one key per replication");
        anyhow::ensure!(objs.len() == self.r,
                        "need one objective slot per replication");
        let t_stage = Timer::start();
        self.ensure_panel(keys)?;
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let (_, panel) = self.panel.as_ref().unwrap();
        let t_exec = Timer::start();
        let outs = self.grad_exec.call_b(&[
            BufArg::Host(Arg::F32(x)),
            BufArg::Dev(panel),
            BufArg::Dev(&self.kc_buf),
            BufArg::Dev(&self.h_buf),
            BufArg::Dev(&self.v_buf),
        ])?;
        self.prof.add(Phase::Compute, t_exec.elapsed_s());
        let t_red = Timer::start();
        let g_out = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(g_out.len() == g.len(),
                        "nv_grad_panel_batch returned wrong panel shape");
        g.copy_from_slice(&g_out);
        let obj_out = exec::f32_vec(&outs[1])?;
        anyhow::ensure!(obj_out.len() == self.r,
                        "nv_grad_panel_batch returned {} objectives for {} \
                         replications", obj_out.len(), self.r);
        for (slot, o) in objs.iter_mut().zip(obj_out) {
            *slot = o as f64;
        }
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

/// Task 3 batched: `lr_grad_batch` / `lr_hvp_batch` gather every
/// replication's minibatch in-graph against the ONE device-resident copy of
/// the dataset — per iteration the host ships an `[R × n]` iterate panel
/// and `[R × b]` indices instead of R separate dispatches.  Algorithm-4
/// directions run through `lr_dir_batch` (or `lr_dir_twoloop_batch`): the
/// driver's dense padded `[R × mem × n]` correction panels go up with the
/// per-row valid counts, and ONE fused hbuild+happly dispatch returns all
/// R directions — the last per-replication dispatch of the batched spine,
/// closed (DESIGN.md §11).  Rebuilding H in-dispatch trades the
/// sequential arm's once-per-L resident-H amortization for a single
/// launch per step; per the paper's dispatch-dominance premise that is
/// the right trade on the batched path, and the n×n matrices now never
/// exist on the host at all.
pub struct XlaLrBatch {
    grad_exec: Rc<Exec>,
    hvp_exec: Rc<Exec>,
    dir_exec: Rc<Exec>,
    memory: usize,
    r: usize,
    n: usize,
    x_buf: DeviceBuf,
    z_buf: DeviceBuf,
    idx_i32: Vec<i32>,
    counts_i32: Vec<i32>,
    /// Per-phase attribution (see [`XlaMvBatch`]); the fused Algorithm-4
    /// dispatch books as direction.
    prof: Profiler,
}

impl XlaLrBatch {
    pub fn new(engine: &Engine, data: &ClassifyData, batch: usize,
               hbatch: usize, memory: usize, hessian_mode: HessianMode,
               r_reps: usize) -> Result<Self> {
        let n = data.n_features as i64;
        let rows = data.n_samples as i64;
        let r = r_reps as i64;
        let grad_exec = engine
            .load_by_params(
                "lr_grad_batch",
                &[("n", n), ("b", batch as i64), ("rows", rows), ("r", r)],
            )
            .context(
                "loading lr_grad_batch artifact (regenerate with \
                 `python -m compile.aot --reps R`)",
            )?;
        let hvp_exec = engine.load_by_params(
            "lr_hvp_batch",
            &[("n", n), ("bh", hbatch as i64), ("rows", rows), ("r", r)],
        )?;
        // ONE padded direction artifact per Hessian mode (batched
        // hbuild+happly, or the batched two-loop recursion)
        let dir_entry = match hessian_mode {
            HessianMode::Explicit => "lr_dir_batch",
            HessianMode::TwoLoop => "lr_dir_twoloop_batch",
        };
        let dir_exec = engine
            .load_by_params(
                dir_entry, &[("n", n), ("mem", memory as i64), ("r", r)])
            .with_context(|| format!(
                "loading {} artifact (regenerate with \
                 `python -m compile.aot --reps R`)", dir_entry))?;
        // lr_grad_batch inputs: (w, x_full, z_full, idx) — the dataset is
        // uploaded ONCE and shared by the grad and hvp dispatches
        let x_buf = grad_exec.upload(1, Arg::F32(&data.x))?;
        let z_buf = grad_exec.upload(2, Arg::F32(&data.z))?;
        Ok(XlaLrBatch {
            grad_exec,
            hvp_exec,
            dir_exec,
            memory,
            r: r_reps,
            n: data.n_features,
            x_buf,
            z_buf,
            idx_i32: Vec::new(),
            counts_i32: Vec::with_capacity(r_reps),
            prof: Profiler::new(),
        })
    }

    fn flatten_idx(&mut self, idx: &[Vec<usize>]) {
        self.idx_i32.clear();
        for rep in idx {
            self.idx_i32.extend(rep.iter().map(|&i| i as i32));
        }
    }
}

impl LrBatchBackend for XlaLrBatch {
    fn name(&self) -> &'static str {
        "xla_batch"
    }

    fn batch_reps(&self) -> usize {
        self.r
    }

    fn grad_batch(&mut self, w: &[f32], _data: &ClassifyData,
                  idx: &[Vec<usize>], g: &mut [f32], losses: &mut [f64])
        -> Result<()> {
        anyhow::ensure!(w.len() == self.r * self.n,
                        "iterate panel {} != {}×{}", w.len(), self.r, self.n);
        anyhow::ensure!(g.len() == w.len(), "gradient panel shape mismatch");
        anyhow::ensure!(idx.len() == self.r,
                        "need one index set per replication");
        anyhow::ensure!(losses.len() == self.r,
                        "need one loss slot per replication");
        let t_stage = Timer::start();
        self.flatten_idx(idx);
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let t_exec = Timer::start();
        let outs = self.grad_exec.call_b(&[
            BufArg::Host(Arg::F32(w)),
            BufArg::Dev(&self.x_buf),
            BufArg::Dev(&self.z_buf),
            BufArg::Host(Arg::I32(&self.idx_i32)),
        ])?;
        self.prof.add(Phase::Compute, t_exec.elapsed_s());
        let t_red = Timer::start();
        let g_out = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(g_out.len() == g.len(),
                        "lr_grad_batch returned wrong panel shape");
        g.copy_from_slice(&g_out);
        let loss_out = exec::f32_vec(&outs[1])?;
        anyhow::ensure!(loss_out.len() == self.r,
                        "lr_grad_batch returned {} losses for {} \
                         replications", loss_out.len(), self.r);
        for (slot, l) in losses.iter_mut().zip(loss_out) {
            *slot = l as f64;
        }
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn hvp_batch(&mut self, wbar: &[f32], s: &[f32], _data: &ClassifyData,
                 idx: &[Vec<usize>], y: &mut [f32]) -> Result<()> {
        anyhow::ensure!(wbar.len() == self.r * self.n
                            && s.len() == self.r * self.n,
                        "ω̄/s panel shape mismatch");
        anyhow::ensure!(y.len() == self.r * self.n,
                        "output panel shape mismatch");
        anyhow::ensure!(idx.len() == self.r,
                        "need one index set per replication");
        let t_stage = Timer::start();
        self.flatten_idx(idx);
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let t_exec = Timer::start();
        let outs = self.hvp_exec.call_b(&[
            BufArg::Host(Arg::F32(wbar)),
            BufArg::Host(Arg::F32(s)),
            BufArg::Dev(&self.x_buf),
            BufArg::Host(Arg::I32(&self.idx_i32)),
        ])?;
        self.prof.add(Phase::Compute, t_exec.elapsed_s());
        let t_red = Timer::start();
        let y_out = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(y_out.len() == y.len(),
                        "lr_hvp_batch returned wrong panel shape");
        y.copy_from_slice(&y_out);
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn direction_batch(&mut self, mem: BatchMemView<'_>, g: &[f32],
                       out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(mem.reps() == self.r && mem.dim() == self.n,
                        "correction panels are {}×{}, backend is {}×{}",
                        mem.reps(), mem.dim(), self.r, self.n);
        anyhow::ensure!(mem.capacity() == self.memory,
                        "correction capacity {} != artifact mem {}",
                        mem.capacity(), self.memory);
        anyhow::ensure!(g.len() == self.r * self.n
                            && out.len() == self.r * self.n,
                        "gradient/output panel shape mismatch");
        // ONE fused dispatch: the dense zero-padded panels go up as-is
        // (the artifact masks invalid slots by zeroing ρ, so rows with
        // empty or partial memories are handled in-graph — an empty row
        // reduces to the identity, d = g).
        let t_stage = Timer::start();
        self.counts_i32.clear();
        self.counts_i32
            .extend(mem.counts().iter().map(|&c| c as i32));
        self.prof.add(Phase::Dispatch, t_stage.elapsed_s());
        let t_exec = Timer::start();
        let outs = self.dir_exec.call(&[
            Arg::F32(mem.s_panel()),
            Arg::F32(mem.y_panel()),
            Arg::I32(&self.counts_i32),
            Arg::F32(g),
        ])?;
        self.prof.add(Phase::Direction, t_exec.elapsed_s());
        let t_red = Timer::start();
        let d = exec::f32_vec(&outs[0])?;
        anyhow::ensure!(d.len() == out.len(),
                        "direction artifact returned wrong panel shape");
        out.copy_from_slice(&d);
        self.prof.add(Phase::Reduce, t_red.elapsed_s());
        Ok(())
    }

    fn take_profile(&mut self) -> Option<Profiler> {
        Some(self.prof.take())
    }
}

// Cross-backend agreement tests live in rust/tests/integration_runtime.rs
// (they need compiled artifacts).
