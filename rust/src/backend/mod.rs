//! Execution backends — the paper's CPU-vs-GPU axis as traits.
//!
//! Each of the three tasks has a narrow backend interface covering exactly
//! the work the paper offloads to the accelerator; everything else
//! (LMO LPs, correction-memory bookkeeping, step sizes, batching) is
//! backend-independent and lives in the drivers under [`crate::opt`].
//!
//! Implementations:
//! * [`native`] — sequential scalar Rust (the paper's CPU arm); also hosts
//!   the thread-pooled variant for ablation A3.
//! * [`xla`] — AOT-compiled XLA artifacts through PJRT (the vectorized
//!   "GPU-style" arm).

pub mod native;
pub mod plane;
pub mod xla;

use anyhow::Result;

use crate::tasks::{BatchMemView, CorrectionMemory};
use crate::util::profile::Profiler;

/// Task 1: one full Algorithm-1 epoch (resample + `m_inner` FW steps).
///
/// `key` addresses the epoch's Monte-Carlo panel; the same key must
/// reproduce the same panel (counter-based RNG on both arms).
pub trait MvBackend {
    fn name(&self) -> &'static str;

    /// Returns the updated iterate and the end-of-epoch empirical objective.
    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)>;

    /// In-place variant: advance `w` where it lives and return only the
    /// objective.  The default routes through [`MvBackend::epoch`] (one
    /// owned iterate per call); allocation-free backends override it
    /// (DESIGN.md §16) — the batched native engine steps each panel row
    /// through this entry point.
    fn epoch_into(&mut self, w: &mut [f32], k_epoch: usize, key: [u32; 2])
        -> Result<f64> {
        let (next, obj) = self.epoch(w, k_epoch, key)?;
        w.copy_from_slice(&next);
        Ok(obj)
    }

    /// Drain the backend's per-phase attribution accumulated since the
    /// last drain (DESIGN.md §15).  `None` (the default) means the
    /// backend does not self-attribute — the driver books the whole
    /// timed wall as `compute`.
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}

/// Task 2: the Monte-Carlo gradient + objective estimate at `x`
/// (Algorithm 2 line 7).  The LP LMO stays in the driver.
pub trait NvBackend {
    fn name(&self) -> &'static str;

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)>;

    /// In-place variant: write the gradient into `g` and return the
    /// objective.  Default routes through [`NvBackend::grad_obj`];
    /// allocation-free backends override it (DESIGN.md §16).
    fn grad_obj_into(&mut self, x: &[f32], key: [u32; 2], g: &mut [f32])
        -> Result<f64> {
        let (grad, obj) = self.grad_obj(x, key)?;
        g.copy_from_slice(&grad);
        Ok(obj)
    }

    /// Drain the backend's per-phase attribution (see
    /// [`MvBackend::take_profile`]).
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}

/// Task 3: the SQN compute kernels (Algorithm 3).  The driver samples the
/// minibatch *indices* (shared across arms — CRN); each backend owns its
/// data path: the native arm gathers rows on the host, the XLA arm keeps
/// the full design matrix resident on the device and gathers in-graph.
pub trait LrBackend {
    fn name(&self) -> &'static str;

    /// Minibatch gradient (12) + mean loss at rows `idx` of `data`.
    fn grad(&mut self, w: &[f32], data: &crate::sim::ClassifyData,
            idx: &[usize]) -> Result<(Vec<f32>, f64)>;

    /// Sub-sampled Hessian-vector product (13) at rows `idx`.
    fn hvp(&mut self, wbar: &[f32], s: &[f32],
           data: &crate::sim::ClassifyData, idx: &[usize])
        -> Result<Vec<f32>>;

    /// H_t·g via Algorithm 4 over the correction memory.
    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>>;

    /// Drain the backend's per-phase attribution (see
    /// [`MvBackend::take_profile`]).
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}

/// Which Hessian application Algorithm 4 uses (ablation A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HessianMode {
    /// The paper's explicit (I−ρsyᵀ)H(I−ρysᵀ)+ρssᵀ matrix build, O(Mn²).
    Explicit,
    /// Two-loop recursion, O(Mn).
    TwoLoop,
}

impl HessianMode {
    /// CLI / wire-protocol name (the spec's canonical `hessian` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            HessianMode::Explicit => "explicit",
            HessianMode::TwoLoop => "twoloop",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "explicit" => Some(HessianMode::Explicit),
            "twoloop" | "two-loop" => Some(HessianMode::TwoLoop),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Replication-batched backends (DESIGN.md §11)
// ---------------------------------------------------------------------------
//
// The per-replication traits above advance ONE replication per call; an
// R-replication experiment therefore costs R dispatches per step — the
// many-small-launches pattern that wastes both the thread pool and the
// accelerator.  The batch traits below advance ALL replications of an
// experiment in one call on row-major `[R × n]` panels (row r belongs to
// replication r).  Contract shared by every implementation:
//
// * `keys[r]` / the index sets for row r are derived from the SAME
//   `StreamTree` subtree the sequential path uses, and each row's
//   arithmetic is the same operations in the same order as the
//   per-replication backend.  On the native arm this makes batched and
//   sequential runs bit-for-bit identical (enforced by
//   tests/batch_determinism.rs).  The XLA arm's vmap-lowered artifacts
//   were measured row-by-row against their per-replication originals in
//   jax (panels, gradients, losses, HVPs, objectives: all bitwise) —
//   but vmap can in principle reassociate reductions, so the batched
//   artifact set sticks to lowerings where that was verified; the padded
//   direction artifacts (`lr_dir_batch` / `lr_dir_twoloop_batch`) lower
//   through lax.map rather than vmap for exactly this reason (vmap
//   showed ~1-ulp drift on the Algorithm-4 recursion; DESIGN.md §11).
// * Implementations may parallelize across the replication axis
//   (replication-major data parallelism) or fuse it into one device
//   dispatch; neither may change per-row arithmetic.

/// Task 1, batched: one Algorithm-1 epoch for all R replications.
pub trait MvBatchBackend {
    fn name(&self) -> &'static str;

    /// Number of replications the backend was built for.
    fn batch_reps(&self) -> usize;

    /// Advance the `[R × d]` iterate panel `w` in place by one fused epoch;
    /// `keys[r]` addresses replication r's Monte-Carlo panel.  Writes the
    /// per-replication end-of-epoch empirical objectives into `objs`
    /// (length R) — an out-param so steady-state callers allocate nothing
    /// per epoch (DESIGN.md §16).
    fn epoch_batch(&mut self, w: &mut [f32], k_epoch: usize,
                   keys: &[[u32; 2]], objs: &mut [f64]) -> Result<()>;

    /// Drain the backend's per-phase attribution (see
    /// [`MvBackend::take_profile`]).
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}

/// Task 2, batched: the Monte-Carlo gradient + objective estimate for all R
/// replications at their own iterates.  The LP LMO stays in the driver (it
/// is host-side in both arms), advanced as one pool-parallel panel per
/// inner step (`NvLmo::solve_panel_into`, DESIGN.md §17).
pub trait NvBatchBackend {
    fn name(&self) -> &'static str;

    fn batch_reps(&self) -> usize;

    /// `x` and `g` are `[R × d]` row-major panels; `keys[r]` addresses
    /// replication r's epoch panel (same key ⇒ same panel, counter-based
    /// RNG).  Writes the per-replication objective estimates into `objs`
    /// (length R).
    fn grad_obj_batch(&mut self, x: &[f32], keys: &[[u32; 2]],
                      g: &mut [f32], objs: &mut [f64]) -> Result<()>;

    /// Drain the backend's per-phase attribution (see
    /// [`MvBackend::take_profile`]).
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}

/// Task 3, batched: the SQN compute kernels for all R replications.  The
/// driver owns per-replication minibatch indices, ω̄ averaging and
/// correction memories, exactly as in the sequential path.
pub trait LrBatchBackend {
    fn name(&self) -> &'static str;

    fn batch_reps(&self) -> usize;

    /// Minibatch gradient (12) + mean loss per replication: `w`/`g` are
    /// `[R × n]` panels, `idx[r]` is replication r's minibatch; the mean
    /// losses land in `losses` (length R).
    fn grad_batch(&mut self, w: &[f32], data: &crate::sim::ClassifyData,
                  idx: &[Vec<usize>], g: &mut [f32], losses: &mut [f64])
        -> Result<()>;

    /// Sub-sampled Hessian-vector product (13) per replication on
    /// `[R × n]` panels.
    fn hvp_batch(&mut self, wbar: &[f32], s: &[f32],
                 data: &crate::sim::ClassifyData, idx: &[Vec<usize>],
                 y: &mut [f32]) -> Result<()>;

    /// H_t·g (Algorithm 4) for ALL replications in one call, over a
    /// borrowed [`BatchMemView`] of the driver's dense padded
    /// `[R × mem × n]` correction panels — the last per-replication
    /// dispatch of the batched SQN spine, closed (DESIGN.md §11).  Taking
    /// a *view* rather than the owning
    /// [`BatchCorrectionMemory`](crate::tasks::BatchCorrectionMemory) is
    /// what lets the shard plane hand each shard its contiguous row
    /// window with zero copies (DESIGN.md §13).  Row r of `out` must be
    /// bit-identical to the ragged path's `direction(&mems[r], &g[r·n..])`;
    /// rows with `mem.count(r) == 0` need not be written (the driver takes
    /// the plain gradient step for them, as the sequential path does
    /// before the memory fills) but MAY be — an empty memory's H is the
    /// identity, so d = g bitwise either way.
    fn direction_batch(&mut self, mem: BatchMemView<'_>, g: &[f32],
                       out: &mut [f32]) -> Result<()>;

    /// Drain the backend's per-phase attribution (see
    /// [`MvBackend::take_profile`]).
    fn take_profile(&mut self) -> Option<Profiler> {
        None
    }
}
