//! Execution backends — the paper's CPU-vs-GPU axis as traits.
//!
//! Each of the three tasks has a narrow backend interface covering exactly
//! the work the paper offloads to the accelerator; everything else
//! (LMO LPs, correction-memory bookkeeping, step sizes, batching) is
//! backend-independent and lives in the drivers under [`crate::opt`].
//!
//! Implementations:
//! * [`native`] — sequential scalar Rust (the paper's CPU arm); also hosts
//!   the thread-pooled variant for ablation A3.
//! * [`xla`] — AOT-compiled XLA artifacts through PJRT (the vectorized
//!   "GPU-style" arm).

pub mod native;
pub mod xla;

use anyhow::Result;

use crate::tasks::CorrectionMemory;

/// Task 1: one full Algorithm-1 epoch (resample + `m_inner` FW steps).
///
/// `key` addresses the epoch's Monte-Carlo panel; the same key must
/// reproduce the same panel (counter-based RNG on both arms).
pub trait MvBackend {
    fn name(&self) -> &'static str;

    /// Returns the updated iterate and the end-of-epoch empirical objective.
    fn epoch(&mut self, w: &[f32], k_epoch: usize, key: [u32; 2])
        -> Result<(Vec<f32>, f64)>;
}

/// Task 2: the Monte-Carlo gradient + objective estimate at `x`
/// (Algorithm 2 line 7).  The LP LMO stays in the driver.
pub trait NvBackend {
    fn name(&self) -> &'static str;

    fn grad_obj(&mut self, x: &[f32], key: [u32; 2])
        -> Result<(Vec<f32>, f64)>;
}

/// Task 3: the SQN compute kernels (Algorithm 3).  The driver samples the
/// minibatch *indices* (shared across arms — CRN); each backend owns its
/// data path: the native arm gathers rows on the host, the XLA arm keeps
/// the full design matrix resident on the device and gathers in-graph.
pub trait LrBackend {
    fn name(&self) -> &'static str;

    /// Minibatch gradient (12) + mean loss at rows `idx` of `data`.
    fn grad(&mut self, w: &[f32], data: &crate::sim::ClassifyData,
            idx: &[usize]) -> Result<(Vec<f32>, f64)>;

    /// Sub-sampled Hessian-vector product (13) at rows `idx`.
    fn hvp(&mut self, wbar: &[f32], s: &[f32],
           data: &crate::sim::ClassifyData, idx: &[usize])
        -> Result<Vec<f32>>;

    /// H_t·g via Algorithm 4 over the correction memory.
    fn direction(&mut self, mem: &CorrectionMemory, g: &[f32])
        -> Result<Vec<f32>>;
}

/// Which Hessian application Algorithm 4 uses (ablation A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HessianMode {
    /// The paper's explicit (I−ρsyᵀ)H(I−ρysᵀ)+ρssᵀ matrix build, O(Mn²).
    Explicit,
    /// Two-loop recursion, O(Mn).
    TwoLoop,
}
