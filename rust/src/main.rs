//! `simopt` — launcher for the simulation-optimization runtime.
//!
//! Subcommands:
//!   run        one experiment cell (task × backend × size)
//!   sweep      Figure-2 protocol: size axis × backends, timing table
//!   accuracy   Table-2 protocol: RSE at checkpoints across backends
//!   serve      persistent experiment service on a unix socket (§14)
//!   submit     send a spec (or status/shutdown) to a running server
//!   artifacts  list AOT artifacts from the manifest
//!   hardware   print the execution-backend spec table (Table-1 analogue)

use std::sync::Arc;

use anyhow::{bail, Result};

use simopt::backend::HessianMode;
use simopt::config::{default_sizes, BackendKind, BudgetPolicy, ExecMode,
                     TaskKind};
use simopt::coordinator::{report, Coordinator, ExperimentSpec, RunResult,
                          SweepSpec};
use simopt::opt::{NullSink, TracingSink};
use simopt::service::{Client, Response, Server, ServerConfig,
                      PROTOCOL_VERSION};
use simopt::tasks::registry;
use simopt::util::cli::Args;
use simopt::util::log;
use simopt::util::trace::{now_us, Span, TraceId, Tracer};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            log::error("simopt", "fatal")
                .field("err", format!("{:#}", e))
                .emit();
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "sweep" => cmd_sweep(rest),
        "accuracy" => cmd_accuracy(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "artifacts" => cmd_artifacts(rest),
        "hardware" => cmd_hardware(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{}' — try `simopt help`", other),
    }
}

fn print_usage() {
    println!(
        "simopt — simulation optimization on an AOT-compiled XLA runtime\n\
         (reproduction of He et al. 2024, see DESIGN.md)\n\n\
         USAGE: simopt <command> [flags]\n\n\
         COMMANDS:\n\
         \x20 run        one experiment (--task --backend --size ...)\n\
         \x20 sweep      Figure-2 timing sweep (--task --sizes --backends)\n\
         \x20 accuracy   Table-2 RSE comparison (--task --size)\n\
         \x20 serve      persistent experiment service on a unix socket\n\
         \x20 submit     send a spec / status / shutdown to a server\n\
         \x20 artifacts  list compiled artifacts\n\
         \x20 hardware   backend spec table\n\n\
         TASKS (from the registry — every row works with every command):"
    );
    for task in registry::all() {
        println!(
            "  {:<14} {}  [aliases: {}]",
            task.name(),
            task.about(),
            task.aliases().join(", ")
        );
    }
    println!("\nRun any command with --help for its flags.");
}

/// Alias summary for `--task` errors/help, derived from the registry so an
/// unregistered task can never hide behind stale CLI text.
fn task_choices() -> &'static str {
    use std::sync::OnceLock;
    static CHOICES: OnceLock<String> = OnceLock::new();
    CHOICES.get_or_init(|| {
        registry::all()
            .map(|t| t.aliases().first().copied().unwrap_or_else(|| t.name()))
            .collect::<Vec<_>>()
            .join("|")
    })
}

fn parse_task(a: &Args) -> Result<TaskKind> {
    let t = a.get("task").unwrap_or_default();
    TaskKind::parse(&t).ok_or_else(|| {
        anyhow::anyhow!("--task must be {}, got '{}'", task_choices(), t)
    })
}

fn parse_backends(a: &Args) -> Result<Vec<BackendKind>> {
    a.get_str_list("backends")
        .iter()
        .map(|b| {
            BackendKind::parse(b)
                .ok_or_else(|| anyhow::anyhow!("bad backend '{}'", b))
        })
        .collect()
}

/// `--task` help line, leaked once so flag declarations stay `'static`.
fn task_help() -> &'static str {
    use std::sync::OnceLock;
    static TASK_HELP: OnceLock<String> = OnceLock::new();
    TASK_HELP
        .get_or_init(|| format!("task: {}", task_choices()))
        .as_str()
}

/// The `--log-level` gate every command takes (DESIGN.md §18); call
/// [`apply_log_level`] right after parsing so every later diagnostic
/// respects it.
fn log_flag(args: Args) -> Args {
    args.flag("log-level", Some("info"),
              "stderr log gate: error | warn | info | debug")
}

fn apply_log_level(a: &Args) -> Result<()> {
    let v = a.get("log-level").unwrap_or_default();
    let level = log::Level::parse(&v).ok_or_else(|| anyhow::anyhow!(
        "--log-level must be error|warn|info|debug, got '{}'", v))?;
    log::set_level(level);
    Ok(())
}

fn common_flags(args: Args) -> Args {
    log_flag(args).flag("task", Some("mv"), task_help())
        .flag("artifacts", Some("artifacts"), "artifact directory")
        .flag("results", Some("results"), "results directory")
        .flag("seed", Some("42"), "experiment seed")
        .flag("reps", Some("5"), "replications")
        .flag("epochs", None, "epochs (FW) / iterations (SQN)")
        .flag("hessian", Some("explicit"), "SQN Hessian: explicit | twoloop")
}

/// The `--exec` / `--shards` flags; the `--exec` default differs per
/// command (the Figure-2 / Table-2 protocols pin `seq` to keep the
/// paper's per-replication timing methodology — see SweepSpec::figure2).
fn exec_flag(args: Args, default: &'static str) -> Args {
    args.flag("exec", Some(default),
              "replication execution: auto | seq | batch (DESIGN.md §11)")
        .flag("shards", Some("1"),
              "shard count for --exec batch: split the R replication rows \
               into S contiguous shards, one inner batch backend each \
               (DESIGN.md §13)")
}

/// The adaptive-replication-budget flags (`run` and `submit`); the
/// policy is off unless `--budget` names a checkpoint interval.
fn budget_flags(args: Args) -> Args {
    args.flag("budget", None,
              "adaptive replication budget: freeze dominated replications \
               every N epochs (batched plans only; off by default)")
        .flag("budget-gap", Some("0.25"),
              "relative trace-gap above the incumbent that freezes a \
               replication at a checkpoint")
        .flag("budget-tol", Some("1e-6"),
              "relative per-checkpoint change below which survivors count \
               as converged (early stop when all do)")
}

fn budget_from_flags(a: &Args) -> Result<Option<BudgetPolicy>> {
    if a.get("budget").is_none() {
        return Ok(None);
    }
    Ok(Some(BudgetPolicy {
        check_every: a.get_usize("budget")?,
        gap: a.get_f64("budget-gap")?,
        tol: a.get_f64("budget-tol")?,
    }))
}

fn epochs_default(task: TaskKind, a: &Args) -> Result<usize> {
    match a.get("epochs") {
        Some(_) => Ok(a.get_usize("epochs")?),
        None => Ok(registry::get(task).default_epochs()),
    }
}

fn hessian_mode(a: &Args) -> Result<HessianMode> {
    let v = a.get("hessian").unwrap_or_default();
    HessianMode::parse(&v)
        .ok_or_else(|| anyhow::anyhow!("--hessian must be explicit|twoloop, \
                                        got '{}'", v))
}

fn exec_mode(a: &Args) -> Result<ExecMode> {
    let v = a.get("exec").unwrap_or_default();
    let mode = ExecMode::parse(&v)
        .ok_or_else(|| anyhow::anyhow!("--exec must be auto|seq|batch, got '{}'", v))?;
    let shards = a.get_usize("shards")?;
    match mode {
        // shards == 0 / shards > reps are rejected by spec validation
        ExecMode::Batched { .. } => Ok(ExecMode::Batched { shards }),
        _ if shards != 1 => bail!(
            "--shards selects the sharded batched plane — it requires \
             --exec batch (got --exec {})", v),
        _ => Ok(mode),
    }
}

/// Build one experiment spec from the shared `run`/`submit` flag set.
fn spec_from_flags(a: &Args) -> Result<ExperimentSpec> {
    let task = parse_task(a)?;
    let backend = BackendKind::parse(&a.get("backend").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let size = match a.get("size") {
        Some(_) => a.get_usize("size")?,
        None => default_sizes(task)[0],
    };
    let mut spec = ExperimentSpec::new(task, backend)
        .size(size)
        .epochs(epochs_default(task, a)?)
        .replications(a.get_usize("reps")?)
        .seed(a.get_u64("seed")?)
        .hessian(hessian_mode(a)?)
        .execution(exec_mode(a)?);
    if let Some(dir) = a.get("results-dir") {
        spec = spec.results_dir(&dir);
    }
    if let Some(budget) = budget_from_flags(a)? {
        spec = spec.budget(budget);
    }
    Ok(spec)
}

/// Persist the full result payload (`RunResult::to_json` — spec, plan,
/// the structured `"timing"` object with the per-phase attribution, and
/// the records) when `--out` was given.  For the same spec, `run` and
/// `submit` payloads are byte-identical except for the measured
/// `"timing"` object — the CI service smoke strips that one key before
/// diffing the two, and greps `per_phase` out of it (DESIGN.md §15).
fn write_out(a: &Args, result: &RunResult) -> Result<()> {
    if let Some(path) = a.get("out") {
        std::fs::write(&path, result.to_json().to_string_pretty())?;
        log::info("out", "wrote").field("path", path).emit();
    }
    Ok(())
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let a = budget_flags(exec_flag(
        common_flags(Args::new("run", "run one experiment cell")), "auto"))
        .flag("backend", Some("native"), "backend: native | native_par | xla")
        .flag("size", None, "problem dimension (default: task's smallest)")
        .flag("results-dir", None,
              "per-run report bundle directory (threaded through the spec \
               so concurrent runs don't collide; DESIGN.md §14)")
        .flag("out", None,
              "write the deterministic result payload (JSON) here")
        .flag("trace-out", None,
              "append this run's spans (a `run` parent + per-epoch \
               execution spans) here as Chrome-trace JSONL \
               (DESIGN.md §18)")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    apply_log_level(&a)?;
    let task = parse_task(&a)?;
    let spec = spec_from_flags(&a)?;
    let mut coord =
        Coordinator::new(&a.get("artifacts").unwrap(), &a.get("results").unwrap())?;
    let result = match a.get("trace-out") {
        Some(path) => {
            // same recording surface the server uses: a TracingSink over
            // the null observer, so the traced run is bitwise-identical
            // to an untraced one (tests/trace_invariance.rs)
            let tracer = Arc::new(Tracer::to_file(&path)?);
            let trace = TraceId::mint();
            let t0 = now_us();
            let mut base = NullSink;
            let mut sink =
                TracingSink::new(Arc::clone(&tracer), trace, &mut base);
            let result = coord.run_with(&spec, &mut sink)?;
            tracer.record(&Span::new(trace, "run", t0, now_us())
                .with("task", spec.label()));
            log::info("run", "trace_written")
                .field("path", &path)
                .field("trace", trace.as_hex())
                .emit();
            result
        }
        None => coord.run(&spec)?,
    };
    println!("{}", result.summary());
    write_out(&a, &result)?;
    let t = result.time_stats();
    let unit = if task == TaskKind::Classification { "iter" } else { "epoch" };
    if result.batched {
        // batch_wall/R shares carry no cross-replication spread; sharded
        // plans surface their shard count (DESIGN.md §13)
        println!(
            "per-{} time: {:.6}s mean, band2 = n/a (batched execution, \
             {} shard{}, DESIGN.md §11/§13)",
            unit,
            result.step_stats().mean(),
            result.shards,
            if result.shards == 1 { "" } else { "s" }
        );
    } else {
        println!(
            "per-{} time: {:.6}s mean, band2 = [{:.6}, {:.6}]",
            unit,
            result.step_stats().mean(),
            t.band2().0,
            t.band2().1
        );
    }
    if !result.profile.is_empty() {
        println!("per-phase attribution: {}",
                 result.profile.to_json().to_string_compact());
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> Result<()> {
    let a = exec_flag(common_flags(Args::new("sweep", "Figure-2 timing sweep")),
                      "seq")
        .flag("sizes", None, "comma list of sizes (default: task defaults)")
        .flag("backends", Some("native,xla"), "comma list of backends")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    apply_log_level(&a)?;
    let task = parse_task(&a)?;
    let mut sweep = SweepSpec::figure2(task);
    if a.get("sizes").is_some() {
        sweep.sizes = a.get_usize_list("sizes")?;
    }
    sweep.backends = parse_backends(&a)?;
    sweep.reps = a.get_usize("reps")?;
    sweep.epochs = epochs_default(task, &a)?;
    sweep.seed = a.get_u64("seed")?;
    sweep.exec = exec_mode(&a)?;

    let results_dir = a.get("results").unwrap();
    let mut coord = Coordinator::new(&a.get("artifacts").unwrap(), &results_dir)?;
    let results = coord.sweep(&sweep)?;
    let md = report::figure2_markdown(&results);
    println!("{}", md);
    report::write_report(&results_dir, &format!("sweep_{}", task), &results,
                         &report::DEFAULT_FRACS)?;
    println!("[report] written to {}/sweep_{}_*", results_dir, task);
    Ok(())
}

fn cmd_accuracy(rest: &[String]) -> Result<()> {
    let a = exec_flag(common_flags(Args::new("accuracy", "Table-2 RSE \
                                              comparison")),
                      "seq")
        .flag("size", None, "problem dimension (default: task's middle size)")
        .flag("backends", Some("native,xla"), "comma list of backends")
        .flag("fracs", Some("0.05,0.1,0.25,0.5,1.0"),
              "checkpoint fractions of the run")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    apply_log_level(&a)?;
    let task = parse_task(&a)?;
    let sizes = default_sizes(task);
    let size = match a.get("size") {
        Some(_) => a.get_usize("size")?,
        None => sizes[sizes.len() / 2],
    };
    let fracs: Vec<f64> = a
        .get("fracs")
        .unwrap()
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let backends = parse_backends(&a)?;
    let results_dir = a.get("results").unwrap();
    let mut coord = Coordinator::new(&a.get("artifacts").unwrap(), &results_dir)?;
    let mut results = Vec::new();
    for backend in backends {
        let spec = ExperimentSpec::new(task, backend)
            .size(size)
            .epochs(epochs_default(task, &a)?)
            .replications(a.get_usize("reps")?)
            .seed(a.get_u64("seed")?)
            .hessian(hessian_mode(&a)?)
            .execution(exec_mode(&a)?);
        log::info("accuracy", "run")
            .field("task", task)
            .field("backend", backend)
            .emit();
        results.push(coord.run(&spec)?);
    }
    println!("{}", report::table2_markdown(&results, &fracs));
    report::write_report(&results_dir, &format!("accuracy_{}", task), &results,
                         &fracs)?;
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let a = Args::new("serve", "persistent experiment service (DESIGN.md §14)")
        .flag("socket", Some("simopt.sock"), "unix socket path to listen on")
        .flag("artifacts", Some("artifacts"), "artifact directory")
        .flag("results", Some("results"),
              "default results directory (a spec's --results-dir overrides \
               per request)")
        .flag("workers", Some("1"),
              "executor threads, one warm coordinator each")
        .flag("queue", Some("16"),
              "admission queue capacity (a full queue answers `busy`)")
        .flag("cache", Some("256"),
              "result-cache bound in entries (FIFO eviction; 0 disables \
               caching)")
        .flag("trace-out", None,
              "append request spans (admission → cache check → queue wait \
               → per-epoch execution → relay) here as Chrome-trace JSONL \
               (DESIGN.md §18)")
        .flag("log-level", Some("info"),
              "stderr log gate: error | warn | info | debug")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    apply_log_level(&a)?;
    let cfg = ServerConfig {
        socket: a.get("socket").unwrap().into(),
        artifact_dir: a.get("artifacts").unwrap(),
        results_dir: a.get("results").unwrap(),
        workers: a.get_usize("workers")?,
        queue_capacity: a.get_usize("queue")?,
        cache_capacity: a.get_usize("cache")?,
        trace_out: a.get("trace-out").map(Into::into),
    };
    let server = Server::bind(cfg)?;
    let cfg = server.config();
    log::info("serve", "listening")
        .field("socket", cfg.socket.display())
        .field("workers", cfg.workers)
        .field("queue", cfg.queue_capacity)
        .field("artifacts", &cfg.artifact_dir)
        .emit();
    let stats = server.run()?;
    log::info("serve", "shutdown")
        .field("executed", stats.executed)
        .field("cache_hits", stats.cache_hits)
        .field("cache_entries", stats.cache_entries)
        .emit();
    Ok(())
}

fn cmd_submit(rest: &[String]) -> Result<()> {
    let a = budget_flags(exec_flag(
        Args::new("submit",
                  "submit a spec to a running `simopt serve` (DESIGN.md §14)")
            .flag("socket", Some("simopt.sock"), "server socket path")
            .flag("task", Some("mv"), task_help())
            .flag("backend", Some("native"),
                  "backend: native | native_par | xla")
            .flag("size", None, "problem dimension (default: task's smallest)")
            .flag("seed", Some("42"), "experiment seed")
            .flag("reps", Some("5"), "replications")
            .flag("epochs", None, "epochs (FW) / iterations (SQN)")
            .flag("hessian", Some("explicit"),
                  "SQN Hessian: explicit | twoloop")
            .flag("results-dir", None,
                  "server-side report bundle directory for this request")
            .flag("out", None,
                  "write the deterministic result payload (JSON) here")
            .switch("stream",
                    "stream per-epoch progress frames ahead of the result \
                     (protocol v2)")
            .switch("status", "query server counters instead of submitting")
            .switch("metrics",
                    "scrape the server's metrics registry (protocol v2) \
                     instead of submitting")
            .flag("metrics-format", Some("prom"),
                  "--metrics rendering: prom (Prometheus-style text) | \
                   json")
            .switch("shutdown", "request graceful server shutdown")
            .flag("log-level", Some("info"),
                  "stderr log gate: error | warn | info | debug"),
        "auto"))
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    apply_log_level(&a)?;
    let mut client = Client::connect(a.get("socket").unwrap())?;
    if a.get_bool("metrics") {
        let snap = client.metrics()?;
        match a.get("metrics-format").unwrap_or_default().as_str() {
            "json" => println!("{}", snap.to_json().to_string_pretty()),
            "prom" | "prometheus" => print!("{}", snap.to_prometheus()),
            other => bail!("--metrics-format must be prom|json, got '{}'",
                           other),
        }
        return Ok(());
    }
    if a.get_bool("status") {
        let st = client.status()?;
        println!(
            "[status] queue_depth={} capacity={} workers={} executed={} \
             cache_entries={} cache_hits={}",
            st.queue_depth, st.capacity, st.workers, st.executed,
            st.cache_entries, st.cache_hits
        );
        // the v2 structured stats object (DESIGN.md §15)
        for (i, w) in st.per_worker.iter().enumerate() {
            println!("[status] worker {}: executed={} cache_hits={}",
                     i, w.executed, w.cache_hits);
        }
        println!("[status] per_phase: {}",
                 st.per_phase.to_json().to_string_compact());
        return Ok(());
    }
    if a.get_bool("shutdown") {
        client.shutdown()?;
        println!("[submit] server acknowledged shutdown");
        return Ok(());
    }
    let spec = spec_from_flags(&a)?;
    // the session surface (protocol v2): queued → progress* → terminal
    let mut session = client.session(&spec, a.get_bool("stream"))?;
    let resp = loop {
        match session.next_event()? {
            Some(Response::Queued { id, position }) => {
                log::info("submit", "queued")
                    .field("id", id)
                    .field("position", position)
                    .emit()
            }
            Some(Response::Progress(p)) => {
                // `event=progress id=…` keeps the line greppable by the
                // same `progress id=` probe the CI smoke always used
                log::info("submit", "progress")
                    .field("id", p.id)
                    .field("epoch", format!("{}/{}", p.epoch, p.epochs))
                    .field("live", p.live)
                    .field("step_s", format!("{:.6}", p.step_s))
                    .emit()
            }
            Some(terminal) => break terminal,
            None => bail!("session ended without a terminal frame"),
        }
    };
    match resp {
        Response::Completed { id, cache_hit, result } => {
            println!("{}", result.summary());
            println!("[submit] result id={} cache_hit={} exec={} shards={}",
                     id, cache_hit,
                     if result.batched { "batched" } else { "sequential" },
                     result.shards);
            if !result.frozen.is_empty() {
                println!("[submit] budget froze {} replication(s){}",
                         result.frozen.len(),
                         match result.early_stop {
                             Some(e) => format!(", early stop at epoch {}",
                                                e),
                             None => String::new(),
                         });
            }
            write_out(&a, &result)?;
            Ok(())
        }
        Response::Busy { capacity } => bail!(
            "server busy: admission queue full (capacity {}) — retry later \
             or raise `simopt serve --queue`", capacity),
        Response::Error { message } => bail!("server error: {}", message),
        Response::UnsupportedVersion { max } => bail!(
            "server speaks protocol ≤ {}, this client sent v{} — upgrade \
             the server or downgrade the client", max, PROTOCOL_VERSION),
        other => bail!("unexpected server answer: {:?}", other),
    }
}

fn cmd_artifacts(rest: &[String]) -> Result<()> {
    let a = Args::new("artifacts", "list compiled artifacts")
        .flag("artifacts", Some("artifacts"), "artifact directory")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    let manifest =
        simopt::runtime::Manifest::load(a.get("artifacts").unwrap())?;
    println!("{:<32} {:<16} {:<14} params", "name", "entry", "task");
    for art in &manifest.artifacts {
        let params: Vec<String> =
            art.params.iter().map(|(k, v)| format!("{}={}", k, v)).collect();
        println!(
            "{:<32} {:<16} {:<14} {}",
            art.name, art.entry, art.task, params.join(" ")
        );
    }
    println!("{} artifacts in {}", manifest.artifacts.len(),
             manifest.dir.display());
    Ok(())
}

fn cmd_hardware(rest: &[String]) -> Result<()> {
    let a = Args::new("hardware", "backend spec table (Table-1 analogue)")
        .flag("artifacts", Some("artifacts"), "artifact directory")
        .parse(rest)
        .map_err(|e| anyhow::anyhow!("{}", e))?;
    println!("| | native (sequential) | xla (PJRT) |");
    println!("|---|---|---|");
    println!("| execution model | scalar loops, one sample at a time | \
              XLA-fused, vectorized, in-graph sampling |");
    println!(
        "| threads | 1 | {} (PJRT-internal) |",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    match simopt::runtime::Engine::new(a.get("artifacts").unwrap()) {
        Ok(engine) => println!("| platform | rustc host | {} |", engine.platform()),
        Err(_) => println!("| platform | rustc host | (artifacts not built) |"),
    }
    println!("\nPaper Table 1: Threadripper 3970X (108 GF FP32, 172.7 GB/s) \
              vs RTX 3090 (35.58 TF FP32, 936.2 GB/s); see DESIGN.md §2 for \
              the substitution argument.");
    Ok(())
}
