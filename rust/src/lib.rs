//! # simopt — simulation optimization on an AOT-compiled XLA runtime
//!
//! Production-shaped reproduction of *"A Preliminary Study on Accelerating
//! Simulation Optimization with GPU Implementation"* (He, Liu, Wu, Zheng,
//! Zhu; 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: experiment scheduling,
//!   replication fan-out, the Frank-Wolfe / stochastic-quasi-Newton drivers,
//!   the LP solver backing the newsvendor linear subproblem, metrics and
//!   report generation.  Python never runs here.
//! * **L2 (python/compile/model.py)** — the paper's compute graphs in JAX,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, validated against a pure-jnp oracle at build time.
//!
//! The paper's CPU-vs-GPU axis is reproduced as an execution-model axis
//! (see DESIGN.md §2): [`backend::native`] executes every algorithm with
//! sequential scalar loops (the paper's description of CPU execution), while
//! [`backend::xla`] dispatches the same algorithm to the vectorized,
//! XLA-fused artifacts through PJRT.
//!
//! On top of that axis sits the **batched replication engine**
//! (DESIGN.md §11): every experiment's R replications can advance through
//! one `*BatchBackend` call per step on `[R × n]` panels — replication-major
//! thread parallelism on the native arm, one fused artifact dispatch on the
//! XLA arm — bit-for-bit identical to the per-replication protocol under
//! the same seed.  The **shard-aware panel plane** ([`backend::plane`],
//! DESIGN.md §13) splits that spine further: `--shards S` partitions the
//! R rows into S contiguous shards, one inner batch backend each (scoped
//! pool workers on the native arm; one `[R/S × …]` artifact dispatch per
//! shard on the XLA arm, the seam a multi-device PJRT build maps onto) —
//! still bit-identical for every S.  [`config::ExecMode`] selects the
//! plan per experiment.
//!
//! The whole stack can also stay *resident*: the [`service`] layer
//! (`simopt serve` / `simopt submit`, DESIGN.md §14) keeps warm
//! coordinators behind a Unix-socket JSON-lines protocol with a bounded
//! admission queue and a content-addressed result cache, serving results
//! bit-identical to direct runs without re-paying startup per experiment.
//!
//! ## Quickstart
//!
//! ```no_run
//! use simopt::coordinator::{Coordinator, ExperimentSpec};
//! use simopt::config::{BackendKind, TaskKind};
//!
//! let spec = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
//!     .size(128)
//!     .epochs(20)
//!     .replications(3)
//!     .seed(7);
//! let mut coord = Coordinator::new("artifacts", "results").unwrap();
//! let result = coord.run(&spec).unwrap();
//! println!("{}", result.summary());
//! ```

pub mod backend;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod linalg;
pub mod lp;
pub mod opt;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod tasks;
pub mod util;

/// Convenience re-exports for the examples and benches.
pub mod prelude {
    pub use crate::backend::plane::{Panel, PanelMut, ShardMap, ShardedBatch};
    pub use crate::backend::{
        LrBackend, LrBatchBackend, MvBackend, MvBatchBackend, NvBackend,
        NvBatchBackend,
    };
    pub use crate::config::{BackendKind, ExecMode, TaskKind};
    pub use crate::coordinator::{Coordinator, ExperimentSpec, RunResult};
    pub use crate::rng::{Philox, StreamTree};
    pub use crate::service::{Client, Response, Server, ServerConfig};
    pub use crate::tasks::registry::{SimTask, TaskBackend};
}
