//! Paper-shaped report rendering: Figure-2 timing tables (size × backend,
//! mean ± 2σ, speedup column) and Table-2 RSE tables, as markdown + CSV.
//!
//! Nothing here pins a directory: [`write_report`] takes the destination
//! from the caller — the CLI's `--results`, a spec's `--results-dir`
//! (per-run isolation, DESIGN.md §14), or a test's temp dir — so
//! concurrent served requests and CI runs never collide in one shared
//! `results/` tree.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::Result;

use crate::config::BackendKind;
use crate::util::json::{arr, num, obj, s, Value};
use crate::util::profile::Phase;
use crate::util::timer::fmt_duration;

use super::metrics::RunResult;

/// Paper Table 2 reference rows (RSE %, ±2σ %), for side-by-side printing.
pub const PAPER_TABLE2: &[(&str, [(f64, f64); 4])] = &[
    // (column, [(rse, band) at iters 50, 100, 500, 1000])
    ("asset5k_gpu", [(85.07, 9.74), (62.41, 5.46), (24.07, 4.97), (13.39, 2.86)]),
    ("asset5k_cpu", [(83.19, 10.65), (63.71, 4.86), (25.62, 5.87), (12.93, 3.96)]),
    ("inv10k_gpu", [(89.92, 7.02), (76.25, 8.49), (40.94, 8.11), (20.58, 5.78)]),
    ("inv10k_cpu", [(88.73, 7.33), (72.93, 9.45), (38.52, 8.53), (23.67, 6.48)]),
    ("class1k_gpu", [(72.16, 8.44), (51.06, 5.92), (31.29, 4.07), (15.59, 4.00)]),
    ("class1k_cpu", [(76.25, 7.74), (53.46, 5.10), (29.67, 5.21), (16.77, 3.71)]),
];

/// Figure-2-shaped timing table: rows = sizes, columns = backends, plus a
/// speedup column (sequential-native / xla) — the paper's headline ratio.
pub fn figure2_markdown(results: &[RunResult]) -> String {
    // group by (size) → backend → result
    let mut by_size: BTreeMap<usize, BTreeMap<String, &RunResult>> = BTreeMap::new();
    let mut backends: Vec<String> = Vec::new();
    for r in results {
        let b = r.spec.backend.to_string();
        if !backends.contains(&b) {
            backends.push(b.clone());
        }
        by_size.entry(r.spec.size).or_default().insert(b, r);
    }
    let task = results
        .first()
        .map(|r| r.spec.task.to_string())
        .unwrap_or_default();
    let mut out = format!("### Figure 2 — {} computation time\n\n", task);
    out.push_str("| size |");
    for b in &backends {
        out.push_str(&format!(" {} (mean ±2σ) |", b));
    }
    out.push_str(" speedup native/xla |\n|---|");
    for _ in &backends {
        out.push_str("---|");
    }
    out.push_str("---|\n");
    for (size, row) in &by_size {
        out.push_str(&format!("| {} |", size));
        for b in &backends {
            match row.get(b) {
                Some(r) => {
                    let t = r.time_stats();
                    if r.batched {
                        // batched execution attributes batch_wall/R shares
                        // — a cross-replication timing band would be a
                        // fake ±0.00, not a measurement (DESIGN.md §11);
                        // sharded plans record their shard count too
                        // (DESIGN.md §13)
                        let plan = if r.shards > 1 {
                            format!("batched, {} shards", r.shards)
                        } else {
                            "batched".to_string()
                        };
                        out.push_str(&format!(
                            " {} ±n/a ({}) |",
                            fmt_duration(t.mean()),
                            plan
                        ));
                    } else {
                        out.push_str(&format!(
                            " {} ±{} |",
                            fmt_duration(t.mean()),
                            fmt_duration(2.0 * t.std())
                        ));
                    }
                }
                None => out.push_str(" – |"),
            }
        }
        let speed = match (
            row.get(&BackendKind::Native.to_string()),
            row.get(&BackendKind::Xla.to_string()),
        ) {
            (Some(n), Some(x)) => {
                let (nm, xm) = (n.time_stats().mean(), x.time_stats().mean());
                if xm > 0.0 {
                    format!("{:.2}×", nm / xm)
                } else {
                    "–".into()
                }
            }
            _ => "–".into(),
        };
        out.push_str(&format!(" {} |\n", speed));
    }
    // per-phase attribution rows (DESIGN.md §15) — only for results that
    // carry a profile, so hand-built or pre-profiler results render the
    // historical table unchanged
    let profiled: Vec<&RunResult> =
        results.iter().filter(|r| !r.profile.is_empty()).collect();
    if !profiled.is_empty() {
        out.push_str("\n#### Per-phase attribution (seconds, DESIGN.md \
                      §15)\n\n| backend | size |");
        for p in Phase::ALL {
            out.push_str(&format!(" {} |", p));
        }
        out.push_str("\n|---|---|");
        for _ in Phase::ALL {
            out.push_str("---|");
        }
        out.push('\n');
        for r in profiled {
            out.push_str(&format!("| {} | {} |", r.spec.backend,
                                  r.spec.size));
            for p in Phase::ALL {
                out.push_str(&format!(" {:.6} |", r.profile.get(p)));
            }
            out.push('\n');
        }
    }
    out
}

/// Table-2-shaped accuracy table: RSE ± 2σ at fractional checkpoints per
/// backend, with the paper's reference rows appended.
pub fn table2_markdown(results: &[RunResult], fracs: &[f64]) -> String {
    let task = results
        .first()
        .map(|r| r.spec.task.to_string())
        .unwrap_or_default();
    let mut out = format!("### Table 2 — {} RSE by iteration\n\n", task);
    out.push_str("| checkpoint (frac, iter) |");
    for r in results {
        out.push_str(&format!(" {} (d={}) |", r.spec.backend, r.spec.size));
    }
    out.push_str("\n|---|");
    for _ in results {
        out.push_str("---|");
    }
    out.push('\n');
    if let Some(first) = results.first() {
        let anchor = first.rse_checkpoints(fracs);
        for (row, &(frac, it, _, _)) in anchor.iter().enumerate() {
            out.push_str(&format!("| {:.1}% (it {}) |", frac * 100.0, it));
            for r in results {
                let cps = r.rse_checkpoints(fracs);
                match cps.get(row) {
                    Some(&(_, _, m, sd)) => out.push_str(&format!(
                        " {} |",
                        crate::util::stats::fmt_pm(m, sd)
                    )),
                    None => out.push_str(" – |"),
                }
            }
            out.push('\n');
        }
    }
    out.push_str(
        "\nPaper reference (Table 2, iters 50/100/500/1000 of 10000):\n\n",
    );
    out.push_str("| column | it 50 | it 100 | it 500 | it 1000 |\n");
    out.push_str("|---|---|---|---|---|\n");
    for (name, cells) in PAPER_TABLE2 {
        out.push_str(&format!("| {} |", name));
        for (m, band) in cells {
            out.push_str(&format!(" {:.2}% (±{:.2}%) |", m, band));
        }
        out.push('\n');
    }
    out
}

/// CSV with one row per (size, backend): timing + final objective stats.
/// `shards` records the resolved execution plan (1 = sequential or the
/// unsharded batched engine, DESIGN.md §13).
pub fn results_csv(results: &[RunResult]) -> String {
    let mut out = String::from(
        "task,backend,size,reps,shards,total_mean_s,total_std_s,\
         step_mean_s,final_obj_mean,final_obj_std",
    );
    // per-phase attribution columns ride at the END so historical column
    // indices stay stable for downstream consumers (DESIGN.md §15)
    for p in Phase::ALL {
        out.push_str(&format!(",phase_{}_s", p));
    }
    out.push('\n');
    for r in results {
        let t = r.time_stats();
        let st = r.step_stats();
        let fo = r.final_obj_stats();
        // batched rows carry batch_wall/R time shares: the cross-
        // replication timing spread is n/a, not 0 (DESIGN.md §11)
        let total_std = if r.batched {
            "n/a".to_string()
        } else {
            format!("{:.9}", t.std())
        };
        out.push_str(&format!(
            "{},{},{},{},{},{:.9},{},{:.9},{:.9},{:.9}",
            r.spec.task,
            r.spec.backend,
            r.spec.size,
            r.reps.len(),
            r.shards,
            t.mean(),
            total_std,
            st.mean(),
            fo.mean(),
            fo.std()
        ));
        for p in Phase::ALL {
            out.push_str(&format!(",{:.9}", r.profile.get(p)));
        }
        out.push('\n');
    }
    out
}

/// Full per-epoch convergence traces as CSV (for the Figure-2 RSE panels).
pub fn traces_csv(results: &[RunResult]) -> String {
    let mut out = String::from("task,backend,size,rep,iter,obj,rse_pct\n");
    for r in results {
        for (rep_i, rep) in r.reps.iter().enumerate() {
            let rse = rep.rse_trace();
            for (i, (&o, &e)) in rep.objs.iter().zip(&rse).enumerate() {
                let it = rep.obj_iters.get(i).copied().unwrap_or(i + 1);
                out.push_str(&format!(
                    "{},{},{},{},{},{:.9},{:.6}\n",
                    r.spec.task, r.spec.backend, r.spec.size, rep_i, it, o, e
                ));
            }
        }
    }
    out
}

/// JSON summary (machine-readable results index).
pub fn results_json(results: &[RunResult]) -> Value {
    arr(results
        .iter()
        .map(|r| {
            let t = r.time_stats();
            // null, not 0.0: batched timing has no cross-replication
            // spread to report (DESIGN.md §11)
            let total_std = if r.batched {
                Value::Null
            } else {
                num(t.std())
            };
            obj(vec![
                ("task", s(&r.spec.task.to_string())),
                ("backend", s(&r.spec.backend.to_string())),
                ("size", num(r.spec.size as f64)),
                ("reps", num(r.reps.len() as f64)),
                ("total_mean_s", num(t.mean())),
                ("total_std_s", total_std),
                ("batched", Value::Bool(r.batched)),
                ("shards", num(r.shards as f64)),
                ("final_obj", num(r.final_obj_stats().mean())),
                ("per_phase", r.profile.to_json()),
            ])
        })
        .collect())
}

/// Checkpoint fractions every default report bundle uses.
pub const DEFAULT_FRACS: [f64; 4] = [0.1, 0.25, 0.5, 1.0];

/// The bundle name one run's report persists under: the human-readable
/// label plus the spec's content hash — `label()` alone is only
/// task_backend_dsize, so two specs differing in seed/reps/exec sharing
/// one `--results-dir` would silently overwrite each other without the
/// hash.
pub fn run_report_name(result: &RunResult) -> String {
    format!("run_{}_{:016x}", result.spec.label(), result.spec.spec_hash())
}

/// Persist ONE run's report bundle under `dir` with the canonical
/// [`run_report_name`] naming — the single recipe shared by
/// `Coordinator::run` (executed runs with a `results_dir`) and the
/// experiment service's cache-hit delivery (DESIGN.md §14), so the two
/// paths can never diverge in naming or checkpoint fractions.
pub fn persist_run_report(dir: &str, result: &RunResult) -> Result<()> {
    write_report(dir, &run_report_name(result),
                 std::slice::from_ref(result), &DEFAULT_FRACS)
}

/// Persist the full report bundle under `dir`.
pub fn write_report(dir: impl AsRef<Path>, name: &str, results: &[RunResult],
                    fracs: &[f64]) -> Result<()> {
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    fs::write(dir.join(format!("{}_fig2.md", name)),
              figure2_markdown(results))?;
    fs::write(dir.join(format!("{}_table2.md", name)),
              table2_markdown(results, fracs))?;
    fs::write(dir.join(format!("{}_summary.csv", name)), results_csv(results))?;
    fs::write(dir.join(format!("{}_traces.csv", name)), traces_csv(results))?;
    fs::write(
        dir.join(format!("{}_summary.json", name)),
        results_json(results).to_string_pretty(),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HessianMode;
    use crate::config::{ExecMode, TaskKind, TaskParams};
    use crate::coordinator::{ExperimentSpec, RepRecord};

    fn fake_result(backend: BackendKind, size: usize, step: f64) -> RunResult {
        let spec = ExperimentSpec {
            task: TaskKind::MeanVariance,
            backend,
            size,
            reps: 2,
            seed: 1,
            hessian_mode: HessianMode::Explicit,
            track_every: 1,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(TaskKind::MeanVariance, size),
            budget: None,
            results_dir: None,
        };
        let rec = |sc: f64| RepRecord {
            total_s: step * sc * 4.0,
            objs: vec![4.0, 2.0, 1.5, 1.0],
            obj_iters: vec![1, 2, 3, 4],
            step_s: vec![step * sc; 4],
        };
        RunResult::new(spec, vec![rec(1.0), rec(1.1)])
    }

    fn sample_results() -> Vec<RunResult> {
        vec![
            fake_result(BackendKind::Native, 128, 0.4),
            fake_result(BackendKind::Xla, 128, 0.1),
            fake_result(BackendKind::Native, 512, 4.0),
            fake_result(BackendKind::Xla, 512, 0.5),
        ]
    }

    #[test]
    fn figure2_table_contains_speedups() {
        let md = figure2_markdown(&sample_results());
        assert!(md.contains("| 128 |"));
        assert!(md.contains("| 512 |"));
        assert!(md.contains("4.00×")); // 0.4/0.1
        assert!(md.contains("8.00×")); // 4.0/0.5
    }

    #[test]
    fn batched_rows_mark_timing_band_na() {
        // Batched execution attributes batch_wall/R to every replication —
        // the ±2σ band would be a misleading ±0.00, so every renderer must
        // mark it n/a instead (DESIGN.md §11).
        let batched = fake_result(BackendKind::Native, 128, 0.4)
            .executed(Some(1));
        let seq = fake_result(BackendKind::Xla, 128, 0.1);
        let results = vec![batched, seq];

        let md = figure2_markdown(&results);
        assert!(md.contains("±n/a (batched)"), "{}", md);
        assert!(md.contains("±"), "sequential rows keep their band");

        let csv = results_csv(&results);
        let batched_row = csv.lines().nth(1).unwrap();
        assert!(batched_row.split(',').nth(6).unwrap() == "n/a",
                "{}", batched_row);
        let seq_row = csv.lines().nth(2).unwrap();
        assert!(seq_row.split(',').nth(6).unwrap().parse::<f64>().is_ok(),
                "{}", seq_row);

        let json = results_json(&results).to_string_pretty();
        let back = crate::util::json::Value::parse(&json).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr[0].get("total_std_s"),
                   Some(&crate::util::json::Value::Null));
        assert_eq!(arr[0].get("batched"),
                   Some(&crate::util::json::Value::Bool(true)));
        assert!(arr[1].get("total_std_s").unwrap().as_f64().is_some());
    }

    #[test]
    fn sharded_rows_record_shard_count_with_band_still_na() {
        // A sharded plan (DESIGN.md §13) must surface its shard count in
        // every machine-readable renderer while the timing band stays n/a
        // — sharding changes dispatch granularity, not the attribution
        // methodology.
        let sharded = fake_result(BackendKind::Native, 128, 0.4)
            .executed(Some(3));
        let seq = fake_result(BackendKind::Xla, 128, 0.1);
        let results = vec![sharded, seq];

        let md = figure2_markdown(&results);
        assert!(md.contains("±n/a (batched, 3 shards)"), "{}", md);

        let csv = results_csv(&results);
        assert!(csv.lines().next().unwrap().contains(",shards,"));
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(row.split(',').nth(4).unwrap(), "3", "{}", row);
        assert_eq!(row.split(',').nth(6).unwrap(), "n/a", "{}", row);
        let seq_row = csv.lines().nth(2).unwrap();
        assert_eq!(seq_row.split(',').nth(4).unwrap(), "1", "{}", seq_row);

        let json = results_json(&results).to_string_pretty();
        let back = crate::util::json::Value::parse(&json).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr[0].get("shards").unwrap().as_f64(), Some(3.0));
        assert_eq!(arr[0].get("total_std_s"),
                   Some(&crate::util::json::Value::Null));
        assert_eq!(arr[1].get("shards").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn table2_includes_paper_reference() {
        let md = table2_markdown(&sample_results()[..2], &[0.25, 1.0]);
        assert!(md.contains("Paper reference"));
        assert!(md.contains("asset5k_gpu"));
        assert!(md.contains("85.07%"));
    }

    #[test]
    fn csv_has_row_per_result() {
        let csv = results_csv(&sample_results());
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.contains("mean_variance,native,128,2,"));
    }

    #[test]
    fn renderers_surface_per_phase_attribution() {
        // Per-phase totals (DESIGN.md §15) must reach every machine-
        // readable renderer: trailing CSV columns, a `per_phase` object in
        // the JSON summary, and an attribution table in the markdown —
        // while profile-less results keep the historical shapes.
        use crate::util::profile::{Phase, Profiler};
        let mut prof = Profiler::new();
        prof.add(Phase::Compute, 1.25);
        prof.add(Phase::Lmo, 0.5);
        let profiled = fake_result(BackendKind::Native, 128, 0.4)
            .with_profile(prof);
        let bare = fake_result(BackendKind::Xla, 128, 0.1);
        let results = vec![profiled, bare];

        let csv = results_csv(&results);
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with(
            ",phase_dispatch_s,phase_compute_s,phase_reduce_s,\
             phase_lmo_s,phase_direction_s,phase_freeze_check_s"),
            "{}", header);
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(row.split(',').nth(11).unwrap(), "1.250000000", "{}", row);
        assert_eq!(row.split(',').nth(13).unwrap(), "0.500000000", "{}", row);
        let bare_row = csv.lines().nth(2).unwrap();
        assert_eq!(bare_row.split(',').nth(11).unwrap(), "0.000000000");

        let json = results_json(&results).to_string_pretty();
        let back = crate::util::json::Value::parse(&json).unwrap();
        let arr = back.as_arr().unwrap();
        let pp = arr[0].get("per_phase").unwrap();
        assert_eq!(pp.get("compute").unwrap().as_f64(), Some(1.25));
        assert_eq!(pp.get("lmo").unwrap().as_f64(), Some(0.5));
        assert!(arr[1].get("per_phase").unwrap().as_obj().unwrap()
                      .is_empty());

        let md = figure2_markdown(&results);
        assert!(md.contains("Per-phase attribution"), "{}", md);
        assert!(md.contains("| compute |"), "{}", md);
        assert!(md.contains("1.250000"), "{}", md);
        // a profile-less batch keeps the historical figure untouched
        let plain = figure2_markdown(&[fake_result(BackendKind::Xla, 128,
                                                   0.1)]);
        assert!(!plain.contains("Per-phase"), "{}", plain);
    }

    #[test]
    fn traces_csv_covers_all_points() {
        let csv = traces_csv(&sample_results()[..1]);
        // header + 2 reps × 4 points
        assert_eq!(csv.lines().count(), 9);
    }

    #[test]
    fn json_roundtrips() {
        let v = results_json(&sample_results());
        let text = v.to_string_pretty();
        let back = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 4);
    }

    #[test]
    fn write_report_creates_files() {
        let dir = std::env::temp_dir().join("simopt_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_report(&dir, "t", &sample_results(), &[0.5, 1.0]).unwrap();
        for suffix in ["t_fig2.md", "t_table2.md", "t_summary.csv",
                       "t_traces.csv", "t_summary.json"] {
            assert!(dir.join(suffix).exists(), "{} missing", suffix);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
