//! Experiment specifications: one run (task × backend × size × reps) and
//! full sweeps (the Figure-2 protocol).

use anyhow::{ensure, Result};

use crate::backend::HessianMode;
use crate::config::{BackendKind, ExecMode, TaskKind, TaskParams};

/// One experiment cell.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub task: TaskKind,
    pub backend: BackendKind,
    pub size: usize,
    pub reps: usize,
    pub seed: u64,
    pub hessian_mode: HessianMode,
    /// SQN loss-tracking cadence (iterations).
    pub track_every: usize,
    /// How the replication axis executes (DESIGN.md §11).
    pub exec: ExecMode,
    pub params: TaskParams,
}

impl ExperimentSpec {
    pub fn new(task: TaskKind, backend: BackendKind) -> Self {
        let size = crate::config::default_sizes(task)[0];
        ExperimentSpec {
            task,
            backend,
            size,
            reps: 5,
            seed: 42,
            hessian_mode: HessianMode::Explicit,
            track_every: 10,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(task, size),
        }
    }

    pub fn size(mut self, size: usize) -> Self {
        self.size = size;
        self.params.size = size;
        self
    }

    /// Epochs (FW) / iterations (SQN).
    pub fn epochs(mut self, iters: usize) -> Self {
        self.params.iters = iters;
        self
    }

    pub fn replications(mut self, reps: usize) -> Self {
        self.reps = reps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn samples(mut self, samples: usize) -> Self {
        self.params.samples = samples;
        self
    }

    pub fn hessian(mut self, mode: HessianMode) -> Self {
        self.hessian_mode = mode;
        self
    }

    /// Select sequential vs replication-batched execution.
    pub fn execution(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Batched execution through the shard plane with `shards` shards
    /// (DESIGN.md §13).
    pub fn sharded(mut self, shards: usize) -> Self {
        self.exec = ExecMode::Batched { shards };
        self
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.size > 0, "size must be positive");
        ensure!(self.reps > 0, "reps must be positive");
        ensure!(self.params.iters > 0, "iters must be positive");
        // degenerate shard plans fail HERE with an actionable message, not
        // downstream in the panel loop (DESIGN.md §13)
        if let ExecMode::Batched { shards } = self.exec {
            ensure!(shards > 0, "shards must be positive (got 0)");
            ensure!(shards <= self.reps,
                    "shards ({}) must not exceed replications ({}) — every \
                     shard needs at least one replication row",
                    shards, self.reps);
            // the XLA arm dispatches one fixed-shape [R/S × …] artifact
            // per shard, so an uneven split would need artifacts at TWO
            // shard sizes — which `python -m compile.aot --shards` refuses
            // to emit; fail here instead of at artifact-load time with an
            // unsatisfiable regenerate hint (the native arm keeps uneven
            // splits: its rows are plain host buffers)
            if self.backend == BackendKind::Xla && shards > 1 {
                ensure!(self.reps % shards == 0,
                        "--backend xla needs --shards ({}) to divide reps \
                         ({}): each shard dispatches one fixed-shape \
                         [R/S × …] artifact (emit them with `python -m \
                         compile.aot --reps {} --shards {}`)",
                        shards, self.reps, self.reps, shards);
            }
        }
        // task-specific parameter checks live on the registry entry
        crate::tasks::registry::get(self.task).validate(self)
    }

    /// Label used in reports and CSV files.
    pub fn label(&self) -> String {
        format!("{}_{}_d{}", self.task, self.backend, self.size)
    }
}

/// The Figure-2 protocol: one task, a size axis, a set of backends.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub task: TaskKind,
    pub sizes: Vec<usize>,
    pub backends: Vec<BackendKind>,
    pub reps: usize,
    pub epochs: usize,
    pub seed: u64,
    /// Execution mode applied to every cell (DESIGN.md §11).
    pub exec: ExecMode,
}

impl SweepSpec {
    pub fn figure2(task: TaskKind) -> Self {
        SweepSpec {
            task,
            sizes: crate::config::default_sizes(task),
            backends: vec![BackendKind::Native, BackendKind::Xla],
            reps: 5,
            epochs: crate::tasks::registry::get(task).default_epochs(),
            seed: 42,
            // The paper's protocol times each replication's own sequential
            // run (mean ± 2σ across replications).  Batched execution
            // reports batch_wall/R shares with zero cross-replication
            // variance, which is a different methodology — so the Figure-2
            // protocol pins sequential; batch timing has its own bench
            // (batch_sweep) and CLI switch (--exec batch).
            exec: ExecMode::Sequential,
        }
    }

    pub fn spec_for(&self, size: usize, backend: BackendKind) -> ExperimentSpec {
        ExperimentSpec::new(self.task, backend)
            .size(size)
            .epochs(self.epochs)
            .replications(self.reps)
            .seed(self.seed)
            .execution(self.exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let s = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla)
            .size(512)
            .epochs(7)
            .replications(3)
            .seed(9)
            .samples(16)
            .execution(ExecMode::Batched { shards: 1 });
        assert_eq!(s.size, 512);
        assert_eq!(s.params.size, 512);
        assert_eq!(s.params.iters, 7);
        assert_eq!(s.reps, 3);
        assert_eq!(s.seed, 9);
        assert_eq!(s.params.samples, 16);
        assert_eq!(s.exec, ExecMode::Batched { shards: 1 });
        s.validate().unwrap();
        let s = s.sharded(3);
        assert_eq!(s.exec, ExecMode::Batched { shards: 3 });
        s.validate().unwrap();
    }

    #[test]
    fn default_exec_modes() {
        // single experiments default to Auto…
        let s = ExperimentSpec::new(TaskKind::Newsvendor, BackendKind::Native);
        assert_eq!(s.exec, ExecMode::Auto);
        // …but the paper's Figure-2 protocol pins the sequential
        // per-replication timing methodology (see figure2()).
        let sw = SweepSpec::figure2(TaskKind::Newsvendor);
        assert_eq!(sw.spec_for(64, BackendKind::Native).exec,
                   ExecMode::Sequential);
    }

    #[test]
    fn validation_catches_zeroes() {
        let mut s = ExperimentSpec::new(TaskKind::Newsvendor, BackendKind::Native);
        s.reps = 0;
        assert!(s.validate().is_err());
        let mut s = ExperimentSpec::new(TaskKind::Classification, BackendKind::Native);
        s.params.batch = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validation_catches_degenerate_shard_plans() {
        let base = ExperimentSpec::new(TaskKind::MeanVariance,
                                       BackendKind::Native)
            .replications(4);
        assert!(base.clone().sharded(0).validate().is_err(),
                "shards == 0 must be rejected at validate time");
        let err = base.clone().sharded(5).validate().unwrap_err();
        assert!(format!("{:#}", err).contains("must not exceed"),
                "{:#}", err);
        // every legal shard count passes, including S = R and uneven
        for s in 1..=4 {
            base.clone().sharded(s).validate().unwrap();
        }
        // shard counts are a batched-plan property: seq/auto never carry
        // one, so reps alone bounds nothing there
        base.clone()
            .execution(ExecMode::Sequential)
            .replications(1)
            .validate()
            .unwrap();
    }

    #[test]
    fn xla_shard_plans_must_divide_reps() {
        // The XLA arm dispatches fixed-shape [R/S × …] artifacts, and
        // aot.py only emits equal shard sizes — an uneven split must die
        // in validate with the regenerate recipe, not at artifact load.
        let base = ExperimentSpec::new(TaskKind::MeanVariance,
                                       BackendKind::Xla)
            .replications(5);
        let err = base.clone().sharded(2).validate().unwrap_err();
        assert!(format!("{:#}", err).contains("--shards"), "{:#}", err);
        base.clone().sharded(5).validate().unwrap();
        base.clone().sharded(1).validate().unwrap();
        // the native arm keeps uneven splits
        ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
            .replications(5)
            .sharded(2)
            .validate()
            .unwrap();
    }

    #[test]
    fn sweep_expands_grid() {
        let sw = SweepSpec::figure2(TaskKind::MeanVariance);
        assert_eq!(sw.sizes.len(), 3);
        assert_eq!(sw.backends.len(), 2);
        let spec = sw.spec_for(128, BackendKind::Native);
        assert_eq!(spec.size, 128);
        assert_eq!(spec.reps, sw.reps);
    }

    #[test]
    fn label_shape() {
        let s = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla);
        assert_eq!(s.label(), format!("mean_variance_xla_d{}", s.size));
    }
}
