//! Experiment specifications: one run (task × backend × size × reps) and
//! full sweeps (the Figure-2 protocol).
//!
//! Since the experiment service (DESIGN.md §14) specs are also a *wire
//! type*: [`ExperimentSpec::to_json`] / [`ExperimentSpec::from_json`] are
//! the canonical encoding `simopt submit` ships over the socket, and
//! [`ExperimentSpec::spec_hash`] over that canonical form is the service
//! cache key.  parse∘render is identity (enforced by a property test in
//! `tests/prop_invariants.rs` across every registered task, exec mode,
//! and shard count), so equal specs hash equal however they were built.

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::backend::HessianMode;
use crate::config::{BackendKind, BudgetPolicy, ExecMode, TaskKind,
                    TaskParams};
use crate::util::json::{num, obj, s, Value};

/// One experiment cell.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub task: TaskKind,
    pub backend: BackendKind,
    pub size: usize,
    pub reps: usize,
    pub seed: u64,
    pub hessian_mode: HessianMode,
    /// SQN loss-tracking cadence (iterations).
    pub track_every: usize,
    /// How the replication axis executes (DESIGN.md §11).
    pub exec: ExecMode,
    pub params: TaskParams,
    /// Opt-in adaptive replication budget (DESIGN.md §14).  `None` — the
    /// default — runs every replication for every epoch and keeps the
    /// bitwise seq==batch contract.  Unlike `results_dir`, a budget
    /// changes what is *computed*, so it participates in the canonical
    /// encoding and the cache key whenever present (and is simply absent
    /// from the wire form when off, keeping legacy encodings and hashes
    /// byte-identical).
    pub budget: Option<BudgetPolicy>,
    /// Where this run's report bundle persists (`None` = don't persist).
    /// Threaded through the spec so concurrent served requests and CI runs
    /// isolate their outputs instead of colliding in one `results/`
    /// directory — a *delivery* detail, deliberately excluded from
    /// [`ExperimentSpec::spec_hash`] (DESIGN.md §14).
    pub results_dir: Option<String>,
}

impl ExperimentSpec {
    pub fn new(task: TaskKind, backend: BackendKind) -> Self {
        let size = crate::config::default_sizes(task)[0];
        ExperimentSpec {
            task,
            backend,
            size,
            reps: 5,
            seed: 42,
            hessian_mode: HessianMode::Explicit,
            track_every: 10,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(task, size),
            budget: None,
            results_dir: None,
        }
    }

    pub fn size(mut self, size: usize) -> Self {
        self.size = size;
        self.params.size = size;
        self
    }

    /// Epochs (FW) / iterations (SQN).
    pub fn epochs(mut self, iters: usize) -> Self {
        self.params.iters = iters;
        self
    }

    pub fn replications(mut self, reps: usize) -> Self {
        self.reps = reps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn samples(mut self, samples: usize) -> Self {
        self.params.samples = samples;
        self
    }

    pub fn hessian(mut self, mode: HessianMode) -> Self {
        self.hessian_mode = mode;
        self
    }

    /// Select sequential vs replication-batched execution.
    pub fn execution(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Batched execution through the shard plane with `shards` shards
    /// (DESIGN.md §13).
    pub fn sharded(mut self, shards: usize) -> Self {
        self.exec = ExecMode::Batched { shards };
        self
    }

    /// Persist this run's report bundle under `dir` (DESIGN.md §14).
    pub fn results_dir(mut self, dir: &str) -> Self {
        self.results_dir = Some(dir.to_string());
        self
    }

    /// Attach an adaptive replication budget (requires a batched plan —
    /// the trace-gap rule reads the shared replication panel).
    pub fn budget(mut self, budget: BudgetPolicy) -> Self {
        self.budget = Some(budget);
        self
    }

    // -- canonical wire encoding (DESIGN.md §14) ----------------------------

    /// The canonical JSON encoding `simopt submit` ships over the wire.
    /// Key set and order are fixed; `seed` is a decimal *string* because
    /// the JSON layer holds numbers as `f64` and u64 seeds above 2^53
    /// would silently lose bits.  The `budget` key is emitted only when a
    /// policy is attached, so default-off specs encode (and hash) exactly
    /// as they did before budgets existed.
    pub fn to_json(&self) -> Value {
        let p = &self.params;
        let mut kv = vec![
            ("task", s(self.task.as_str())),
            ("backend", s(self.backend.as_str())),
            ("size", num(self.size as f64)),
            ("reps", num(self.reps as f64)),
            ("seed", s(&self.seed.to_string())),
            ("hessian", s(self.hessian_mode.as_str())),
            ("track_every", num(self.track_every as f64)),
            ("exec", s(self.exec.as_str())),
            ("shards", num(self.exec.shards() as f64)),
            ("params", obj(vec![
                ("size", num(p.size as f64)),
                ("samples", num(p.samples as f64)),
                ("m_inner", num(p.m_inner as f64)),
                ("iters", num(p.iters as f64)),
                ("batch", num(p.batch as f64)),
                ("hbatch", num(p.hbatch as f64)),
                ("memory", num(p.memory as f64)),
                ("l_every", num(p.l_every as f64)),
                ("beta", num(p.beta as f64)),
                ("resources", num(p.resources as f64)),
                ("tightness", num(p.tightness as f64)),
            ])),
        ];
        if let Some(b) = &self.budget {
            kv.push(("budget", obj(vec![
                ("check_every", num(b.check_every as f64)),
                ("gap", num(b.gap)),
                ("tol", num(b.tol)),
            ])));
        }
        kv.push(("results_dir", match &self.results_dir {
            Some(d) => s(d),
            None => Value::Null,
        }));
        obj(kv)
    }

    /// Parse the wire encoding back.  Strict: every computation key is
    /// required (`results_dir` — a delivery detail — may be absent or
    /// `null`, so canonical encodings parse too), unknown keys are
    /// rejected so a client typo becomes a typed error frame instead of a
    /// silently defaulted field, and a `shards` count on a non-batched
    /// mode is a contradiction (`ExecMode::from_parts`).  Shape/type
    /// errors only — semantic validation stays in
    /// [`ExperimentSpec::validate`] so the service can answer it with its
    /// own error frame.
    pub fn from_json(v: &Value) -> Result<ExperimentSpec> {
        const KEYS: [&str; 10] =
            ["task", "backend", "size", "reps", "seed", "hessian",
             "track_every", "exec", "shards", "params"];
        const PARAM_KEYS: [&str; 11] =
            ["size", "samples", "m_inner", "iters", "batch", "hbatch",
             "memory", "l_every", "beta", "resources", "tightness"];
        let top = v.as_obj().context("spec must be a JSON object")?;
        for (k, _) in top {
            ensure!(KEYS.contains(&k.as_str()) || k == "results_dir"
                        || k == "budget",
                    "unknown spec key '{}'", k);
        }
        for key in KEYS {
            ensure!(v.get(key).is_some(), "spec is missing key '{}'", key);
        }
        let pv = v.get("params").unwrap();
        let pobj = pv.as_obj().context("spec 'params' must be an object")?;
        for (k, _) in pobj {
            ensure!(PARAM_KEYS.contains(&k.as_str()),
                    "unknown params key '{}'", k);
        }
        for key in PARAM_KEYS {
            ensure!(pv.get(key).is_some(), "params is missing key '{}'", key);
        }

        let task_s = wire_str(v, "task")?;
        let task = TaskKind::parse(task_s)
            .ok_or_else(|| anyhow!("unknown task '{}'", task_s))?;
        let backend_s = wire_str(v, "backend")?;
        let backend = BackendKind::parse(backend_s)
            .ok_or_else(|| anyhow!("unknown backend '{}'", backend_s))?;
        let hessian_s = wire_str(v, "hessian")?;
        let hessian_mode = HessianMode::parse(hessian_s)
            .ok_or_else(|| anyhow!("unknown hessian mode '{}'", hessian_s))?;
        let exec_s = wire_str(v, "exec")?;
        let shards = wire_usize(v, "shards")?;
        let exec = ExecMode::from_parts(exec_s, shards).ok_or_else(|| {
            anyhow!("invalid execution plan '{}' with shards={}", exec_s,
                    shards)
        })?;
        let seed_s = wire_str(v, "seed")?;
        let seed: u64 = seed_s.parse().map_err(|_| {
            anyhow!("spec 'seed' must be a decimal u64 string, got '{}'",
                    seed_s)
        })?;
        let size = wire_usize(v, "size")?;
        let params = TaskParams {
            size: wire_usize(pv, "size")?,
            samples: wire_usize(pv, "samples")?,
            m_inner: wire_usize(pv, "m_inner")?,
            iters: wire_usize(pv, "iters")?,
            batch: wire_usize(pv, "batch")?,
            hbatch: wire_usize(pv, "hbatch")?,
            memory: wire_usize(pv, "memory")?,
            l_every: wire_usize(pv, "l_every")?,
            beta: wire_f64(pv, "beta")? as f32,
            resources: wire_usize(pv, "resources")?,
            tightness: wire_f64(pv, "tightness")? as f32,
        };
        ensure!(params.size == size,
                "spec 'size' ({}) and 'params.size' ({}) disagree", size,
                params.size);
        let results_dir = match v.get("results_dir") {
            None | Some(Value::Null) => None,
            Some(Value::Str(d)) => Some(d.clone()),
            Some(_) => bail!("spec 'results_dir' must be a string or null"),
        };
        // budget is wire-optional: absent (or null) means off, matching
        // pre-budget encodings byte for byte
        let budget = match v.get("budget") {
            None | Some(Value::Null) => None,
            Some(bv) => {
                let bobj =
                    bv.as_obj().context("spec 'budget' must be an object")?;
                for (k, _) in bobj {
                    ensure!(matches!(k.as_str(),
                                     "check_every" | "gap" | "tol"),
                            "unknown budget key '{}'", k);
                }
                Some(BudgetPolicy {
                    check_every: wire_usize(bv, "check_every")?,
                    gap: wire_f64(bv, "gap")?,
                    tol: wire_f64(bv, "tol")?,
                })
            }
        };
        Ok(ExperimentSpec {
            task,
            backend,
            size,
            reps: wire_usize(v, "reps")?,
            seed,
            hessian_mode,
            track_every: wire_usize(v, "track_every")?,
            exec,
            params,
            budget,
            results_dir,
        })
    }

    /// The content the service cache addresses (DESIGN.md §14): the wire
    /// encoding minus `results_dir` — where a result is *delivered* never
    /// changes what is *computed*, so two submissions differing only in
    /// their results directory share one cache entry.
    pub fn canonical_json(&self) -> Value {
        match self.to_json() {
            Value::Obj(kv) => Value::Obj(
                kv.into_iter().filter(|(k, _)| k != "results_dir").collect()),
            _ => unreachable!("to_json always renders an object"),
        }
    }

    /// Stable content hash of [`ExperimentSpec::canonical_json`] (64-bit
    /// FNV-1a over the compact rendering) — the service cache key.  The
    /// cache stores the canonical string next to each entry and verifies
    /// it on lookup, so a hash collision degrades to a cache miss, never
    /// to a wrong result.
    pub fn spec_hash(&self) -> u64 {
        let text = self.canonical_json().to_string_compact();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.size > 0, "size must be positive");
        ensure!(self.reps > 0, "reps must be positive");
        ensure!(self.params.iters > 0, "iters must be positive");
        // degenerate shard plans fail HERE with an actionable message, not
        // downstream in the panel loop (DESIGN.md §13)
        if let ExecMode::Batched { shards } = self.exec {
            ensure!(shards > 0, "shards must be positive (got 0)");
            ensure!(shards <= self.reps,
                    "shards ({}) must not exceed replications ({}) — every \
                     shard needs at least one replication row",
                    shards, self.reps);
            // the XLA arm dispatches one fixed-shape [R/S × …] artifact
            // per shard, so an uneven split would need artifacts at TWO
            // shard sizes — which `python -m compile.aot --shards` refuses
            // to emit; fail here instead of at artifact-load time with an
            // unsatisfiable regenerate hint (the native arm keeps uneven
            // splits: its rows are plain host buffers)
            if self.backend == BackendKind::Xla && shards > 1 {
                ensure!(self.reps % shards == 0,
                        "--backend xla needs --shards ({}) to divide reps \
                         ({}): each shard dispatches one fixed-shape \
                         [R/S × …] artifact (emit them with `python -m \
                         compile.aot --reps {} --shards {}`)",
                        shards, self.reps, self.reps, shards);
            }
        }
        // the budget's trace-gap rule reads the shared replication panel,
        // so it only exists on the batched plan
        if let Some(b) = &self.budget {
            ensure!(b.check_every > 0,
                    "budget check_every must be positive");
            ensure!(b.gap.is_finite() && b.gap >= 0.0,
                    "budget gap must be finite and non-negative");
            ensure!(b.tol.is_finite() && b.tol >= 0.0,
                    "budget tol must be finite and non-negative");
            ensure!(matches!(self.exec, ExecMode::Batched { .. }),
                    "an adaptive replication budget needs the batched \
                     plan (--exec batch): the trace-gap rule reads the \
                     shared replication panel");
        }
        // task-specific parameter checks live on the registry entry
        crate::tasks::registry::get(self.task).validate(self)
    }

    /// Label used in reports and CSV files.
    pub fn label(&self) -> String {
        format!("{}_{}_d{}", self.task, self.backend, self.size)
    }
}

// -- typed wire-field accessors (shape errors with the offending key) -------

fn wire_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("spec '{}' must be a string", key))
}

fn wire_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("spec '{}' must be a number", key))
}

fn wire_usize(v: &Value, key: &str) -> Result<usize> {
    let n = v.get(key)
        .and_then(Value::as_uint)
        .ok_or_else(|| anyhow!("spec '{}' must be a non-negative integer",
                               key))?;
    ensure!(n <= u32::MAX as u64, "spec '{}' is out of range ({})", key, n);
    Ok(n as usize)
}

/// The Figure-2 protocol: one task, a size axis, a set of backends.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub task: TaskKind,
    pub sizes: Vec<usize>,
    pub backends: Vec<BackendKind>,
    pub reps: usize,
    pub epochs: usize,
    pub seed: u64,
    /// Execution mode applied to every cell (DESIGN.md §11).
    pub exec: ExecMode,
}

impl SweepSpec {
    pub fn figure2(task: TaskKind) -> Self {
        SweepSpec {
            task,
            sizes: crate::config::default_sizes(task),
            backends: vec![BackendKind::Native, BackendKind::Xla],
            reps: 5,
            epochs: crate::tasks::registry::get(task).default_epochs(),
            seed: 42,
            // The paper's protocol times each replication's own sequential
            // run (mean ± 2σ across replications).  Batched execution
            // reports batch_wall/R shares with zero cross-replication
            // variance, which is a different methodology — so the Figure-2
            // protocol pins sequential; batch timing has its own bench
            // (batch_sweep) and CLI switch (--exec batch).
            exec: ExecMode::Sequential,
        }
    }

    pub fn spec_for(&self, size: usize, backend: BackendKind) -> ExperimentSpec {
        ExperimentSpec::new(self.task, backend)
            .size(size)
            .epochs(self.epochs)
            .replications(self.reps)
            .seed(self.seed)
            .execution(self.exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let s = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla)
            .size(512)
            .epochs(7)
            .replications(3)
            .seed(9)
            .samples(16)
            .execution(ExecMode::Batched { shards: 1 });
        assert_eq!(s.size, 512);
        assert_eq!(s.params.size, 512);
        assert_eq!(s.params.iters, 7);
        assert_eq!(s.reps, 3);
        assert_eq!(s.seed, 9);
        assert_eq!(s.params.samples, 16);
        assert_eq!(s.exec, ExecMode::Batched { shards: 1 });
        s.validate().unwrap();
        let s = s.sharded(3);
        assert_eq!(s.exec, ExecMode::Batched { shards: 3 });
        s.validate().unwrap();
    }

    #[test]
    fn default_exec_modes() {
        // single experiments default to Auto…
        let s = ExperimentSpec::new(TaskKind::Newsvendor, BackendKind::Native);
        assert_eq!(s.exec, ExecMode::Auto);
        // …but the paper's Figure-2 protocol pins the sequential
        // per-replication timing methodology (see figure2()).
        let sw = SweepSpec::figure2(TaskKind::Newsvendor);
        assert_eq!(sw.spec_for(64, BackendKind::Native).exec,
                   ExecMode::Sequential);
    }

    #[test]
    fn validation_catches_zeroes() {
        let mut s = ExperimentSpec::new(TaskKind::Newsvendor, BackendKind::Native);
        s.reps = 0;
        assert!(s.validate().is_err());
        let mut s = ExperimentSpec::new(TaskKind::Classification, BackendKind::Native);
        s.params.batch = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn validation_catches_degenerate_shard_plans() {
        let base = ExperimentSpec::new(TaskKind::MeanVariance,
                                       BackendKind::Native)
            .replications(4);
        assert!(base.clone().sharded(0).validate().is_err(),
                "shards == 0 must be rejected at validate time");
        let err = base.clone().sharded(5).validate().unwrap_err();
        assert!(format!("{:#}", err).contains("must not exceed"),
                "{:#}", err);
        // every legal shard count passes, including S = R and uneven
        for s in 1..=4 {
            base.clone().sharded(s).validate().unwrap();
        }
        // shard counts are a batched-plan property: seq/auto never carry
        // one, so reps alone bounds nothing there
        base.clone()
            .execution(ExecMode::Sequential)
            .replications(1)
            .validate()
            .unwrap();
    }

    #[test]
    fn xla_shard_plans_must_divide_reps() {
        // The XLA arm dispatches fixed-shape [R/S × …] artifacts, and
        // aot.py only emits equal shard sizes — an uneven split must die
        // in validate with the regenerate recipe, not at artifact load.
        let base = ExperimentSpec::new(TaskKind::MeanVariance,
                                       BackendKind::Xla)
            .replications(5);
        let err = base.clone().sharded(2).validate().unwrap_err();
        assert!(format!("{:#}", err).contains("--shards"), "{:#}", err);
        base.clone().sharded(5).validate().unwrap();
        base.clone().sharded(1).validate().unwrap();
        // the native arm keeps uneven splits
        ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
            .replications(5)
            .sharded(2)
            .validate()
            .unwrap();
    }

    #[test]
    fn wire_roundtrip_is_identity_for_every_task() {
        // the deterministic arm of the round-trip property (the random arm
        // lives in tests/prop_invariants.rs): every registered task, every
        // exec mode, every legal shard count
        for task in TaskKind::all() {
            for backend in [BackendKind::Native, BackendKind::Xla] {
                let reps = 4;
                let mut modes = vec![ExecMode::Auto, ExecMode::Sequential];
                for shards in 1..=reps {
                    modes.push(ExecMode::Batched { shards });
                }
                for exec in modes {
                    let spec = ExperimentSpec::new(task, backend)
                        .replications(reps)
                        .seed(u64::MAX - 7)
                        .execution(exec)
                        .results_dir("/tmp/rt");
                    let text = spec.to_json().to_string_compact();
                    let back = ExperimentSpec::from_json(
                        &Value::parse(&text).unwrap()).unwrap();
                    assert_eq!(back.to_json().to_string_compact(), text,
                               "task {} exec {:?}", task, exec);
                    assert_eq!(back.spec_hash(), spec.spec_hash());
                    assert_eq!(back.seed, spec.seed, "u64 seed must survive");
                    assert_eq!(back.exec, spec.exec);
                    // the canonical (delivery-stripped) form parses too —
                    // result payloads embed exactly this encoding
                    let canon = spec.canonical_json().to_string_compact();
                    let back = ExperimentSpec::from_json(
                        &Value::parse(&canon).unwrap()).unwrap();
                    assert_eq!(back.results_dir, None);
                    assert_eq!(back.spec_hash(), spec.spec_hash());
                }
            }
        }
    }

    #[test]
    fn budget_is_wire_optional_and_hash_relevant() {
        let plain = ExperimentSpec::new(TaskKind::MeanVariance,
                                        BackendKind::Native)
            .execution(ExecMode::Batched { shards: 1 });
        // no budget ⇒ the key is absent from the wire form entirely
        // (legacy encodings and hashes stay byte-identical)
        let text = plain.to_json().to_string_compact();
        assert!(!text.contains("budget"), "{}", text);

        let budgeted =
            plain.clone().budget(BudgetPolicy { check_every: 5, gap: 0.25,
                                                tol: 1e-6 });
        budgeted.validate().unwrap();
        // a budget changes what is computed ⇒ it changes the cache key
        assert_ne!(plain.spec_hash(), budgeted.spec_hash());
        // and round-trips bit-exactly through the wire form
        let text = budgeted.to_json().to_string_compact();
        assert!(text.contains("\"budget\":{\"check_every\":5"), "{}", text);
        let back =
            ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.budget, budgeted.budget);
        assert_eq!(back.to_json().to_string_compact(), text);
        assert_eq!(back.spec_hash(), budgeted.spec_hash());
        // an explicit null parses as off, like results_dir
        let text = plain.to_json().to_string_compact().replace(
            "\"results_dir\":null",
            "\"budget\":null,\"results_dir\":null");
        let back =
            ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.budget, None);
        assert_eq!(back.spec_hash(), plain.spec_hash());
    }

    #[test]
    fn budget_validation_requires_a_batched_plan_and_sane_fields() {
        let base = ExperimentSpec::new(TaskKind::MeanVariance,
                                       BackendKind::Native);
        let policy = BudgetPolicy { check_every: 2, gap: 0.25, tol: 1e-6 };
        // seq and auto plans have no shared panel to budget over
        for exec in [ExecMode::Sequential, ExecMode::Auto] {
            let err = base.clone().execution(exec).budget(policy)
                .validate().unwrap_err();
            assert!(format!("{:#}", err).contains("batched"), "{:#}", err);
        }
        let batched = base.clone().execution(ExecMode::Batched { shards: 1 });
        batched.clone().budget(policy).validate().unwrap();
        // degenerate policies die at validate time with the field named
        for bad in [BudgetPolicy { check_every: 0, ..policy },
                    BudgetPolicy { gap: f64::NAN, ..policy },
                    BudgetPolicy { gap: -0.5, ..policy },
                    BudgetPolicy { tol: f64::INFINITY, ..policy }] {
            assert!(batched.clone().budget(bad).validate().is_err(),
                    "{:?}", bad);
        }
        // malformed budget objects are shape errors at parse time
        let text = batched.clone().budget(policy).to_json()
            .to_string_compact()
            .replace("\"gap\":0.25,", "\"gap\":0.25,\"wat\":1,");
        assert!(ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                    .is_err());
    }

    #[test]
    fn results_dir_is_excluded_from_the_cache_key() {
        let a = ExperimentSpec::new(TaskKind::MeanVariance,
                                    BackendKind::Native);
        let b = a.clone().results_dir("/tmp/somewhere-else");
        assert_eq!(a.spec_hash(), b.spec_hash(),
                   "delivery location must not change the cache key");
        assert_ne!(a.to_json().to_string_compact(),
                   b.to_json().to_string_compact(),
                   "…but the wire form still carries it");
        let c = a.clone().seed(43);
        assert_ne!(a.spec_hash(), c.spec_hash(),
                   "computation-relevant fields must change the key");
    }

    #[test]
    fn from_json_rejects_malformed_specs() {
        let good = ExperimentSpec::new(TaskKind::Newsvendor,
                                       BackendKind::Native);
        let v = good.to_json();
        // unknown key
        let mut kv = match v.clone() {
            Value::Obj(kv) => kv,
            _ => unreachable!(),
        };
        kv.push(("surprise".to_string(), Value::Bool(true)));
        assert!(ExperimentSpec::from_json(&Value::Obj(kv)).is_err());
        // missing key
        let kv: Vec<_> = match v.clone() {
            Value::Obj(kv) => kv.into_iter()
                .filter(|(k, _)| k != "reps")
                .collect(),
            _ => unreachable!(),
        };
        assert!(ExperimentSpec::from_json(&Value::Obj(kv)).is_err());
        // shard count on a non-batched mode
        let text = v.to_string_compact().replace("\"shards\":1",
                                                 "\"shards\":3");
        assert!(ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                    .is_err());
        // numeric seed (the wire form is a decimal string)
        let text = v.to_string_compact().replace("\"seed\":\"42\"",
                                                 "\"seed\":42");
        assert!(ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                    .is_err());
        // disagreeing size / params.size
        let text = v.to_string_compact().replace("\"size\":256,\"samples\"",
                                                 "\"size\":255,\"samples\"");
        assert!(ExperimentSpec::from_json(&Value::parse(&text).unwrap())
                    .is_err());
        // not an object at all
        assert!(ExperimentSpec::from_json(&Value::parse("[1]").unwrap())
                    .is_err());
    }

    #[test]
    fn sweep_expands_grid() {
        let sw = SweepSpec::figure2(TaskKind::MeanVariance);
        assert_eq!(sw.sizes.len(), 3);
        assert_eq!(sw.backends.len(), 2);
        let spec = sw.spec_for(128, BackendKind::Native);
        assert_eq!(spec.size, 128);
        assert_eq!(spec.reps, sw.reps);
    }

    #[test]
    fn label_shape() {
        let s = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla);
        assert_eq!(s.label(), format!("mean_variance_xla_d{}", s.size));
    }
}
