//! L3 coordination: experiment specs → replicated runs → aggregated,
//! paper-shaped reports.
//!
//! The coordinator owns the PJRT engine (XLA jobs run on its thread — the
//! PJRT handles are not `Send`; the CPU runtime parallelizes compute
//! internally) and fans native replications out over a thread pool.

pub mod experiment;
pub mod metrics;
pub mod report;

pub use experiment::{ExperimentSpec, SweepSpec};
pub use metrics::{RepRecord, RunResult};

use anyhow::{bail, Context, Result};

use crate::backend::native::{
    NativeLr, NativeLrBatch, NativeMode, NativeMv, NativeMvBatch, NativeNv,
    NativeNvBatch,
};
use crate::backend::xla::{XlaLr, XlaLrBatch, XlaMv, XlaMvBatch, XlaNv,
                          XlaNvBatch};
use crate::backend::{LrBackend, MvBackend, NvBackend};
use crate::config::{BackendKind, ExecMode, TaskKind};
use crate::opt::{frank_wolfe, sqn};
use crate::rng::StreamTree;
use crate::runtime::Engine;
use crate::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use crate::tasks::NvLmo;
use crate::util::pool::parallel_map;

/// Path offset for replication subtrees (keeps problem-generation streams
/// and replication streams disjoint).
pub const REP_PATH_BASE: u64 = 1_000;

/// Replication stream subtrees for one experiment — the ONE derivation both
/// the sequential and batched paths use, so the two execution modes are
/// bit-reproducible against each other.  Public so benches/examples derive
/// the exact streams the coordinator runs instead of re-hardcoding the
/// path constant.
pub fn rep_subtrees(tree: &StreamTree, reps: usize) -> Vec<StreamTree> {
    (0..reps)
        .map(|r| tree.subtree(&[REP_PATH_BASE + r as u64]))
        .collect()
}

pub struct Coordinator {
    artifact_dir: String,
    pub results_dir: String,
    engine: Option<Engine>,
    /// Threads for native replication fan-out.
    pub native_threads: usize,
}

impl Coordinator {
    pub fn new(artifact_dir: &str, results_dir: &str) -> Result<Self> {
        std::fs::create_dir_all(results_dir).ok();
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Ok(Coordinator {
            artifact_dir: artifact_dir.to_string(),
            results_dir: results_dir.to_string(),
            engine: None,
            native_threads: threads,
        })
    }

    /// Lazily initialize the PJRT engine (only when an XLA job runs).
    pub fn engine(&mut self) -> Result<&Engine> {
        if self.engine.is_none() {
            self.engine = Some(
                Engine::new(&self.artifact_dir)
                    .context("initializing PJRT engine")?,
            );
        }
        Ok(self.engine.as_ref().unwrap())
    }

    /// Run one experiment (task × backend × size × reps).
    pub fn run(&mut self, spec: &ExperimentSpec) -> Result<RunResult> {
        spec.validate()?;
        if self.use_batched(spec) && spec.backend == BackendKind::NativePar {
            // The batch engine runs each row with the paper's sequential
            // kernels; silently substituting them for native_par's blocked
            // intra-gradient kernels (ablation A3) would mislabel results.
            bail!(
                "--exec batch does not support the native_par ablation arm \
                 — use --backend native (same hardware, replication-major \
                 parallelism) or --exec seq"
            );
        }
        match spec.task {
            TaskKind::MeanVariance => self.run_mv(spec),
            TaskKind::Newsvendor => self.run_nv(spec),
            TaskKind::Classification => self.run_lr(spec),
        }
    }

    /// Resolve the spec's execution mode into a concrete plan
    /// (DESIGN.md §11).  `Auto` batches multi-replication runs on the
    /// plain native backend; `native_par` keeps the sequential protocol
    /// (its intra-gradient threading is an ablation arm), and the XLA
    /// batch artifacts are opt-in because the default AOT set does not
    /// include them.
    fn use_batched(&self, spec: &ExperimentSpec) -> bool {
        match spec.exec {
            ExecMode::Sequential => false,
            ExecMode::Batched => true,
            ExecMode::Auto => {
                spec.backend == BackendKind::Native && spec.reps >= 2
            }
        }
    }

    /// Run a full sweep (the Figure-2 protocol): every size × backend.
    pub fn sweep(&mut self, sweep: &SweepSpec) -> Result<Vec<RunResult>> {
        let mut out = Vec::new();
        for &size in &sweep.sizes {
            for &backend in &sweep.backends {
                let spec = sweep.spec_for(size, backend);
                eprintln!(
                    "[sweep] {} size={} backend={} reps={}",
                    spec.task, size, backend, spec.reps
                );
                out.push(self.run(&spec)?);
            }
        }
        Ok(out)
    }

    // -- task runners --------------------------------------------------------

    fn run_mv(&mut self, spec: &ExperimentSpec) -> Result<RunResult> {
        let tree = StreamTree::new(spec.seed);
        let universe = AssetUniverse::generate(&tree, spec.size);
        let p = &spec.params;
        let w0 = vec![1.0f32 / spec.size as f32; spec.size];
        let reps = spec.reps;

        if self.use_batched(spec) {
            let trees = rep_subtrees(&tree, reps);
            let traces = match spec.backend {
                BackendKind::Xla => {
                    let engine = self.engine()?;
                    let mut backend = XlaMvBatch::new(
                        engine, &universe, p.samples, p.m_inner, reps)?;
                    frank_wolfe::run_mv_batch(&mut backend, &w0, p.iters,
                                              &trees)?
                        .1
                }
                _ => {
                    let mut backend = NativeMvBatch::new(
                        &universe, p.samples, p.m_inner, reps,
                        self.native_threads);
                    frank_wolfe::run_mv_batch(&mut backend, &w0, p.iters,
                                              &trees)?
                        .1
                }
            };
            let records = traces.into_iter().map(RepRecord::from_fw).collect();
            return Ok(RunResult::new(spec.clone(), records));
        }

        let records: Vec<RepRecord> = match spec.backend {
            BackendKind::Xla => {
                let engine = self.engine()?;
                let mut backend =
                    XlaMv::new(engine, &universe, p.samples, p.m_inner)?;
                (0..reps)
                    .map(|r| {
                        let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                        let (_, trace) = frank_wolfe::run_mv(
                            &mut backend, w0.clone(), p.iters, &sub)?;
                        Ok(RepRecord::from_fw(trace))
                    })
                    .collect::<Result<_>>()?
            }
            BackendKind::Native | BackendKind::NativePar => {
                let mode = native_mode(spec.backend, self.native_threads);
                let results = parallel_map(reps, self.native_threads, |r| {
                    let mut backend = NativeMv::new(
                        universe.clone(), p.samples, p.m_inner, mode);
                    let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                    frank_wolfe::run_mv(&mut backend, w0.clone(), p.iters, &sub)
                        .map(|(_, t)| RepRecord::from_fw(t))
                });
                results.into_iter().collect::<Result<_>>()?
            }
        };
        Ok(RunResult::new(spec.clone(), records))
    }

    fn run_nv(&mut self, spec: &ExperimentSpec) -> Result<RunResult> {
        let tree = StreamTree::new(spec.seed);
        let inst = NewsvendorInstance::generate(
            &tree, spec.size, spec.params.resources, spec.params.tightness);
        let p = &spec.params;
        let x0 = inst.feasible_start();
        let reps = spec.reps;

        if self.use_batched(spec) {
            let trees = rep_subtrees(&tree, reps);
            let mut lmos: Vec<NvLmo> =
                (0..reps).map(|_| NvLmo::new(&inst)).collect();
            let traces = match spec.backend {
                BackendKind::Xla => {
                    let engine = self.engine()?;
                    let mut backend =
                        XlaNvBatch::new(engine, &inst, p.samples, reps)?;
                    frank_wolfe::run_nv_batch(&mut backend, &mut lmos, &x0,
                                              p.iters, p.m_inner, &trees)?
                        .1
                }
                _ => {
                    let mut backend = NativeNvBatch::new(
                        &inst, p.samples, reps, self.native_threads);
                    frank_wolfe::run_nv_batch(&mut backend, &mut lmos, &x0,
                                              p.iters, p.m_inner, &trees)?
                        .1
                }
            };
            let records = traces.into_iter().map(RepRecord::from_fw).collect();
            return Ok(RunResult::new(spec.clone(), records));
        }

        let records: Vec<RepRecord> = match spec.backend {
            BackendKind::Xla => {
                let engine = self.engine()?;
                let mut backend = XlaNv::new(engine, &inst, p.samples)?;
                (0..reps)
                    .map(|r| {
                        let mut lmo = NvLmo::new(&inst);
                        let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                        let (_, trace) = frank_wolfe::run_nv(
                            &mut backend, &mut lmo, x0.clone(), p.iters,
                            p.m_inner, &sub)?;
                        Ok(RepRecord::from_fw(trace))
                    })
                    .collect::<Result<_>>()?
            }
            BackendKind::Native | BackendKind::NativePar => {
                let mode = native_mode(spec.backend, self.native_threads);
                let results = parallel_map(reps, self.native_threads, |r| {
                    let mut backend =
                        NativeNv::new(inst.clone(), p.samples, mode);
                    let mut lmo = NvLmo::new(&inst);
                    let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                    frank_wolfe::run_nv(&mut backend, &mut lmo, x0.clone(),
                                        p.iters, p.m_inner, &sub)
                        .map(|(_, t)| RepRecord::from_fw(t))
                });
                results.into_iter().collect::<Result<_>>()?
            }
        };
        Ok(RunResult::new(spec.clone(), records))
    }

    fn run_lr(&mut self, spec: &ExperimentSpec) -> Result<RunResult> {
        let tree = StreamTree::new(spec.seed);
        let data = ClassifyData::generate(&tree, spec.size);
        let p = &spec.params;
        let cfg = sqn::SqnConfig {
            iters: p.iters,
            batch: p.batch,
            hbatch: p.hbatch,
            l_every: p.l_every,
            memory: p.memory,
            beta: p.beta,
            track_every: spec.track_every,
            track_rows: 2048,
        };
        let reps = spec.reps;

        if self.use_batched(spec) {
            let trees = rep_subtrees(&tree, reps);
            let traces = match spec.backend {
                BackendKind::Xla => {
                    let engine = self.engine()?;
                    let mut backend = XlaLrBatch::new(
                        engine, &data, p.batch, p.hbatch, p.memory,
                        spec.hessian_mode, reps)?;
                    sqn::run_sqn_batch(&mut backend, &data, &cfg, &trees)?.1
                }
                _ => {
                    let mut backend = NativeLrBatch::new(
                        &data, reps, self.native_threads, spec.hessian_mode);
                    sqn::run_sqn_batch(&mut backend, &data, &cfg, &trees)?.1
                }
            };
            let records =
                traces.into_iter().map(RepRecord::from_sqn).collect();
            return Ok(RunResult::new(spec.clone(), records));
        }

        let records: Vec<RepRecord> = match spec.backend {
            BackendKind::Xla => {
                let engine = self.engine()?;
                let mut backend = XlaLr::new(engine, &data, p.batch, p.hbatch,
                                             p.memory, spec.hessian_mode)?;
                (0..reps)
                    .map(|r| {
                        let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                        let (_, trace) =
                            sqn::run_sqn(&mut backend, &data, &cfg, &sub)?;
                        Ok(RepRecord::from_sqn(trace))
                    })
                    .collect::<Result<_>>()?
            }
            BackendKind::Native | BackendKind::NativePar => {
                let mode = native_mode(spec.backend, self.native_threads);
                let results = parallel_map(reps, self.native_threads, |r| {
                    let mut backend =
                        NativeLr::new(&data, mode, spec.hessian_mode);
                    let sub = tree.subtree(&[REP_PATH_BASE + r as u64]);
                    sqn::run_sqn(&mut backend, &data, &cfg, &sub)
                        .map(|(_, t)| RepRecord::from_sqn(t))
                });
                results.into_iter().collect::<Result<_>>()?
            }
        };
        Ok(RunResult::new(spec.clone(), records))
    }

    /// Instantiate a boxed backend for one-off use (examples, benches).
    pub fn make_mv_backend(&mut self, spec: &ExperimentSpec,
                           universe: &AssetUniverse)
        -> Result<Box<dyn MvBackend>> {
        let p = &spec.params;
        Ok(match spec.backend {
            BackendKind::Xla => Box::new(XlaMv::new(
                self.engine()?, universe, p.samples, p.m_inner)?),
            b => Box::new(NativeMv::new(
                universe.clone(), p.samples, p.m_inner,
                native_mode(b, self.native_threads))),
        })
    }

    pub fn make_nv_backend(&mut self, spec: &ExperimentSpec,
                           inst: &NewsvendorInstance)
        -> Result<Box<dyn NvBackend>> {
        let p = &spec.params;
        Ok(match spec.backend {
            BackendKind::Xla => {
                Box::new(XlaNv::new(self.engine()?, inst, p.samples)?)
            }
            b => Box::new(NativeNv::new(
                inst.clone(), p.samples, native_mode(b, self.native_threads))),
        })
    }

    pub fn make_lr_backend(&mut self, spec: &ExperimentSpec,
                           data: &ClassifyData) -> Result<Box<dyn LrBackend>> {
        let p = &spec.params;
        Ok(match spec.backend {
            BackendKind::Xla => Box::new(XlaLr::new(
                self.engine()?, data, p.batch, p.hbatch, p.memory,
                spec.hessian_mode)?),
            b => Box::new(NativeLr::with_dim(
                data.n_features, native_mode(b, self.native_threads),
                spec.hessian_mode)),
        })
    }
}

fn native_mode(kind: BackendKind, threads: usize) -> NativeMode {
    match kind {
        BackendKind::Native => NativeMode::Sequential,
        BackendKind::NativePar => NativeMode::Parallel { threads },
        BackendKind::Xla => {
            // callers dispatch Xla before reaching here
            unreachable!("native_mode called with Xla")
        }
    }
}

/// Validate that every artifact a spec needs exists before running (fail
/// fast with an actionable message).
pub fn check_artifacts(engine: &Engine, spec: &ExperimentSpec) -> Result<()> {
    if spec.backend != BackendKind::Xla {
        return Ok(());
    }
    let p = &spec.params;
    let missing: Vec<String> = match spec.task {
        TaskKind::MeanVariance => {
            let req = [("d", spec.size as i64), ("n", p.samples as i64),
                       ("m", p.m_inner as i64)];
            if engine.manifest.find("mv_epoch", &req).is_none() {
                vec![format!("mv_epoch d={} n={} m={}", spec.size, p.samples,
                             p.m_inner)]
            } else {
                vec![]
            }
        }
        TaskKind::Newsvendor => {
            let req = [("d", spec.size as i64), ("s", p.samples as i64)];
            if engine.manifest.find("nv_grad", &req).is_none() {
                vec![format!("nv_grad d={} s={}", spec.size, p.samples)]
            } else {
                vec![]
            }
        }
        TaskKind::Classification => {
            let n = spec.size as i64;
            let mut m = Vec::new();
            if engine.manifest.find("lr_grad", &[("n", n)]).is_none() {
                m.push(format!("lr_grad n={}", n));
            }
            if engine.manifest.find("lr_hvp", &[("n", n)]).is_none() {
                m.push(format!("lr_hvp n={}", n));
            }
            m
        }
    };
    if !missing.is_empty() {
        bail!(
            "missing artifacts: {} — regenerate with \
             `cd python && python -m compile.aot --out ../artifacts \
             --{}-dims {}`",
            missing.join(", "),
            match spec.task {
                TaskKind::MeanVariance => "mv",
                TaskKind::Newsvendor => "nv",
                TaskKind::Classification => "lr",
            },
            spec.size
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HessianMode;
    use crate::config::TaskParams;

    fn tiny_spec(task: TaskKind) -> ExperimentSpec {
        let size = match task {
            TaskKind::MeanVariance => 16,
            TaskKind::Newsvendor => 16,
            TaskKind::Classification => 16,
        };
        let mut params = TaskParams::defaults(task, size);
        match task {
            TaskKind::Classification => {
                params.iters = 30;
                params.batch = 16;
                params.hbatch = 32;
                params.l_every = 5;
                params.memory = 3;
            }
            _ => {
                params.iters = 4;
                params.m_inner = 3;
                params.samples = 8;
            }
        }
        ExperimentSpec {
            task,
            backend: BackendKind::Native,
            size,
            reps: 2,
            seed: 7,
            hessian_mode: HessianMode::Explicit,
            track_every: 5,
            exec: ExecMode::Auto,
            params,
        }
    }

    #[test]
    fn native_mv_run_produces_records() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let res = c.run(&tiny_spec(TaskKind::MeanVariance)).unwrap();
        assert_eq!(res.reps.len(), 2);
        assert!(res.reps[0].total_s > 0.0);
        assert_eq!(res.reps[0].objs.len(), 4);
        // replications with different streams differ
        assert_ne!(res.reps[0].objs, res.reps[1].objs);
    }

    #[test]
    fn native_nv_run_produces_records() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let res = c.run(&tiny_spec(TaskKind::Newsvendor)).unwrap();
        assert_eq!(res.reps.len(), 2);
        assert!(res.reps[0].objs.iter().all(|o| o.is_finite()));
    }

    #[test]
    fn native_lr_run_produces_records() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let res = c.run(&tiny_spec(TaskKind::Classification)).unwrap();
        assert_eq!(res.reps.len(), 2);
        assert!(!res.reps[0].objs.is_empty());
    }

    #[test]
    fn run_is_reproducible() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let spec = tiny_spec(TaskKind::MeanVariance);
        let a = c.run(&spec).unwrap();
        let b = c.run(&spec).unwrap();
        assert_eq!(a.reps[0].objs, b.reps[0].objs);
        assert_eq!(a.reps[1].objs, b.reps[1].objs);
    }

    #[test]
    fn invalid_spec_rejected() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let mut spec = tiny_spec(TaskKind::MeanVariance);
        spec.reps = 0;
        assert!(c.run(&spec).is_err());
    }

    #[test]
    fn auto_mode_batches_native_multirep_only() {
        let c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let mut spec = tiny_spec(TaskKind::MeanVariance);
        assert!(c.use_batched(&spec), "native reps=2 should auto-batch");
        spec.reps = 1;
        assert!(!c.use_batched(&spec), "single replication stays sequential");
        spec.reps = 2;
        spec.backend = BackendKind::NativePar;
        assert!(!c.use_batched(&spec), "native_par is an ablation arm");
        spec.backend = BackendKind::Xla;
        assert!(!c.use_batched(&spec), "xla batch artifacts are opt-in");
        spec.exec = ExecMode::Batched;
        assert!(c.use_batched(&spec));
        spec.exec = ExecMode::Sequential;
        spec.backend = BackendKind::Native;
        assert!(!c.use_batched(&spec));
    }

    #[test]
    fn batched_native_par_rejected() {
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        let mut spec = tiny_spec(TaskKind::MeanVariance);
        spec.backend = BackendKind::NativePar;
        spec.exec = ExecMode::Batched;
        let err = c.run(&spec).unwrap_err();
        assert!(format!("{:#}", err).contains("native_par"), "{:#}", err);
    }

    #[test]
    fn sequential_and_batched_runs_agree_bitwise() {
        // The coordinator-level contract behind ExecMode::Auto: flipping
        // the execution mode never changes a single objective bit.
        let mut c = Coordinator::new("artifacts", "/tmp/simopt-test-results")
            .unwrap();
        for task in TaskKind::all() {
            let mut spec = tiny_spec(task);
            spec.exec = ExecMode::Sequential;
            let seq = c.run(&spec).unwrap();
            spec.exec = ExecMode::Batched;
            let bat = c.run(&spec).unwrap();
            assert_eq!(seq.reps.len(), bat.reps.len());
            for (a, b) in seq.reps.iter().zip(&bat.reps) {
                assert_eq!(a.objs, b.objs, "task {}", task);
                assert_eq!(a.obj_iters, b.obj_iters, "task {}", task);
            }
        }
    }
}
