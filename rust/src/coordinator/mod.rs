//! L3 coordination: experiment specs → replicated runs → aggregated,
//! paper-shaped reports.
//!
//! The coordinator owns the PJRT engine (XLA jobs run on its thread — the
//! PJRT handles are not `Send`; the CPU runtime parallelizes compute
//! internally) and fans native replications out over a thread pool.
//!
//! Since the task-registry refactor (DESIGN.md §12) the coordinator is
//! task-generic: [`Coordinator::run`] resolves the execution plan
//! (sequential vs batched, DESIGN.md §11), looks the task up in
//! [`crate::tasks::registry`], and delegates — adding a scenario never
//! touches this module.

pub mod experiment;
pub mod metrics;
pub mod report;

pub use experiment::{ExperimentSpec, SweepSpec};
pub use metrics::{RepRecord, RunResult};

use anyhow::{bail, Context, Result};

use crate::config::{BackendKind, ExecMode};
use crate::opt::{NullSink, ProgressSink};
use crate::util::log;
use crate::rng::StreamTree;
use crate::runtime::Engine;
use crate::tasks::registry::{self, TaskBackend};

/// Path offset for replication subtrees (keeps problem-generation streams
/// and replication streams disjoint).
pub const REP_PATH_BASE: u64 = 1_000;

/// Replication stream subtrees for one experiment — the ONE derivation both
/// the sequential and batched paths use, so the two execution modes are
/// bit-reproducible against each other.  Public so benches/examples derive
/// the exact streams the coordinator runs instead of re-hardcoding the
/// path constant.
pub fn rep_subtrees(tree: &StreamTree, reps: usize) -> Vec<StreamTree> {
    (0..reps)
        .map(|r| tree.subtree(&[REP_PATH_BASE + r as u64]))
        .collect()
}

pub struct Coordinator {
    artifact_dir: String,
    pub results_dir: String,
    engine: Option<Engine>,
    /// Threads for native replication fan-out.
    pub native_threads: usize,
}

impl Coordinator {
    pub fn new(artifact_dir: &str, results_dir: &str) -> Result<Self> {
        std::fs::create_dir_all(results_dir).ok();
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Ok(Coordinator {
            artifact_dir: artifact_dir.to_string(),
            results_dir: results_dir.to_string(),
            engine: None,
            native_threads: threads,
        })
    }

    /// Lazily initialize the PJRT engine (only when an XLA job runs).
    pub fn engine(&mut self) -> Result<&Engine> {
        if self.engine.is_none() {
            self.engine = Some(
                Engine::new(&self.artifact_dir)
                    .context("initializing PJRT engine")?,
            );
        }
        Ok(self.engine.as_ref().unwrap())
    }

    /// Run one experiment (task × backend × size × reps) — the ONE
    /// task-generic plan-select-and-execute path: validate, resolve the
    /// execution plan, and delegate to the task's registry entry.
    pub fn run(&mut self, spec: &ExperimentSpec) -> Result<RunResult> {
        self.run_with(spec, &mut NullSink)
    }

    /// [`Coordinator::run`] with the execution plane's observer hook
    /// (DESIGN.md §14): every outer optimization step is reported to
    /// `sink` as a [`crate::opt::StepEvent`] from outside the timed
    /// kernel regions, so observing a run never perturbs its measured
    /// timings — and with the default policy knobs the result payload is
    /// byte-identical to an unobserved run.
    pub fn run_with(&mut self, spec: &ExperimentSpec,
                    sink: &mut dyn ProgressSink) -> Result<RunResult> {
        spec.validate()?;
        let plan = self.exec_plan(spec);
        if plan.is_some() && spec.backend == BackendKind::NativePar {
            // The batch engine runs each row with the paper's sequential
            // kernels; silently substituting them for native_par's blocked
            // intra-gradient kernels (ablation A3) would mislabel results.
            bail!(
                "--exec batch does not support the native_par ablation arm \
                 — use --backend native (same hardware, replication-major \
                 parallelism) or --exec seq"
            );
        }
        let task = registry::get(spec.task);
        let result = match plan {
            Some(shards) => {
                let run = task.run_batch(self, spec, shards, sink)?;
                RunResult::new(spec.clone(), run.records)
                    .executed(plan)
                    .with_budget_outcome(run.frozen, run.early_stop)
                    .with_profile(run.profile)
            }
            None => {
                let (records, prof) = task.run_seq(self, spec, sink)?;
                RunResult::new(spec.clone(), records)
                    .executed(plan)
                    .with_profile(prof)
            }
        };
        // Per-run report isolation (DESIGN.md §14): a spec that names its
        // own results directory gets its report bundle there — concurrent
        // served requests and CI runs never collide in one shared
        // `results/` tree.  `None` (the default) keeps the historical
        // behavior: single runs persist nothing.
        if let Some(dir) = &spec.results_dir {
            report::persist_run_report(dir, &result)
                .with_context(|| format!("persisting report under {}", dir))?;
        }
        Ok(result)
    }

    /// Resolve the spec's execution mode into a concrete plan
    /// (DESIGN.md §11/§13): `None` = sequential, `Some(shards)` = the
    /// shard-aware batched plane.  `Auto` batches multi-replication runs
    /// on the plain native backend as one unsharded panel; `native_par`
    /// keeps the sequential protocol (its intra-gradient threading is an
    /// ablation arm), and the XLA batch artifacts are opt-in because the
    /// default AOT set does not include them.
    fn exec_plan(&self, spec: &ExperimentSpec) -> Option<usize> {
        match spec.exec {
            ExecMode::Sequential => None,
            ExecMode::Batched { shards } => Some(shards),
            ExecMode::Auto => (spec.backend == BackendKind::Native
                               && spec.reps >= 2)
                .then_some(1),
        }
    }

    /// Run a full sweep (the Figure-2 protocol): every size × backend.
    pub fn sweep(&mut self, sweep: &SweepSpec) -> Result<Vec<RunResult>> {
        let mut out = Vec::new();
        for &size in &sweep.sizes {
            for &backend in &sweep.backends {
                let spec = sweep.spec_for(size, backend);
                log::info("sweep", "run")
                    .field("task", spec.task)
                    .field("size", size)
                    .field("backend", backend)
                    .field("reps", spec.reps)
                    .emit();
                out.push(self.run(&spec)?);
            }
        }
        Ok(out)
    }

    /// Instantiate a boxed per-replication backend for one-off use
    /// (examples, benches) — a registry lookup; the task generates its own
    /// problem instance from the spec seed.
    pub fn make_backend(&mut self, spec: &ExperimentSpec)
        -> Result<TaskBackend> {
        registry::get(spec.task).make_backend(self, spec)
    }
}

/// Validate that every artifact a spec needs exists before running (fail
/// fast with an actionable message) — a registry lookup over the task's
/// declared artifact requirements.
pub fn check_artifacts(engine: &Engine, spec: &ExperimentSpec) -> Result<()> {
    if spec.backend != BackendKind::Xla {
        return Ok(());
    }
    let task = registry::get(spec.task);
    let missing = task.missing_artifacts(engine, spec);
    if !missing.is_empty() {
        bail!(
            "missing artifacts: {} — regenerate with \
             `cd python && python -m compile.aot --out ../artifacts \
             --{}-dims {}`",
            missing.join(", "),
            task.dims_flag(),
            spec.size
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BudgetPolicy, TaskKind};
    use crate::opt::StepEvent;

    fn coord() -> Coordinator {
        Coordinator::new("artifacts", "/tmp/simopt-test-results").unwrap()
    }

    /// Records `(epoch, live)` per event — enough to check coverage.
    struct RecordingSink {
        events: Vec<(usize, usize)>,
    }

    impl ProgressSink for RecordingSink {
        fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
            assert_eq!(ev.reps.len(), ev.objs.len());
            assert!(ev.epoch >= 1 && ev.epoch <= ev.epochs);
            self.events.push((ev.epoch, ev.live));
            Ok(())
        }
    }

    // -- registry-conformance suite (DESIGN.md §12) -------------------------
    //
    // ONE suite iterates every registered task; registering a new scenario
    // (e.g. mean_cvar) must pass it with zero suite changes.

    #[test]
    fn conformance_every_task_produces_records() {
        let mut c = coord();
        for task in registry::all() {
            let spec = task.smoke_spec();
            let res = c.run(&spec).unwrap_or_else(|e| {
                panic!("{} run failed: {:#}", task.name(), e)
            });
            assert_eq!(res.reps.len(), spec.reps, "task {}", task.name());
            for rep in &res.reps {
                assert!(rep.total_s > 0.0, "task {}", task.name());
                assert!(!rep.objs.is_empty(), "task {}", task.name());
                assert!(rep.objs.iter().all(|o| o.is_finite()),
                        "task {}: non-finite objective", task.name());
                assert_eq!(rep.objs.len(), rep.obj_iters.len(),
                           "task {}", task.name());
            }
            // replications with different streams differ
            assert_ne!(res.reps[0].objs, res.reps[1].objs,
                       "task {}: replication streams collided", task.name());
        }
    }

    #[test]
    fn conformance_every_task_is_reproducible() {
        let mut c = coord();
        for task in registry::all() {
            let spec = task.smoke_spec();
            let a = c.run(&spec).unwrap();
            let b = c.run(&spec).unwrap();
            for (ra, rb) in a.reps.iter().zip(&b.reps) {
                assert_eq!(ra.objs, rb.objs, "task {}", task.name());
            }
        }
    }

    #[test]
    fn conformance_sequential_and_batched_agree_bitwise() {
        // The coordinator-level contract behind ExecMode::Auto: flipping
        // the execution mode never changes a single objective bit, for
        // EVERY registered task.
        let mut c = coord();
        for task in registry::all() {
            let mut spec = task.smoke_spec();
            spec.exec = ExecMode::Sequential;
            let seq = c.run(&spec).unwrap();
            assert!(!seq.batched);
            spec.exec = ExecMode::Batched { shards: 1 };
            let bat = c.run(&spec).unwrap();
            assert!(bat.batched);
            assert_eq!(seq.reps.len(), bat.reps.len());
            for (a, b) in seq.reps.iter().zip(&bat.reps) {
                assert_eq!(a.objs, b.objs, "task {}", task.name());
                assert_eq!(a.obj_iters, b.obj_iters, "task {}",
                           task.name());
            }
        }
    }

    #[test]
    fn conformance_every_task_runs_the_sharded_plan_bitwise() {
        // The shard plane's refactor invariant (DESIGN.md §13), at the
        // coordinator level for EVERY registered task: S ∈ {1, 2, R} with
        // R = 3 (so S = 2 is an uneven 2+1 split) is bit-identical to the
        // sequential protocol, and the resolved plan is recorded.
        let mut c = coord();
        for task in registry::all() {
            let mut spec = task.smoke_spec();
            spec.reps = 3;
            spec.exec = ExecMode::Sequential;
            let seq = c.run(&spec).unwrap();
            for shards in [1usize, 2, 3] {
                spec.exec = ExecMode::Batched { shards };
                let sharded = c.run(&spec).unwrap_or_else(|e| {
                    panic!("{} S={} failed: {:#}", task.name(), shards, e)
                });
                assert!(sharded.batched, "task {}", task.name());
                assert_eq!(sharded.shards, shards, "task {}", task.name());
                assert_eq!(seq.reps.len(), sharded.reps.len());
                for (a, b) in seq.reps.iter().zip(&sharded.reps) {
                    assert_eq!(a.objs, b.objs, "task {} S={}",
                               task.name(), shards);
                    assert_eq!(a.obj_iters, b.obj_iters, "task {} S={}",
                               task.name(), shards);
                }
            }
        }
    }

    #[test]
    fn conformance_observed_runs_match_unobserved_runs_bitwise() {
        // The observer hook (DESIGN.md §14) is measurement-neutral: for
        // EVERY registered task, on both plans, attaching a sink reports
        // at least one event per plan epoch and changes no objective bit.
        let mut c = coord();
        for task in registry::all() {
            for exec in [ExecMode::Sequential, ExecMode::Batched { shards: 1 }] {
                let mut spec = task.smoke_spec();
                spec.exec = exec;
                let plain = c.run(&spec).unwrap();
                let mut sink = RecordingSink { events: Vec::new() };
                let observed = c.run_with(&spec, &mut sink).unwrap();
                assert!(!sink.events.is_empty(), "task {} {:?} silent",
                        task.name(), exec);
                assert_eq!(plain.reps.len(), observed.reps.len());
                for (a, b) in plain.reps.iter().zip(&observed.reps) {
                    assert_eq!(a.objs, b.objs, "task {} {:?}",
                               task.name(), exec);
                    assert_eq!(a.obj_iters, b.obj_iters, "task {} {:?}",
                               task.name(), exec);
                }
                assert!(observed.frozen.is_empty());
                assert_eq!(observed.early_stop, None);
            }
        }
    }

    #[test]
    fn budget_freezes_dominated_replications_and_rides_on_the_result() {
        // gap = 0 freezes every replication strictly worse than the
        // incumbent at the first checkpoint; frozen traces are bitwise
        // prefixes of the unbudgeted run (masked, not resliced), and the
        // surviving replication is untouched.
        let mut c = coord();
        let task = registry::get(TaskKind::MeanVariance);
        let mut spec = task.smoke_spec();
        spec.reps = 3;
        spec.exec = ExecMode::Batched { shards: 1 };
        let full = c.run(&spec).unwrap();
        spec.budget = Some(BudgetPolicy { check_every: 1, gap: 0.0,
                                          tol: 0.0 });
        let res = c.run(&spec).unwrap();
        assert!(!res.frozen.is_empty(), "gap=0 must freeze someone");
        let frozen: Vec<usize> = res.frozen.iter().map(|f| f.0).collect();
        assert!(frozen.len() < spec.reps, "the incumbent must survive");
        for (r, (a, b)) in full.reps.iter().zip(&res.reps).enumerate() {
            if frozen.contains(&r) {
                assert!(b.objs.len() < a.objs.len(),
                        "frozen rep {} kept its full trace", r);
            } else {
                assert_eq!(a.objs.len(), b.objs.len());
            }
            assert_eq!(&a.objs[..b.objs.len()], &b.objs[..],
                       "rep {} diverged before its freeze", r);
        }
    }

    // -- plan selection and guard rails -------------------------------------

    #[test]
    fn results_dir_isolates_per_run_reports() {
        let mut c = coord();
        let dir = std::env::temp_dir().join("simopt-results-dir-test");
        let _ = std::fs::remove_dir_all(&dir);
        let task = registry::get(TaskKind::MeanVariance);
        // default: no per-run persistence
        let res = c.run(&task.smoke_spec()).unwrap();
        assert!(!dir.exists());
        // a spec naming its own directory writes the full bundle there
        let spec = task.smoke_spec()
            .results_dir(&dir.to_string_lossy());
        let isolated = c.run(&spec).unwrap();
        let name = report::run_report_name(&isolated);
        for suffix in ["fig2.md", "summary.csv", "summary.json"] {
            let p = dir.join(format!("{}_{}", name, suffix));
            assert!(p.exists(), "{} missing", p.display());
        }
        // delivery location does not perturb the computation
        for (a, b) in res.reps.iter().zip(&isolated.reps) {
            assert_eq!(a.objs, b.objs);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn invalid_spec_rejected() {
        let mut c = coord();
        let mut spec = registry::get(TaskKind::MeanVariance).smoke_spec();
        spec.reps = 0;
        assert!(c.run(&spec).is_err());
        // degenerate shard plans die in validate, before any backend is
        // built (DESIGN.md §13)
        spec.reps = 2;
        spec.exec = ExecMode::Batched { shards: 0 };
        assert!(c.run(&spec).is_err());
        spec.exec = ExecMode::Batched { shards: 3 };
        assert!(c.run(&spec).is_err(), "shards > reps must be rejected");
    }

    #[test]
    fn auto_mode_batches_native_multirep_only() {
        let c = coord();
        let mut spec = registry::get(TaskKind::MeanVariance).smoke_spec();
        assert_eq!(c.exec_plan(&spec), Some(1),
                   "native reps=2 should auto-batch, unsharded");
        spec.reps = 1;
        assert_eq!(c.exec_plan(&spec), None,
                   "single replication stays sequential");
        spec.reps = 2;
        spec.backend = BackendKind::NativePar;
        assert_eq!(c.exec_plan(&spec), None, "native_par is an ablation arm");
        spec.backend = BackendKind::Xla;
        assert_eq!(c.exec_plan(&spec), None,
                   "xla batch artifacts are opt-in");
        spec.exec = ExecMode::Batched { shards: 2 };
        assert_eq!(c.exec_plan(&spec), Some(2),
                   "an explicit plan carries its shard count");
        spec.exec = ExecMode::Sequential;
        spec.backend = BackendKind::Native;
        assert_eq!(c.exec_plan(&spec), None);
    }

    #[test]
    fn batched_native_par_rejected() {
        let mut c = coord();
        let mut spec = registry::get(TaskKind::MeanVariance).smoke_spec();
        spec.backend = BackendKind::NativePar;
        spec.exec = ExecMode::Batched { shards: 1 };
        let err = c.run(&spec).unwrap_err();
        assert!(format!("{:#}", err).contains("native_par"), "{:#}", err);
    }
}
