//! Per-replication records and cross-replication aggregation — the
//! statistics behind Figure 2 (time mean ± 2σ) and Table 2 (RSE ± 2σ at
//! checkpoints).

use crate::opt::{FwTrace, SqnTrace};
use crate::util::stats::{self, OnlineStats};

use super::experiment::ExperimentSpec;

/// One replication's outcome.
#[derive(Debug, Clone)]
pub struct RepRecord {
    /// Total optimization wall-clock (tracking excluded).
    pub total_s: f64,
    /// Objective trace (per epoch for FW, per checkpoint for SQN).
    pub objs: Vec<f64>,
    /// Iteration indices the objective trace corresponds to.
    pub obj_iters: Vec<usize>,
    /// Wall-clock per epoch/iteration.
    pub step_s: Vec<f64>,
}

impl RepRecord {
    pub fn from_fw(t: FwTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = (1..=t.objs.len()).collect();
        RepRecord { total_s, objs: t.objs, obj_iters, step_s: t.epoch_s }
    }

    pub fn from_sqn(t: SqnTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = t.checkpoints.iter().map(|&(k, _)| k).collect();
        RepRecord {
            total_s,
            objs: t.tracked_losses(),
            obj_iters,
            step_s: t.iter_s,
        }
    }

    /// RSE trace against this replication's final objective (the paper's
    /// Table-2 definition).
    pub fn rse_trace(&self) -> Vec<f64> {
        stats::rse_trace(&self.objs)
    }
}

/// Aggregated outcome of one experiment cell.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub spec: ExperimentSpec,
    pub reps: Vec<RepRecord>,
    /// True when the replication axis executed through the batched engine
    /// (DESIGN.md §11).  Batched wall-clock is attributed to replications
    /// as `batch_time / R`, so the cross-replication ±2σ TIMING band is
    /// methodologically n/a — the report renderers mark it so instead of
    /// printing a fake ±0.00.
    pub batched: bool,
    /// Shard count of the resolved plan (DESIGN.md §13): 1 for sequential
    /// runs and the unsharded batched engine, S for `--shards S`.  Timing
    /// attribution stays `batch_time / R` whatever S is.
    pub shards: usize,
}

impl RunResult {
    pub fn new(spec: ExperimentSpec, reps: Vec<RepRecord>) -> Self {
        RunResult { spec, reps, batched: false, shards: 1 }
    }

    /// Record the execution plan that actually ran (set by the coordinator
    /// after resolving `ExecMode::Auto`): `None` = sequential,
    /// `Some(shards)` = the shard-aware batched plane.
    pub fn executed(mut self, plan: Option<usize>) -> Self {
        self.batched = plan.is_some();
        self.shards = plan.unwrap_or(1);
        self
    }

    /// Mean/σ of total runtime across replications.
    pub fn time_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            s.push(r.total_s);
        }
        s
    }

    /// Mean/σ of per-step (epoch or iteration) time across all reps+steps.
    pub fn step_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            for &v in &r.step_s {
                s.push(v);
            }
        }
        s
    }

    /// (mean, std) of the RSE at trace index `idx` across replications.
    pub fn rse_at_index(&self, idx: usize) -> (f64, f64) {
        let vals: Vec<f64> = self
            .reps
            .iter()
            .map(|r| stats::at_checkpoint(&r.rse_trace(), idx))
            .filter(|v| v.is_finite())
            .collect();
        (stats::mean(&vals), stats::std(&vals))
    }

    /// RSE checkpoints at fractional positions of the trace (e.g. 0.05 =
    /// 5% through the run), as (fraction, iteration, mean, std).
    pub fn rse_checkpoints(&self, fracs: &[f64]) -> Vec<(f64, usize, f64, f64)> {
        let len = self.reps.first().map(|r| r.objs.len()).unwrap_or(0);
        if len == 0 {
            return Vec::new();
        }
        fracs
            .iter()
            .map(|&f| {
                let idx = ((len as f64 * f).round() as usize).min(len - 1);
                let it = self
                    .reps
                    .first()
                    .map(|r| r.obj_iters.get(idx).copied().unwrap_or(idx))
                    .unwrap_or(idx);
                let (m, s) = self.rse_at_index(idx);
                (f, it, m, s)
            })
            .collect()
    }

    /// Final objective statistics across replications (accuracy agreement).
    pub fn final_obj_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            if let Some(&o) = r.objs.last() {
                s.push(o);
            }
        }
        s
    }

    pub fn summary(&self) -> String {
        let t = self.time_stats();
        format!(
            "{}: {} reps, total {:.3}s ±{:.3}s, final obj {:.6} ±{:.6}",
            self.spec.label(),
            self.reps.len(),
            t.mean(),
            2.0 * t.std(),
            self.final_obj_stats().mean(),
            2.0 * self.final_obj_stats().std(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HessianMode;
    use crate::config::{BackendKind, ExecMode, TaskKind, TaskParams};

    fn dummy_spec() -> ExperimentSpec {
        ExperimentSpec {
            task: TaskKind::MeanVariance,
            backend: BackendKind::Native,
            size: 8,
            reps: 2,
            seed: 1,
            hessian_mode: HessianMode::Explicit,
            track_every: 1,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(TaskKind::MeanVariance, 8),
        }
    }

    fn rec(objs: Vec<f64>, step: f64) -> RepRecord {
        let n = objs.len();
        RepRecord {
            total_s: step * n as f64,
            objs,
            obj_iters: (1..=n).collect(),
            step_s: vec![step; n],
        }
    }

    #[test]
    fn from_fw_preserves_trace() {
        let t = FwTrace { objs: vec![3.0, 2.0, 1.0], epoch_s: vec![0.1; 3] };
        let r = RepRecord::from_fw(t);
        assert_eq!(r.objs, vec![3.0, 2.0, 1.0]);
        assert!((r.total_s - 0.3).abs() < 1e-12);
        assert_eq!(r.obj_iters, vec![1, 2, 3]);
    }

    #[test]
    fn time_stats_aggregates() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![2.0, 1.0], 0.5),
            rec(vec![2.0, 1.0], 1.5),
        ]);
        let t = rr.time_stats();
        assert!((t.mean() - 2.0).abs() < 1e-12); // (1.0 + 3.0)/2
        assert_eq!(t.count(), 2);
        let s = rr.step_stats();
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn rse_checkpoints_shape() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![10.0, 5.0, 2.0, 1.0], 0.1),
            rec(vec![8.0, 4.0, 2.0, 1.0], 0.1),
        ]);
        let cps = rr.rse_checkpoints(&[0.0, 0.5, 1.0]);
        assert_eq!(cps.len(), 3);
        // early checkpoint has higher RSE than the final one (which is 0)
        assert!(cps[0].2 > cps[2].2);
        assert_eq!(cps[2].2, 0.0);
    }

    #[test]
    fn empty_runs_dont_panic() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert_eq!(rr.time_stats().count(), 0);
        assert!(rr.rse_checkpoints(&[0.5]).is_empty());
    }

    #[test]
    fn summary_contains_label() {
        let rr = RunResult::new(dummy_spec(), vec![rec(vec![1.0], 0.1)]);
        assert!(rr.summary().contains("mean_variance_native_d8"));
    }

    #[test]
    fn executed_plan_marks_result() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert!(!rr.batched, "sequential is the default attribution");
        assert_eq!(rr.shards, 1);
        let seq = RunResult::new(dummy_spec(), vec![]).executed(None);
        assert!(!seq.batched);
        assert_eq!(seq.shards, 1);
        let sharded = RunResult::new(dummy_spec(), vec![]).executed(Some(3));
        assert!(sharded.batched);
        assert_eq!(sharded.shards, 3);
    }
}
