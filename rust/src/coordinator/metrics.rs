//! Per-replication records and cross-replication aggregation — the
//! statistics behind Figure 2 (time mean ± 2σ) and Table 2 (RSE ± 2σ at
//! checkpoints).

use anyhow::{Context, Result};

use crate::opt::{FwTrace, SqnTrace};
use crate::util::json::{arr, num, obj, Value};
use crate::util::stats::{self, OnlineStats};

use super::experiment::ExperimentSpec;

/// One replication's outcome.
#[derive(Debug, Clone)]
pub struct RepRecord {
    /// Total optimization wall-clock (tracking excluded).
    pub total_s: f64,
    /// Objective trace (per epoch for FW, per checkpoint for SQN).
    pub objs: Vec<f64>,
    /// Iteration indices the objective trace corresponds to.
    pub obj_iters: Vec<usize>,
    /// Wall-clock per epoch/iteration.
    pub step_s: Vec<f64>,
}

impl RepRecord {
    pub fn from_fw(t: FwTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = (1..=t.objs.len()).collect();
        RepRecord { total_s, objs: t.objs, obj_iters, step_s: t.epoch_s }
    }

    pub fn from_sqn(t: SqnTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = t.checkpoints.iter().map(|&(k, _)| k).collect();
        RepRecord {
            total_s,
            objs: t.tracked_losses(),
            obj_iters,
            step_s: t.iter_s,
        }
    }

    /// RSE trace against this replication's final objective (the paper's
    /// Table-2 definition).
    pub fn rse_trace(&self) -> Vec<f64> {
        stats::rse_trace(&self.objs)
    }

    /// Wire encoding (DESIGN.md §14).  Finite f64s survive the JSON layer
    /// exactly: the writer emits the shortest string that parses back to
    /// the same value, so objective traces round-trip bitwise.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("total_s", num(self.total_s)),
            ("objs", arr(self.objs.iter().map(|&o| num(o)).collect())),
            ("obj_iters",
             arr(self.obj_iters.iter().map(|&i| num(i as f64)).collect())),
            ("step_s", arr(self.step_s.iter().map(|&t| num(t)).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RepRecord> {
        let f64s = |key: &str| -> Result<Vec<f64>> {
            v.get(key)
                .and_then(Value::as_arr)
                .with_context(|| format!("record '{}' must be an array", key))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .with_context(|| format!("record '{}' holds a \
                                                  non-number", key))
                })
                .collect()
        };
        Ok(RepRecord {
            total_s: v.get("total_s").and_then(Value::as_f64)
                .context("record 'total_s' must be a number")?,
            objs: f64s("objs")?,
            obj_iters: f64s("obj_iters")?
                .into_iter()
                .map(|i| i as usize)
                .collect(),
            step_s: f64s("step_s")?,
        })
    }
}

/// Aggregated outcome of one experiment cell.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub spec: ExperimentSpec,
    pub reps: Vec<RepRecord>,
    /// True when the replication axis executed through the batched engine
    /// (DESIGN.md §11).  Batched wall-clock is attributed to replications
    /// as `batch_time / R`, so the cross-replication ±2σ TIMING band is
    /// methodologically n/a — the report renderers mark it so instead of
    /// printing a fake ±0.00.
    pub batched: bool,
    /// Shard count of the resolved plan (DESIGN.md §13): 1 for sequential
    /// runs and the unsharded batched engine, S for `--shards S`.  Timing
    /// attribution stays `batch_time / R` whatever S is.
    pub shards: usize,
}

impl RunResult {
    pub fn new(spec: ExperimentSpec, reps: Vec<RepRecord>) -> Self {
        RunResult { spec, reps, batched: false, shards: 1 }
    }

    /// Record the execution plan that actually ran (set by the coordinator
    /// after resolving `ExecMode::Auto`): `None` = sequential,
    /// `Some(shards)` = the shard-aware batched plane.
    pub fn executed(mut self, plan: Option<usize>) -> Self {
        self.batched = plan.is_some();
        self.shards = plan.unwrap_or(1);
        self
    }

    /// Mean/σ of total runtime across replications.
    pub fn time_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            s.push(r.total_s);
        }
        s
    }

    /// Mean/σ of per-step (epoch or iteration) time across all reps+steps.
    pub fn step_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            for &v in &r.step_s {
                s.push(v);
            }
        }
        s
    }

    /// (mean, std) of the RSE at trace index `idx` across replications.
    pub fn rse_at_index(&self, idx: usize) -> (f64, f64) {
        let vals: Vec<f64> = self
            .reps
            .iter()
            .map(|r| stats::at_checkpoint(&r.rse_trace(), idx))
            .filter(|v| v.is_finite())
            .collect();
        (stats::mean(&vals), stats::std(&vals))
    }

    /// RSE checkpoints at fractional positions of the trace (e.g. 0.05 =
    /// 5% through the run), as (fraction, iteration, mean, std).
    pub fn rse_checkpoints(&self, fracs: &[f64]) -> Vec<(f64, usize, f64, f64)> {
        let len = self.reps.first().map(|r| r.objs.len()).unwrap_or(0);
        if len == 0 {
            return Vec::new();
        }
        fracs
            .iter()
            .map(|&f| {
                let idx = ((len as f64 * f).round() as usize).min(len - 1);
                let it = self
                    .reps
                    .first()
                    .map(|r| r.obj_iters.get(idx).copied().unwrap_or(idx))
                    .unwrap_or(idx);
                let (m, s) = self.rse_at_index(idx);
                (f, it, m, s)
            })
            .collect()
    }

    /// Final objective statistics across replications (accuracy agreement).
    pub fn final_obj_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            if let Some(&o) = r.objs.last() {
                s.push(o);
            }
        }
        s
    }

    /// Full wire encoding (DESIGN.md §14): spec + resolved plan + every
    /// replication record, timings included.  This is what a `result`
    /// frame carries.  The embedded spec is its *canonical* form
    /// (`results_dir` omitted): a result describes a computation, and
    /// where one submitter asked for delivery must not leak into the
    /// payload another submitter receives from the cache.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("spec", self.spec.canonical_json()),
            ("batched", Value::Bool(self.batched)),
            ("shards", num(self.shards as f64)),
            ("records",
             arr(self.reps.iter().map(RepRecord::to_json).collect())),
        ])
    }

    /// The *deterministic* payload — [`RunResult::to_json`] with the
    /// timing measurements (`total_s`, `step_s`) dropped from every
    /// record.  Two runs of the same spec produce byte-identical canonical
    /// payloads however they executed (direct or served, any exec plan on
    /// the native arm), which is exactly what the service conformance
    /// suite and the CI serve-vs-run diff compare; wall-clock is a
    /// measurement *about* a run, not part of its result.
    pub fn canonical_json(&self) -> Value {
        obj(vec![
            ("spec", self.spec.canonical_json()),
            ("batched", Value::Bool(self.batched)),
            ("shards", num(self.shards as f64)),
            ("records",
             arr(self.reps
                 .iter()
                 .map(|r| obj(vec![
                     ("objs",
                      arr(r.objs.iter().map(|&o| num(o)).collect())),
                     ("obj_iters",
                      arr(r.obj_iters
                          .iter()
                          .map(|&i| num(i as f64))
                          .collect())),
                 ]))
                 .collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RunResult> {
        let spec = ExperimentSpec::from_json(
            v.get("spec").context("result is missing 'spec'")?)?;
        let reps = v
            .get("records")
            .and_then(Value::as_arr)
            .context("result 'records' must be an array")?
            .iter()
            .map(RepRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(RunResult {
            spec,
            reps,
            batched: v.get("batched").and_then(Value::as_bool)
                .context("result 'batched' must be a bool")?,
            shards: v.get("shards").and_then(Value::as_usize)
                .context("result 'shards' must be an integer")?,
        })
    }

    pub fn summary(&self) -> String {
        let t = self.time_stats();
        format!(
            "{}: {} reps, total {:.3}s ±{:.3}s, final obj {:.6} ±{:.6}",
            self.spec.label(),
            self.reps.len(),
            t.mean(),
            2.0 * t.std(),
            self.final_obj_stats().mean(),
            2.0 * self.final_obj_stats().std(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HessianMode;
    use crate::config::{BackendKind, ExecMode, TaskKind, TaskParams};

    fn dummy_spec() -> ExperimentSpec {
        ExperimentSpec {
            task: TaskKind::MeanVariance,
            backend: BackendKind::Native,
            size: 8,
            reps: 2,
            seed: 1,
            hessian_mode: HessianMode::Explicit,
            track_every: 1,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(TaskKind::MeanVariance, 8),
            results_dir: None,
        }
    }

    fn rec(objs: Vec<f64>, step: f64) -> RepRecord {
        let n = objs.len();
        RepRecord {
            total_s: step * n as f64,
            objs,
            obj_iters: (1..=n).collect(),
            step_s: vec![step; n],
        }
    }

    #[test]
    fn from_fw_preserves_trace() {
        let t = FwTrace { objs: vec![3.0, 2.0, 1.0], epoch_s: vec![0.1; 3] };
        let r = RepRecord::from_fw(t);
        assert_eq!(r.objs, vec![3.0, 2.0, 1.0]);
        assert!((r.total_s - 0.3).abs() < 1e-12);
        assert_eq!(r.obj_iters, vec![1, 2, 3]);
    }

    #[test]
    fn time_stats_aggregates() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![2.0, 1.0], 0.5),
            rec(vec![2.0, 1.0], 1.5),
        ]);
        let t = rr.time_stats();
        assert!((t.mean() - 2.0).abs() < 1e-12); // (1.0 + 3.0)/2
        assert_eq!(t.count(), 2);
        let s = rr.step_stats();
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn rse_checkpoints_shape() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![10.0, 5.0, 2.0, 1.0], 0.1),
            rec(vec![8.0, 4.0, 2.0, 1.0], 0.1),
        ]);
        let cps = rr.rse_checkpoints(&[0.0, 0.5, 1.0]);
        assert_eq!(cps.len(), 3);
        // early checkpoint has higher RSE than the final one (which is 0)
        assert!(cps[0].2 > cps[2].2);
        assert_eq!(cps[2].2, 0.0);
    }

    #[test]
    fn empty_runs_dont_panic() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert_eq!(rr.time_stats().count(), 0);
        assert!(rr.rse_checkpoints(&[0.5]).is_empty());
    }

    #[test]
    fn summary_contains_label() {
        let rr = RunResult::new(dummy_spec(), vec![rec(vec![1.0], 0.1)]);
        assert!(rr.summary().contains("mean_variance_native_d8"));
    }

    #[test]
    fn wire_roundtrip_preserves_records_bitwise() {
        // awkward values on purpose: non-representable decimals, subnormal
        // scale, an exact integer (exercises the writer's integer path)
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![0.1 + 0.2, 3.0, -1.0e-300, 0.123456789012345678], 0.37),
            rec(vec![1.0 / 3.0, f64::MIN_POSITIVE, 2.0f64.powi(-40)], 0.01),
        ]).executed(Some(2));
        let text = rr.to_json().to_string_compact();
        let back = RunResult::from_json(
            &crate::util::json::Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.reps.len(), rr.reps.len());
        for (a, b) in rr.reps.iter().zip(&back.reps) {
            // bit-level, not just ==: the wire layer must not perturb a ulp
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&a.objs), bits(&b.objs));
            assert_eq!(bits(&a.step_s), bits(&b.step_s));
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
            assert_eq!(a.obj_iters, b.obj_iters);
        }
        assert!(back.batched);
        assert_eq!(back.shards, 2);
        assert_eq!(back.to_json().to_string_compact(), text);
    }

    #[test]
    fn canonical_payload_drops_timings_only() {
        let a = RunResult::new(dummy_spec(), vec![rec(vec![2.0, 1.0], 0.5)]);
        let mut b = RunResult::new(dummy_spec(),
                                   vec![rec(vec![2.0, 1.0], 0.9)]);
        b.reps[0].total_s = 123.0;
        // same objectives, different wall-clock: canonical payloads agree…
        assert_eq!(a.canonical_json().to_string_pretty(),
                   b.canonical_json().to_string_pretty());
        // …full wire payloads don't
        assert_ne!(a.to_json().to_string_compact(),
                   b.to_json().to_string_compact());
        // and a different objective shows up in the canonical form
        let c = RunResult::new(dummy_spec(), vec![rec(vec![2.0, 1.1], 0.5)]);
        assert_ne!(a.canonical_json().to_string_pretty(),
                   c.canonical_json().to_string_pretty());
    }

    #[test]
    fn executed_plan_marks_result() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert!(!rr.batched, "sequential is the default attribution");
        assert_eq!(rr.shards, 1);
        let seq = RunResult::new(dummy_spec(), vec![]).executed(None);
        assert!(!seq.batched);
        assert_eq!(seq.shards, 1);
        let sharded = RunResult::new(dummy_spec(), vec![]).executed(Some(3));
        assert!(sharded.batched);
        assert_eq!(sharded.shards, 3);
    }
}
