//! Per-replication records and cross-replication aggregation — the
//! statistics behind Figure 2 (time mean ± 2σ) and Table 2 (RSE ± 2σ at
//! checkpoints).

use anyhow::{Context, Result};

use crate::opt::{FwTrace, SqnTrace};
use crate::util::json::{arr, num, obj, s, Value};
use crate::util::profile::Profiler;
use crate::util::stats::{self, OnlineStats};

use super::experiment::ExperimentSpec;

/// One replication's outcome.
#[derive(Debug, Clone)]
pub struct RepRecord {
    /// Total optimization wall-clock (tracking excluded).
    pub total_s: f64,
    /// Objective trace (per epoch for FW, per checkpoint for SQN).
    pub objs: Vec<f64>,
    /// Iteration indices the objective trace corresponds to.
    pub obj_iters: Vec<usize>,
    /// Wall-clock per epoch/iteration.
    pub step_s: Vec<f64>,
}

impl RepRecord {
    pub fn from_fw(t: FwTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = (1..=t.objs.len()).collect();
        RepRecord { total_s, objs: t.objs, obj_iters, step_s: t.epoch_s }
    }

    pub fn from_sqn(t: SqnTrace) -> Self {
        let total_s = t.total_s();
        let obj_iters = t.checkpoints.iter().map(|&(k, _)| k).collect();
        RepRecord {
            total_s,
            objs: t.tracked_losses(),
            obj_iters,
            step_s: t.iter_s,
        }
    }

    /// RSE trace against this replication's final objective (the paper's
    /// Table-2 definition).
    pub fn rse_trace(&self) -> Vec<f64> {
        stats::rse_trace(&self.objs)
    }

    /// The v1 per-record wire encoding (flat timing keys inline) — what
    /// [`RunResult::to_json_legacy`] still renders verbatim for deployed
    /// v1 clients.  Finite f64s survive the JSON layer exactly: the
    /// writer emits the shortest string that parses back to the same
    /// value, so objective traces round-trip bitwise.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("total_s", num(self.total_s)),
            ("objs", arr(self.objs.iter().map(|&o| num(o)).collect())),
            ("obj_iters",
             arr(self.obj_iters.iter().map(|&i| num(i as f64)).collect())),
            ("step_s", arr(self.step_s.iter().map(|&t| num(t)).collect())),
        ])
    }

    /// The timing-free record core (`objs` + `obj_iters`) — what the v2
    /// payload and the canonical payload embed per record; the v2 form
    /// moves the measurements into the result-level `"timing"` object.
    fn core_json(&self) -> Value {
        obj(vec![
            ("objs", arr(self.objs.iter().map(|&o| num(o)).collect())),
            ("obj_iters",
             arr(self.obj_iters.iter().map(|&i| num(i as f64)).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RepRecord> {
        let f64s = |key: &str| -> Result<Vec<f64>> {
            v.get(key)
                .and_then(Value::as_arr)
                .with_context(|| format!("record '{}' must be an array", key))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .with_context(|| format!("record '{}' holds a \
                                                  non-number", key))
                })
                .collect()
        };
        Ok(RepRecord {
            // v2 records carry no inline timings (they ride the result's
            // "timing" object, re-attached by RunResult::from_json); the
            // legacy flat keys still parse when present.
            total_s: match v.get("total_s") {
                None | Some(Value::Null) => 0.0,
                Some(t) => t.as_f64()
                    .context("record 'total_s' must be a number")?,
            },
            objs: f64s("objs")?,
            obj_iters: f64s("obj_iters")?
                .into_iter()
                .map(|i| i as usize)
                .collect(),
            step_s: match v.get("step_s") {
                None | Some(Value::Null) => Vec::new(),
                Some(_) => f64s("step_s")?,
            },
        })
    }
}

/// Aggregated outcome of one experiment cell.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub spec: ExperimentSpec,
    pub reps: Vec<RepRecord>,
    /// True when the replication axis executed through the batched engine
    /// (DESIGN.md §11).  Batched wall-clock is attributed to replications
    /// as `batch_time / R`, so the cross-replication ±2σ TIMING band is
    /// methodologically n/a — the report renderers mark it so instead of
    /// printing a fake ±0.00.
    pub batched: bool,
    /// Shard count of the resolved plan (DESIGN.md §13): 1 for sequential
    /// runs and the unsharded batched engine, S for `--shards S`.  Timing
    /// attribution stays `batch_time / R` whatever S is.
    pub shards: usize,
    /// `(replication, 1-based epoch)` freeze decisions an adaptive
    /// replication budget made (DESIGN.md §14), in decision order; empty
    /// when no budget ran or nothing froze.  Part of the payload so a
    /// budgeted run is reproducible from its result alone.
    pub frozen: Vec<(usize, usize)>,
    /// 1-based epoch after which a budget stopped the run early, if one
    /// did.
    pub early_stop: Option<usize>,
    /// Per-phase wall-clock attribution of the whole run (DESIGN.md §15):
    /// merged over replications on the sequential plan, panel-level on the
    /// batched plane.  Always populated by the coordinator; empty on
    /// hand-built results and payloads from pre-profiler producers.
    pub profile: Profiler,
}

impl RunResult {
    pub fn new(spec: ExperimentSpec, reps: Vec<RepRecord>) -> Self {
        RunResult { spec, reps, batched: false, shards: 1,
                    frozen: Vec::new(), early_stop: None,
                    profile: Profiler::new() }
    }

    /// Attach the run's per-phase profile (set by the coordinator from
    /// the execution plane's drained accumulators).
    pub fn with_profile(mut self, profile: Profiler) -> Self {
        self.profile = profile;
        self
    }

    /// Record the execution plan that actually ran (set by the coordinator
    /// after resolving `ExecMode::Auto`): `None` = sequential,
    /// `Some(shards)` = the shard-aware batched plane.
    pub fn executed(mut self, plan: Option<usize>) -> Self {
        self.batched = plan.is_some();
        self.shards = plan.unwrap_or(1);
        self
    }

    /// Record what an adaptive replication budget did (DESIGN.md §14).
    pub fn with_budget_outcome(mut self, frozen: Vec<(usize, usize)>,
                               early_stop: Option<usize>) -> Self {
        self.frozen = frozen;
        self.early_stop = early_stop;
        self
    }

    /// Mean/σ of total runtime across replications.
    pub fn time_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            s.push(r.total_s);
        }
        s
    }

    /// Mean/σ of per-step (epoch or iteration) time across all reps+steps.
    pub fn step_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            for &v in &r.step_s {
                s.push(v);
            }
        }
        s
    }

    /// (mean, std) of the RSE at trace index `idx` across replications.
    pub fn rse_at_index(&self, idx: usize) -> (f64, f64) {
        let vals: Vec<f64> = self
            .reps
            .iter()
            .map(|r| stats::at_checkpoint(&r.rse_trace(), idx))
            .filter(|v| v.is_finite())
            .collect();
        (stats::mean(&vals), stats::std(&vals))
    }

    /// RSE checkpoints at fractional positions of the trace (e.g. 0.05 =
    /// 5% through the run), as (fraction, iteration, mean, std).
    pub fn rse_checkpoints(&self, fracs: &[f64]) -> Vec<(f64, usize, f64, f64)> {
        let len = self.reps.first().map(|r| r.objs.len()).unwrap_or(0);
        if len == 0 {
            return Vec::new();
        }
        fracs
            .iter()
            .map(|&f| {
                let idx = ((len as f64 * f).round() as usize).min(len - 1);
                let it = self
                    .reps
                    .first()
                    .map(|r| r.obj_iters.get(idx).copied().unwrap_or(idx))
                    .unwrap_or(idx);
                let (m, s) = self.rse_at_index(idx);
                (f, it, m, s)
            })
            .collect()
    }

    /// Final objective statistics across replications (accuracy agreement).
    pub fn final_obj_stats(&self) -> OnlineStats {
        let mut s = OnlineStats::new();
        for r in &self.reps {
            if let Some(&o) = r.objs.last() {
                s.push(o);
            }
        }
        s
    }

    /// Append the budget-outcome keys (only when a budget acted) — shared
    /// by the `"plan"` object and the legacy flat payload.
    fn push_budget_keys(&self, kv: &mut Vec<(&'static str, Value)>) {
        if !self.frozen.is_empty() {
            kv.push(("frozen", arr(self.frozen.iter()
                .map(|&(r, e)| arr(vec![num(r as f64), num(e as f64)]))
                .collect())));
        }
        if let Some(e) = self.early_stop {
            kv.push(("early_stop", num(e as f64)));
        }
    }

    /// The structured `"plan"` object both payload forms embed: the
    /// resolved execution plan plus (only when a budget acted) the freeze
    /// decisions and the early-stop epoch.  Budget-off payloads carry
    /// exactly `{"exec", "shards"}`.
    fn plan_json(&self) -> Value {
        let mut kv = vec![
            ("exec", s(if self.batched { "batched" } else { "sequential" })),
            ("shards", num(self.shards as f64)),
        ];
        self.push_budget_keys(&mut kv);
        obj(kv)
    }

    /// The structured `"timing"` object (DESIGN.md §15) the v2 payload
    /// embeds — the same fold the PR 6 `"plan"` object performed on the
    /// flat exec keys, applied to the measurements: aggregate wall-clock,
    /// the per-phase attribution, how batched wall-clock was attributed
    /// to replications, and the per-replication timing vectors the flat
    /// v1 records used to carry inline.
    fn timing_json(&self) -> Value {
        obj(vec![
            ("total_s",
             num(self.reps.iter().map(|r| r.total_s).sum::<f64>())),
            ("per_phase", self.profile.to_json()),
            ("attribution",
             s(if self.batched { "batch_s/R" } else { "wall" })),
            ("per_rep",
             arr(self.reps
                 .iter()
                 .map(|r| obj(vec![
                     ("total_s", num(r.total_s)),
                     ("step_s",
                      arr(r.step_s.iter().map(|&t| num(t)).collect())),
                 ]))
                 .collect())),
        ])
    }

    /// Full wire encoding (DESIGN.md §14): spec + resolved plan + the
    /// structured `"timing"` object + every replication record.  This is
    /// what a `result` frame carries.  The embedded spec is its
    /// *canonical* form (`results_dir` omitted): a result describes a
    /// computation, and where one submitter asked for delivery must not
    /// leak into the payload another submitter receives from the cache.
    /// Records are timing-free in this form — the measurements ride
    /// `"timing"` (aligned `per_rep` entries plus the per-phase profile);
    /// [`RunResult::from_json`] still accepts the pre-v2 flat record
    /// timings and `batched`/`shards` keys so old `--out` files and
    /// cached entries round-trip.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("spec", self.spec.canonical_json()),
            ("plan", self.plan_json()),
            ("timing", self.timing_json()),
            ("records",
             arr(self.reps.iter().map(RepRecord::core_json).collect())),
        ])
    }

    /// The pre-v2 wire encoding: [`RunResult::to_json`] with the plan as
    /// the flat top-level `batched`/`shards` keys the v1 grammar used.
    /// A v1 conversation's `result` frame must carry this form — a
    /// deployed v1 client's `from_json` is strict about those keys and
    /// has never heard of `"plan"`.  Budget outcomes ride as extra
    /// top-level keys: a v1 parser ignores unknown keys, and
    /// [`RunResult::from_json`]'s legacy branch reads them back so this
    /// form round-trips too.
    pub fn to_json_legacy(&self) -> Value {
        let mut kv = vec![
            ("spec", self.spec.canonical_json()),
            ("batched", Value::Bool(self.batched)),
            ("shards", num(self.shards as f64)),
        ];
        self.push_budget_keys(&mut kv);
        kv.push(("records",
                 arr(self.reps.iter().map(RepRecord::to_json).collect())));
        obj(kv)
    }

    /// The *deterministic* payload — [`RunResult::to_json`] with the
    /// whole `"timing"` object dropped (records are already timing-free
    /// in v2).  Two runs of the same spec produce byte-identical canonical
    /// payloads however they executed (direct or served, any exec plan on
    /// the native arm), which is exactly what the service conformance
    /// suite and the CI serve-vs-run diff compare; wall-clock is a
    /// measurement *about* a run, not part of its result.
    pub fn canonical_json(&self) -> Value {
        obj(vec![
            ("spec", self.spec.canonical_json()),
            ("plan", self.plan_json()),
            ("records",
             arr(self.reps.iter().map(RepRecord::core_json).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RunResult> {
        let spec = ExperimentSpec::from_json(
            v.get("spec").context("result is missing 'spec'")?)?;
        let mut reps = v
            .get("records")
            .and_then(Value::as_arr)
            .context("result 'records' must be an array")?
            .iter()
            .map(RepRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        // v2 timing fold: re-attach the per-rep measurements the flat v1
        // records carried inline, and read the per-phase profile
        let mut profile = Profiler::new();
        if let Some(t) = v.get("timing") {
            if let Some(pp) = t.get("per_phase") {
                profile = Profiler::from_json(pp)
                    .context("parsing timing 'per_phase'")?;
            }
            if let Some(per_rep) = t.get("per_rep").and_then(Value::as_arr) {
                anyhow::ensure!(per_rep.len() == reps.len(),
                                "timing 'per_rep' must align with records");
                for (rec, tv) in reps.iter_mut().zip(per_rep) {
                    rec.total_s = tv.get("total_s").and_then(Value::as_f64)
                        .context("per_rep 'total_s' must be a number")?;
                    rec.step_s = tv.get("step_s").and_then(Value::as_arr)
                        .context("per_rep 'step_s' must be an array")?
                        .iter()
                        .map(|x| x.as_f64()
                            .context("per_rep 'step_s' holds a non-number"))
                        .collect::<Result<Vec<_>>>()?;
                }
            }
        }
        // budget-outcome keys, read off the `"plan"` object (v2) or the
        // payload's top level (legacy form) — same grammar either way
        fn budget_keys(holder: &Value)
            -> Result<(Vec<(usize, usize)>, Option<usize>)> {
            let frozen = match holder.get("frozen") {
                None | Some(Value::Null) => Vec::new(),
                Some(fv) => fv.as_arr()
                    .context("'frozen' must be an array")?
                    .iter()
                    .map(|pair| {
                        let p = pair.as_arr()
                            .filter(|p| p.len() == 2)
                            .context("'frozen' entries must be \
                                      [rep, epoch] pairs")?;
                        Ok((p[0].as_usize()
                                .context("frozen rep must be an integer")?,
                            p[1].as_usize()
                                .context("frozen epoch must be an \
                                          integer")?))
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            let early_stop = match holder.get("early_stop") {
                None | Some(Value::Null) => None,
                Some(e) => Some(e.as_usize()
                    .context("'early_stop' must be an integer")?),
            };
            Ok((frozen, early_stop))
        }
        let (batched, shards, frozen, early_stop) =
            if let Some(plan) = v.get("plan") {
                let exec = plan.get("exec").and_then(Value::as_str)
                    .context("plan 'exec' must be a string")?;
                let batched = match exec {
                    "batched" => true,
                    "sequential" => false,
                    other => anyhow::bail!("unknown plan exec '{}'", other),
                };
                let shards = plan.get("shards").and_then(Value::as_usize)
                    .context("plan 'shards' must be an integer")?;
                let (frozen, early_stop) = budget_keys(plan)?;
                (batched, shards, frozen, early_stop)
            } else {
                // the legacy flat form: pre-v2 `--out` files and cached
                // entries, and what `to_json_legacy` renders for v1
                // conversations
                let (frozen, early_stop) = budget_keys(v)?;
                (v.get("batched").and_then(Value::as_bool)
                     .context("result 'batched' must be a bool")?,
                 v.get("shards").and_then(Value::as_usize)
                     .context("result 'shards' must be an integer")?,
                 frozen,
                 early_stop)
            };
        Ok(RunResult { spec, reps, batched, shards, frozen, early_stop,
                       profile })
    }

    pub fn summary(&self) -> String {
        let t = self.time_stats();
        format!(
            "{}: {} reps, total {:.3}s ±{:.3}s, final obj {:.6} ±{:.6}",
            self.spec.label(),
            self.reps.len(),
            t.mean(),
            2.0 * t.std(),
            self.final_obj_stats().mean(),
            2.0 * self.final_obj_stats().std(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HessianMode;
    use crate::config::{BackendKind, ExecMode, TaskKind, TaskParams};

    fn dummy_spec() -> ExperimentSpec {
        ExperimentSpec {
            task: TaskKind::MeanVariance,
            backend: BackendKind::Native,
            size: 8,
            reps: 2,
            seed: 1,
            hessian_mode: HessianMode::Explicit,
            track_every: 1,
            exec: ExecMode::Auto,
            params: TaskParams::defaults(TaskKind::MeanVariance, 8),
            budget: None,
            results_dir: None,
        }
    }

    fn rec(objs: Vec<f64>, step: f64) -> RepRecord {
        let n = objs.len();
        RepRecord {
            total_s: step * n as f64,
            objs,
            obj_iters: (1..=n).collect(),
            step_s: vec![step; n],
        }
    }

    #[test]
    fn from_fw_preserves_trace() {
        let t = FwTrace { objs: vec![3.0, 2.0, 1.0], epoch_s: vec![0.1; 3],
                          ..FwTrace::default() };
        let r = RepRecord::from_fw(t);
        assert_eq!(r.objs, vec![3.0, 2.0, 1.0]);
        assert!((r.total_s - 0.3).abs() < 1e-12);
        assert_eq!(r.obj_iters, vec![1, 2, 3]);
    }

    #[test]
    fn time_stats_aggregates() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![2.0, 1.0], 0.5),
            rec(vec![2.0, 1.0], 1.5),
        ]);
        let t = rr.time_stats();
        assert!((t.mean() - 2.0).abs() < 1e-12); // (1.0 + 3.0)/2
        assert_eq!(t.count(), 2);
        let s = rr.step_stats();
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn rse_checkpoints_shape() {
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![10.0, 5.0, 2.0, 1.0], 0.1),
            rec(vec![8.0, 4.0, 2.0, 1.0], 0.1),
        ]);
        let cps = rr.rse_checkpoints(&[0.0, 0.5, 1.0]);
        assert_eq!(cps.len(), 3);
        // early checkpoint has higher RSE than the final one (which is 0)
        assert!(cps[0].2 > cps[2].2);
        assert_eq!(cps[2].2, 0.0);
    }

    #[test]
    fn empty_runs_dont_panic() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert_eq!(rr.time_stats().count(), 0);
        assert!(rr.rse_checkpoints(&[0.5]).is_empty());
    }

    #[test]
    fn summary_contains_label() {
        let rr = RunResult::new(dummy_spec(), vec![rec(vec![1.0], 0.1)]);
        assert!(rr.summary().contains("mean_variance_native_d8"));
    }

    #[test]
    fn wire_roundtrip_preserves_records_bitwise() {
        // awkward values on purpose: non-representable decimals, subnormal
        // scale, an exact integer (exercises the writer's integer path)
        let rr = RunResult::new(dummy_spec(), vec![
            rec(vec![0.1 + 0.2, 3.0, -1.0e-300, 0.123456789012345678], 0.37),
            rec(vec![1.0 / 3.0, f64::MIN_POSITIVE, 2.0f64.powi(-40)], 0.01),
        ]).executed(Some(2));
        let text = rr.to_json().to_string_compact();
        let back = RunResult::from_json(
            &crate::util::json::Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.reps.len(), rr.reps.len());
        for (a, b) in rr.reps.iter().zip(&back.reps) {
            // bit-level, not just ==: the wire layer must not perturb a ulp
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&a.objs), bits(&b.objs));
            assert_eq!(bits(&a.step_s), bits(&b.step_s));
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
            assert_eq!(a.obj_iters, b.obj_iters);
        }
        assert!(back.batched);
        assert_eq!(back.shards, 2);
        assert_eq!(back.to_json().to_string_compact(), text);
    }

    #[test]
    fn canonical_payload_drops_timings_only() {
        let a = RunResult::new(dummy_spec(), vec![rec(vec![2.0, 1.0], 0.5)]);
        let mut b = RunResult::new(dummy_spec(),
                                   vec![rec(vec![2.0, 1.0], 0.9)]);
        b.reps[0].total_s = 123.0;
        // same objectives, different wall-clock: canonical payloads agree…
        assert_eq!(a.canonical_json().to_string_pretty(),
                   b.canonical_json().to_string_pretty());
        // …full wire payloads don't
        assert_ne!(a.to_json().to_string_compact(),
                   b.to_json().to_string_compact());
        // and a different objective shows up in the canonical form
        let c = RunResult::new(dummy_spec(), vec![rec(vec![2.0, 1.1], 0.5)]);
        assert_ne!(a.canonical_json().to_string_pretty(),
                   c.canonical_json().to_string_pretty());
    }

    #[test]
    fn plan_object_replaces_flat_keys_and_carries_budget_outcome() {
        // budget-off payloads carry exactly {"exec", "shards"}
        let plain = RunResult::new(dummy_spec(), vec![rec(vec![1.0], 0.1)])
            .executed(Some(2));
        let text = plain.to_json().to_string_compact();
        assert!(text.contains("\"plan\":{\"exec\":\"batched\",\"shards\":2}"),
                "{}", text);
        assert!(!text.contains("\"frozen\""), "{}", text);
        // budget outcomes ride inside the plan, in both payload forms,
        // and round-trip exactly
        let budgeted = RunResult::new(dummy_spec(),
                                      vec![rec(vec![1.0], 0.1)])
            .executed(Some(1))
            .with_budget_outcome(vec![(2, 4), (0, 8)], Some(12));
        for payload in [budgeted.to_json(), budgeted.canonical_json()] {
            let text = payload.to_string_compact();
            assert!(text.contains("\"frozen\":[[2,4],[0,8]]"), "{}", text);
            assert!(text.contains("\"early_stop\":12"), "{}", text);
        }
        let back = RunResult::from_json(
            &Value::parse(&budgeted.to_json().to_string_compact())
                .unwrap()).unwrap();
        assert_eq!(back.frozen, vec![(2, 4), (0, 8)]);
        assert_eq!(back.early_stop, Some(12));
        assert_eq!(back.to_json().to_string_compact(),
                   budgeted.to_json().to_string_compact());
    }

    #[test]
    fn parser_accepts_legacy_flat_plan_keys() {
        // a pre-v2 payload: plan as flat top-level batched/shards keys
        // (old `--out` files and cached entries must keep parsing)
        let modern = RunResult::new(dummy_spec(),
                                    vec![rec(vec![2.0, 1.0], 0.25)])
            .executed(Some(3));
        let text = modern.to_json().to_string_compact().replace(
            "\"plan\":{\"exec\":\"batched\",\"shards\":3}",
            "\"batched\":true,\"shards\":3");
        assert!(!text.contains("\"plan\""), "substitution failed: {}", text);
        let back =
            RunResult::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert!(back.batched);
        assert_eq!(back.shards, 3);
        assert!(back.frozen.is_empty());
        assert_eq!(back.early_stop, None);
        // the records survived the legacy detour bitwise
        assert_eq!(back.reps[0].objs, modern.reps[0].objs);
        // …and re-rendering emits the modern plan object
        assert!(back.to_json().to_string_compact().contains("\"plan\""));
    }

    #[test]
    fn legacy_render_speaks_the_v1_grammar_and_roundtrips() {
        // what a v1 conversation's result frame carries: the flat
        // top-level batched/shards keys, no "plan" object — exactly what
        // a deployed v1 client's strict parser reads
        let rr = RunResult::new(dummy_spec(),
                                vec![rec(vec![2.0, 1.0], 0.25)])
            .executed(Some(3));
        let text = rr.to_json_legacy().to_string_compact();
        assert!(text.contains("\"batched\":true"), "{}", text);
        assert!(text.contains("\"shards\":3"), "{}", text);
        assert!(!text.contains("\"plan\""), "{}", text);
        // the v1 grammar: no "timing" fold, per-record flat timing keys
        assert!(!text.contains("\"timing\""), "{}", text);
        assert!(text.contains("\"records\":[{\"total_s\":"), "{}", text);
        assert!(text.contains("\"step_s\":[0.25,0.25]"), "{}", text);
        let back = RunResult::from_json(&Value::parse(&text).unwrap())
            .unwrap();
        assert!(back.batched);
        assert_eq!(back.shards, 3);
        assert_eq!(back.reps[0].objs, rr.reps[0].objs);
        // budget outcomes survive the legacy detour too (extra top-level
        // keys a v1 parser ignores, ours reads back)
        let budgeted = RunResult::new(dummy_spec(),
                                      vec![rec(vec![1.0], 0.1)])
            .executed(Some(1))
            .with_budget_outcome(vec![(1, 2)], Some(6));
        let text = budgeted.to_json_legacy().to_string_compact();
        assert!(text.contains("\"frozen\":[[1,2]]"), "{}", text);
        let back = RunResult::from_json(&Value::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back.frozen, vec![(1, 2)]);
        assert_eq!(back.early_stop, Some(6));
    }

    #[test]
    fn timing_fold_mirrors_the_plan_fold_and_roundtrips() {
        use crate::util::profile::Phase;
        let mut prof = Profiler::new();
        prof.add(Phase::Compute, 0.75);
        prof.add(Phase::Dispatch, 0.25);
        let rr = RunResult::new(dummy_spec(),
                                vec![rec(vec![2.0, 1.0], 0.5)])
            .executed(None)
            .with_profile(prof);
        let text = rr.to_json().to_string_compact();
        // the fold: ONE structured "timing" object (the PR 6 "plan" fold
        // applied to the measurements), timing-free records
        assert!(text.contains(
            "\"timing\":{\"total_s\":1,\
             \"per_phase\":{\"dispatch\":0.25,\"compute\":0.75},\
             \"attribution\":\"wall\","), "{}", text);
        assert!(text.contains("\"records\":[{\"objs\":"), "{}", text);
        assert!(!text.contains("\"records\":[{\"total_s\""), "{}", text);
        // an `--out` / cached payload round-trips: measurements, profile,
        // and bytes
        let back =
            RunResult::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.profile, rr.profile);
        assert_eq!(back.reps[0].total_s.to_bits(), 1.0f64.to_bits());
        assert_eq!(back.reps[0].step_s, vec![0.5, 0.5]);
        assert_eq!(back.to_json().to_string_compact(), text);
        // batched runs label their per-replication attribution rule
        let b = RunResult::new(dummy_spec(), vec![rec(vec![1.0], 0.1)])
            .executed(Some(2));
        assert!(b.to_json().to_string_compact()
            .contains("\"attribution\":\"batch_s/R\""));
        // …and the canonical payload never grows a timing key
        assert!(!b.canonical_json().to_string_compact()
            .contains("\"timing\""));
    }

    #[test]
    fn executed_plan_marks_result() {
        let rr = RunResult::new(dummy_spec(), vec![]);
        assert!(!rr.batched, "sequential is the default attribution");
        assert_eq!(rr.shards, 1);
        let seq = RunResult::new(dummy_spec(), vec![]).executed(None);
        assert!(!seq.batched);
        assert_eq!(seq.shards, 1);
        let sharded = RunResult::new(dummy_spec(), vec![]).executed(Some(3));
        assert!(sharded.batched);
        assert_eq!(sharded.shards, 3);
    }
}
