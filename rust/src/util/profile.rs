//! Always-on per-phase profiling (DESIGN.md §15).
//!
//! Every run attributes its wall-clock to a fixed taxonomy of six phases.
//! The accumulator is a plain `[f64; 6]` — adding a sample is one array
//! store, reading the monotonic clock is the only real cost, and every
//! probe *read* (draining a backend's accumulator, serializing totals)
//! happens outside the timed regions, so profiling never perturbs the
//! optimization arithmetic or the recorded step timings beyond the
//! nanosecond-scale clock reads themselves.
//!
//! Attribution is cooperative and drain-based: backends accumulate their
//! own dispatch/compute/reduce splits into a private [`Profiler`] and
//! expose it via `take_profile` (drain semantics — returns everything
//! accumulated since the last drain and resets), and the driver-level
//! hooks drain at phase boundaries so no interval is ever counted twice.

use std::fmt;

use anyhow::Result;

use crate::util::json::{num, obj, Value};

/// The fixed phase taxonomy (DESIGN.md §15).
///
/// * `Dispatch` — staging, slicing, buffer uploads, key routing: the work
///   of getting a kernel launched (the overhead Lee et al. show dominates
///   at small batch sizes).
/// * `Compute` — the kernel itself (MC panel simulation, gradients, HVPs).
/// * `Reduce` — copy-out, merging shard outputs, objective reduction.
/// * `Lmo` — host-side LMO solves (the newsvendor LP).
/// * `Direction` — the Algorithm-4 two-loop / explicit H·g application.
/// * `FreezeCheck` — the adaptive-budget checkpoint logic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Dispatch,
    Compute,
    Reduce,
    Lmo,
    Direction,
    FreezeCheck,
}

impl Phase {
    /// Every phase, in canonical wire order.
    pub const ALL: [Phase; 6] = [
        Phase::Dispatch,
        Phase::Compute,
        Phase::Reduce,
        Phase::Lmo,
        Phase::Direction,
        Phase::FreezeCheck,
    ];

    /// Canonical wire / report name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Dispatch => "dispatch",
            Phase::Compute => "compute",
            Phase::Reduce => "reduce",
            Phase::Lmo => "lmo",
            Phase::Direction => "direction",
            Phase::FreezeCheck => "freeze_check",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.as_str() == s)
    }

    fn index(self) -> usize {
        match self {
            Phase::Dispatch => 0,
            Phase::Compute => 1,
            Phase::Reduce => 2,
            Phase::Lmo => 3,
            Phase::Direction => 4,
            Phase::FreezeCheck => 5,
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-phase wall-clock accumulator.  `Copy` on purpose: a step's profile
/// rides a [`crate::opt::StepEvent`] by value, and merging is six adds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Profiler {
    totals: [f64; 6],
}

impl Profiler {
    pub fn new() -> Self {
        Profiler::default()
    }

    /// Accumulate `secs` into `phase`.  Negative or non-finite samples
    /// (clock noise on near-zero residuals) are dropped, never subtracted.
    pub fn add(&mut self, phase: Phase, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            self.totals[phase.index()] += secs;
        }
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.totals[phase.index()]
    }

    /// Sum over every phase.
    pub fn sum(&self) -> f64 {
        self.totals.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.totals.iter().all(|&t| t == 0.0)
    }

    pub fn merge(&mut self, other: &Profiler) {
        for (a, b) in self.totals.iter_mut().zip(&other.totals) {
            *a += b;
        }
    }

    /// Drain: return everything accumulated since the last drain and
    /// reset.  Backends expose their splits this way so a caller that
    /// also timed the enclosing wall can attribute the residual without
    /// double counting.
    pub fn take(&mut self) -> Profiler {
        std::mem::take(self)
    }

    /// `{"dispatch": s, ...}` with zero phases omitted, in canonical
    /// phase order — deterministic for byte-diffing payloads.
    pub fn to_json(&self) -> Value {
        obj(Phase::ALL
            .iter()
            .filter(|p| self.get(**p) != 0.0)
            .map(|p| (p.as_str(), num(self.get(*p))))
            .collect())
    }

    /// Parse a `per_phase` object.  Unknown keys are ignored (forward
    /// compatibility: a newer producer may know more phases).
    pub fn from_json(v: &Value) -> Result<Profiler> {
        let entries = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("per_phase must be an object"))?;
        let mut prof = Profiler::new();
        for (key, val) in entries {
            if let Some(phase) = Phase::parse(key) {
                let secs = val.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("phase '{}' must be a number", key)
                })?;
                prof.add(phase, secs);
            }
        }
        Ok(prof)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_roundtrip_their_names() {
        for p in Phase::ALL {
            assert_eq!(Phase::parse(p.as_str()), Some(p));
        }
        assert_eq!(Phase::parse("warp_drive"), None);
    }

    #[test]
    fn add_merge_take_accumulate_and_drain() {
        let mut a = Profiler::new();
        assert!(a.is_empty());
        a.add(Phase::Compute, 1.5);
        a.add(Phase::Compute, 0.5);
        a.add(Phase::Lmo, 0.25);
        // negative / non-finite samples are dropped, not subtracted
        a.add(Phase::Compute, -4.0);
        a.add(Phase::Reduce, f64::NAN);
        assert_eq!(a.get(Phase::Compute), 2.0);
        assert_eq!(a.get(Phase::Reduce), 0.0);
        assert_eq!(a.sum(), 2.25);

        let mut b = Profiler::new();
        b.add(Phase::Lmo, 0.75);
        a.merge(&b);
        assert_eq!(a.get(Phase::Lmo), 1.0);

        let drained = a.take();
        assert_eq!(drained.get(Phase::Compute), 2.0);
        assert!(a.is_empty(), "take must reset the accumulator");
    }

    #[test]
    fn json_roundtrips_nonzero_phases_in_canonical_order() {
        let mut p = Profiler::new();
        p.add(Phase::Reduce, 0.125);
        p.add(Phase::Dispatch, 2.5);
        let v = p.to_json();
        // canonical order: dispatch before reduce, zero phases omitted
        assert_eq!(v.to_string_compact(),
                   "{\"dispatch\":2.5,\"reduce\":0.125}");
        let back = Profiler::from_json(&v).unwrap();
        assert_eq!(back, p);
        // empty profile serializes to an empty object
        assert_eq!(Profiler::new().to_json().to_string_compact(), "{}");
        assert!(Profiler::from_json(&Profiler::new().to_json())
            .unwrap()
            .is_empty());
    }

    #[test]
    fn from_json_ignores_unknown_phases_and_rejects_non_numbers() {
        let v = Value::parse("{\"compute\": 1.0, \"quantum\": 9.0}").unwrap();
        let p = Profiler::from_json(&v).unwrap();
        assert_eq!(p.get(Phase::Compute), 1.0);
        assert_eq!(p.sum(), 1.0);
        let bad = Value::parse("{\"compute\": \"fast\"}").unwrap();
        assert!(Profiler::from_json(&bad).is_err());
        assert!(Profiler::from_json(&Value::parse("[]").unwrap()).is_err());
    }
}
