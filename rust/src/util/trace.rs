//! Request-scoped tracing: trace ids, spans, and a Chrome-trace JSONL
//! exporter (DESIGN.md §18).
//!
//! The serving plane (DESIGN.md §14) executes a request across three
//! threads — the accept loop, a connection handler, and a warm worker —
//! so no single stack trace ever shows where a request's wall-clock
//! went.  This module makes that life cycle observable: a [`TraceId`]
//! is minted at admission, stamped onto every v2 protocol frame of the
//! conversation, and carried by every [`Span`] the server records for
//! it (admission → cache check → queue wait → per-epoch execution →
//! relay).  Spans share one process-wide monotonic clock
//! ([`now_us`] = `util::timer::monotonic_us`), so intervals recorded on
//! different threads nest and chain exactly.
//!
//! The exporter ([`Tracer`]) appends one Chrome-trace *complete event*
//! (`"ph":"X"`) per line — newline-delimited JSON, each line
//! independently parseable (the compact writer never emits a newline),
//! with `ts`/`dur` in microseconds and the trace id under `args.trace`.
//! Wrap the lines in `[...]` (or load them as-is: the Chrome/Perfetto
//! loaders tolerate newline-separated event streams) to render a
//! request's life in any trace viewer.
//!
//! Invariance bar (same as the §15 profiler): spans are recorded from
//! timestamps taken OUTSIDE the timed regions — before a run starts,
//! after it completes, and from the already-measured `step_s` of a
//! [`StepEvent`] — so a traced run is bitwise-identical to an untraced
//! one.  `tests/trace_invariance.rs` pins that.
//!
//! [`StepEvent`]: crate::opt::StepEvent

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, Value};
use crate::util::timer::monotonic_us;

/// Microseconds on the process-wide monotonic span clock.
pub fn now_us() -> u64 {
    monotonic_us()
}

/// Identity of one request's trace, minted at admission and threaded
/// through every v2 protocol frame (`"trace"` key) and every span the
/// request produces.  Rendered as 16 lowercase hex digits on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(u64);

impl TraceId {
    /// Mint the next id: a per-process wall-clock seed (so traces from
    /// restarted servers don't collide in a merged file) plus a counter.
    /// The value stays below 2^53, so it survives JSON's f64 numerics
    /// when used as a Chrome `tid`.
    pub fn mint() -> TraceId {
        static SEED: OnceLock<u64> = OnceLock::new();
        static NEXT: AtomicU64 = AtomicU64::new(1);
        let seed = *SEED.get_or_init(|| {
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() ^ u64::from(d.subsec_nanos()))
                .unwrap_or(0x9e37_79b9)
        });
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        TraceId(((seed & 0xffff_ffff) << 20) | (n & 0xf_ffff))
    }

    pub fn as_u64(&self) -> u64 {
        self.0
    }

    /// The wire encoding: 16 lowercase hex digits.
    pub fn as_hex(&self) -> String {
        format!("{:016x}", self.0)
    }

    pub fn from_hex(text: &str) -> Option<TraceId> {
        u64::from_str_radix(text, 16).ok().map(TraceId)
    }
}

/// One recorded interval of a request's life.  `start_us`/`dur_us` are
/// on the [`now_us`] clock; `meta` rides into the Chrome event's `args`.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub trace_id: TraceId,
    pub meta: Vec<(String, String)>,
}

impl Span {
    /// Span over `[start_us, end_us]`; a clock tie (`end < start` can
    /// only come from a caller bug) clamps to zero duration.
    pub fn new(trace_id: TraceId, name: &str, start_us: u64, end_us: u64)
        -> Span {
        Span {
            name: name.to_string(),
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            trace_id,
            meta: Vec::new(),
        }
    }

    pub fn with(mut self, key: &str, value: impl std::fmt::Display)
        -> Span {
        self.meta.push((key.to_string(), value.to_string()));
        self
    }

    /// Render as a Chrome-trace *complete event*: `ph:"X"`, `ts`/`dur`
    /// in µs, the full trace id under `args.trace`, and the id's low
    /// 32 bits as `tid` so a viewer lanes spans per request.
    pub fn to_chrome(&self) -> Value {
        let mut args = vec![("trace", s(&self.trace_id.as_hex()))];
        for (k, v) in &self.meta {
            args.push((k.as_str(), s(v)));
        }
        obj(vec![
            ("name", s(&self.name)),
            ("cat", s("simopt")),
            ("ph", s("X")),
            ("ts", num(self.start_us as f64)),
            ("dur", num(self.dur_us as f64)),
            ("pid", num(1.0)),
            ("tid", num((self.trace_id.as_u64() & 0xffff_ffff) as f64)),
            ("args", obj(args)),
        ])
    }
}

/// Span sink: serializes completed spans as Chrome-trace JSONL.  Writes
/// are line-buffered and flushed per span so a reader (or a crashed
/// server's operator) always sees whole lines; the lock is only ever
/// held for one line's formatting + write, far from any timed region.
pub struct Tracer {
    out: Mutex<Box<dyn Write + Send>>,
}

impl Tracer {
    /// Append spans to `path` (created if absent).
    pub fn to_file(path: impl AsRef<Path>) -> Result<Tracer> {
        let path = path.as_ref();
        let file = File::options()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| {
                format!("opening trace output {}", path.display())
            })?;
        Ok(Tracer::to_writer(Box::new(BufWriter::new(file))))
    }

    /// Write spans to an arbitrary sink (tests use an in-memory buffer).
    pub fn to_writer(w: Box<dyn Write + Send>) -> Tracer {
        Tracer { out: Mutex::new(w) }
    }

    /// Serialize one completed span as a single JSONL line.  Sink
    /// failures are swallowed: tracing is an observer and must never
    /// turn a healthy request into an error.
    pub fn record(&self, span: &Span) {
        let mut line = span.to_chrome().to_string_compact();
        line.push('\n');
        if let Ok(mut out) = self.out.lock() {
            let _ = out.write_all(line.as_bytes());
            let _ = out.flush();
        }
    }
}

/// Shared in-memory byte sink for [`Tracer::to_writer`] in tests.
#[derive(Clone, Default)]
pub struct SharedBuf(pub Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_are_unique_hex_and_roundtrip() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert_ne!(a, b);
        for id in [a, b] {
            assert_eq!(id.as_hex().len(), 16);
            assert!(id.as_u64() < (1 << 53), "must survive f64 JSON");
            assert_eq!(TraceId::from_hex(&id.as_hex()), Some(id));
        }
        assert_eq!(TraceId::from_hex("not hex"), None);
    }

    #[test]
    fn span_intervals_clamp_and_carry_meta() {
        let id = TraceId::mint();
        let sp = Span::new(id, "execute", 100, 350).with("task", "mv_d16");
        assert_eq!(sp.dur_us, 250);
        assert_eq!(Span::new(id, "x", 10, 5).dur_us, 0, "tie clamps");
        let chrome = sp.to_chrome();
        assert_eq!(chrome.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(chrome.get("ts").and_then(Value::as_f64), Some(100.0));
        assert_eq!(chrome.get("dur").and_then(Value::as_f64), Some(250.0));
        let args = chrome.get("args").unwrap();
        assert_eq!(args.get("trace").and_then(Value::as_str),
                   Some(id.as_hex().as_str()));
        assert_eq!(args.get("task").and_then(Value::as_str),
                   Some("mv_d16"));
    }

    #[test]
    fn tracer_emits_one_parseable_line_per_span() {
        let buf = SharedBuf::default();
        let tracer = Tracer::to_writer(Box::new(buf.clone()));
        let id = TraceId::mint();
        tracer.record(&Span::new(id, "request", 0, 10));
        tracer.record(&Span::new(id, "execute", 2, 9).with("epoch", 3));
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Value::parse(line).expect("well-formed JSONL");
            assert!(v.get("name").is_some());
            assert_eq!(v.get("args").and_then(|a| a.get("trace"))
                           .and_then(Value::as_str),
                       Some(id.as_hex().as_str()));
        }
    }

    #[test]
    fn now_us_is_monotonic() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
