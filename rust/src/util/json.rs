//! Minimal JSON: a recursive-descent parser and a pretty writer.
//!
//! Covers the full JSON grammar (RFC 8259) minus unicode escapes beyond the
//! BMP; numbers are held as `f64` (every value the manifest and result files
//! use is exactly representable).  Object key order is preserved so written
//! files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(src: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    /// Strict non-negative integer view: `None` for non-numbers,
    /// negatives, fractions, and magnitudes past 2^53 (where f64 stops
    /// representing integers exactly, so "integer" would be ambiguous).
    /// The one definition of "wire integer" shared by the spec and
    /// service-frame decoders (DESIGN.md §14).
    pub fn as_uint(&self) -> Option<u64> {
        match self.as_f64() {
            Some(n) if n >= 0.0
                && n.fract() == 0.0
                && n <= (1u64 << 53) as f64 => Some(n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object entries as a map view (for param lookups).
    pub fn to_map(&self) -> BTreeMap<&str, &Value> {
        match self {
            Value::Obj(o) => o.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line compact encoding — the JSON-lines wire framing of the
    /// experiment service (`service::protocol`): one frame per line, so the
    /// writer must never emit a newline.  Canonical spec hashing also runs
    /// over this form (stable: key order is insertion order, and the number
    /// writer is deterministic).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(sv) => write_str(out, sv),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Value::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

/// Builder helpers so report code stays terse.
pub fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str(" ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, sv: &str) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"hi\\n\"").unwrap(),
            Value::Str("hi\n".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
                   Some("x"));
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Value::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str(), Some("é中"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Value::parse("\"σ±2\"").unwrap();
        assert_eq!(v.as_str(), Some("σ±2"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"open").is_err());
        assert!(Value::parse("nul").is_err());
    }

    #[test]
    fn compact_is_one_line_and_roundtrips() {
        let src = r#"{"name":"mv_epoch","params":{"d":128,"n":64},"inputs":[{"shape":[2],"dtype":"u32"}],"ok":true,"x":null}"#;
        let v = Value::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert!(!compact.contains('\n'), "{}", compact);
        assert!(!compact.contains(' '), "{}", compact);
        assert_eq!(Value::parse(&compact).unwrap(), v);
        // escaped newlines stay escaped, so frames stay one line
        let s = Value::Str("a\nb".to_string()).to_string_compact();
        assert!(!s.contains('\n'));
        assert_eq!(Value::parse(&s).unwrap().as_str(), Some("a\nb"));
        // empty containers
        assert_eq!(Value::Arr(vec![]).to_string_compact(), "[]");
        assert_eq!(Value::Obj(vec![]).to_string_compact(), "{}");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"mv_epoch","params":{"d":128,"n":64},"inputs":[{"shape":[2],"dtype":"u32"}],"ok":true,"x":null}"#;
        let v = Value::parse(src).unwrap();
        let printed = v.to_string_pretty();
        let v2 = Value::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn preserves_key_order() {
        let v = Value::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn as_uint_is_strict() {
        assert_eq!(Value::Num(0.0).as_uint(), Some(0));
        assert_eq!(Value::Num(42.0).as_uint(), Some(42));
        assert_eq!(Value::Num((1u64 << 53) as f64).as_uint(),
                   Some(1u64 << 53));
        assert_eq!(Value::Num(-1.0).as_uint(), None);
        assert_eq!(Value::Num(2.5).as_uint(), None);
        assert_eq!(Value::Num(1e300).as_uint(), None, "past exact-integer \
                    range");
        assert_eq!(Value::Str("3".into()).as_uint(), None);
        assert_eq!(Value::Null.as_uint(), None);
    }

    #[test]
    fn accessors_on_wrong_types_return_none() {
        let v = Value::parse("[1]").unwrap();
        assert!(v.get("x").is_none());
        assert!(v.as_str().is_none());
        assert!(v.idx(5).is_none());
        assert_eq!(v.idx(0).unwrap().as_usize(), Some(1));
        assert_eq!(Value::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![
            ("a", num(1.0)),
            ("b", arr(vec![s("x"), Value::Bool(false)])),
        ]);
        let t = v.to_string_pretty();
        let back = Value::parse(&t).unwrap();
        assert_eq!(back.get("b").unwrap().idx(0).unwrap().as_str(), Some("x"));
    }
}
