//! Leveled, structured (key=value) logging for the CLI and the serving
//! plane (DESIGN.md §18).
//!
//! Every operator-facing diagnostic the binary used to `eprintln!` now
//! goes through here, as one machine-greppable line on stderr:
//!
//! ```text
//! ts=12.345678 level=info target=serve event=listening socket=simopt.sock workers=2
//! ```
//!
//! * `ts` — seconds on the process-wide monotonic clock
//!   (`util::timer::monotonic_us`), the same clock trace spans use, so
//!   log lines and spans correlate directly.
//! * `level` — error | warn | info | debug, gated by the global level
//!   (set from `--log-level`; default `info`).  A disabled event skips
//!   all formatting work.
//! * `target`/`event` — where and what; every further `field()` appends
//!   `key=value`, quoting values that contain spaces, quotes, `=`, or
//!   control characters.
//!
//! This module is the ONLY place in `src/` allowed to call `eprintln!`
//! (satellite bar: the rest of `main.rs`, `server.rs`, and
//! `coordinator/mod.rs` is grep-clean).  Stderr only — stdout stays
//! reserved for command payloads (summaries, tables, prometheus text),
//! and nothing here runs inside a timed region.

use std::fmt::Display;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::timer::monotonic_us;

/// Severity, most to least urgent.  The global level admits everything
/// at or above itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(text: &str) -> Option<Level> {
        match text.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(n: u8) -> Level {
        match n {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            _ => Level::Info,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global gate (what `--log-level` does once per process).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn max_level() -> Level {
    Level::from_u8(MAX_LEVEL.load(Ordering::Relaxed))
}

pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// One structured log line under construction.  Builder style so call
/// sites read as data: `log::info("serve", "listening")
/// .field("socket", path).emit()`.  When the level is gated off, the
/// builder is inert and `field()` formats nothing.
pub struct Event {
    line: Option<String>,
}

fn event(level: Level, target: &str, name: &str) -> Event {
    if !enabled(level) {
        return Event { line: None };
    }
    let mut line = String::with_capacity(80);
    let _ = write!(line, "ts={:.6} level={} target={} event={}",
                   monotonic_us() as f64 / 1e6, level.as_str(), target,
                   name);
    Event { line: Some(line) }
}

pub fn error(target: &str, name: &str) -> Event {
    event(Level::Error, target, name)
}

pub fn warn(target: &str, name: &str) -> Event {
    event(Level::Warn, target, name)
}

pub fn info(target: &str, name: &str) -> Event {
    event(Level::Info, target, name)
}

pub fn debug(target: &str, name: &str) -> Event {
    event(Level::Debug, target, name)
}

fn needs_quoting(v: &str) -> bool {
    v.is_empty()
        || v.contains(|c: char| {
            c == ' ' || c == '"' || c == '=' || c.is_control()
        })
}

impl Event {
    pub fn field(mut self, key: &str, value: impl Display) -> Event {
        if let Some(line) = &mut self.line {
            let rendered = value.to_string();
            if needs_quoting(&rendered) {
                let _ = write!(line, " {}=\"{}\"", key,
                               rendered.replace('\\', "\\\\")
                                   .replace('"', "\\\"")
                                   .replace('\n', "\\n"));
            } else {
                let _ = write!(line, " {}={}", key, rendered);
            }
        }
        self
    }

    /// Write the line to stderr (a no-op when the level was gated off).
    pub fn emit(self) {
        if let Some(line) = self.line {
            eprintln!("{}", line);
        }
    }

    /// The rendered line without emitting it — the testable surface.
    pub fn render(&self) -> Option<&str> {
        self.line.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse(" WARN "), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("trace"), Some(Level::Debug));
        assert_eq!(Level::parse("loud"), None);
        assert!(Level::Error < Level::Debug);
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::from_u8(l as u8), l);
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
    }

    #[test]
    fn lines_are_structured_key_value() {
        let ev = event(Level::Error, "serve", "accept_failed")
            .field("err", "too many open files")
            .field("retries", 3);
        let line = ev.render().unwrap();
        assert!(line.starts_with("ts="), "{}", line);
        assert!(line.contains(" level=error target=serve \
                               event=accept_failed"), "{}", line);
        assert!(line.contains(" err=\"too many open files\""), "{}", line);
        assert!(line.contains(" retries=3"), "{}", line);
    }

    #[test]
    fn quoting_covers_spaces_equals_and_quotes() {
        let line = event(Level::Error, "t", "e")
            .field("plain", "bare-token")
            .field("eq", "a=b")
            .field("quote", "say \"hi\"")
            .field("empty", "")
            .render()
            .unwrap()
            .to_string();
        assert!(line.contains(" plain=bare-token"), "{}", line);
        assert!(line.contains(" eq=\"a=b\""), "{}", line);
        assert!(line.contains(" quote=\"say \\\"hi\\\"\""), "{}", line);
        assert!(line.contains(" empty=\"\""), "{}", line);
    }

    #[test]
    fn gated_levels_format_nothing() {
        // the global level is process state; drive the private surface
        // directly against a throwaway level rather than racing other
        // tests over the global
        let was = max_level();
        set_level(Level::Error);
        let ev = event(Level::Debug, "t", "e").field("k", "v");
        assert!(ev.render().is_none());
        set_level(Level::Debug);
        assert!(event(Level::Debug, "t", "e").render().is_some());
        set_level(was);
    }
}
