//! Worker thread pool + scoped parallel map (no rayon/tokio offline).
//!
//! Three tools:
//! * [`ThreadPool`] — long-lived workers consuming boxed jobs from a shared
//!   queue; used by the coordinator for replication fan-out.
//! * [`parallel_map_chunks`] — scoped data-parallel helper for the
//!   `native_par` ablation backend: splits an index range over N threads and
//!   merges results in order.
//! * [`parallel_try_jobs`] — the disjoint-slice variant for the native batch
//!   engines and the panel LMO (`NvLmo::solve_panel_into`, DESIGN.md §17):
//!   the caller pre-splits its output panel into `&mut` chunks with
//!   [`chunk_len`] + `chunks_mut` (the exact same boundaries
//!   `parallel_map_chunks` would use) and hands one `FnOnce` job per chunk;
//!   no `Mutex`, no merge copy, and a single job runs inline on the calling
//!   thread without touching the heap (DESIGN.md §16).

use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool with a `join`-style barrier.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Msg::Run(job)) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cv.notify_all();
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            }));
        }
        ThreadPool { tx, handles, pending }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job; it may run on any worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.send(Msg::Run(Box::new(f))).expect("pool workers alive");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Scoped parallel map over an index range: calls `f(i)` for `i in 0..n`
/// across `threads` OS threads and returns the results in index order.
///
/// `f` only needs `Sync` borrows — perfect for read-only panels.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // Safety-free: disjoint index writes guarded by the mutex.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|o| o.expect("all indices computed")).collect()
}

/// Split `0..n` into contiguous chunks, run `f(chunk_range)` per thread, and
/// return per-chunk results in order — the shape reductions want.
pub fn parallel_map_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    let ranges: Vec<_> = (0..threads)
        .map(|t| (t * chunk).min(n)..((t + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect();
    let mut out: Vec<Option<T>> = (0..ranges.len()).map(|_| None).collect();
    thread::scope(|s| {
        for (slot, r) in out.iter_mut().zip(ranges) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(r));
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Chunk length [`parallel_map_chunks`] uses for `n` items over `threads`
/// workers — exposed so slice-handing callers can reproduce the exact same
/// split with `chunks_mut` and stay bitwise-aligned with the range-based
/// fan-out (same rows land on the same worker either way).
pub fn chunk_len(n: usize, threads: usize) -> usize {
    let threads = threads.max(1).min(n.max(1));
    n.div_ceil(threads).max(1)
}

/// Run one `FnOnce` job per pre-split chunk of a disjoint workload.
///
/// * an empty iterator is a no-op;
/// * exactly ONE job runs inline on the calling thread — no spawn, no heap
///   traffic, which is what keeps the `threads == 1` native batch hot path
///   allocation-free at steady state (pinned by `tests/alloc_regression.rs`);
/// * two or more jobs run on scoped threads, and the first error in job
///   order is the one propagated (later errors are dropped, matching the
///   old first-error `merge_rows` contract).
///
/// Jobs capture `&mut` slices of the caller's output panel directly
/// (`chunks_mut`-split, hence disjoint), so no per-row `Mutex` and no
/// copy-back merge phase is needed.
pub fn parallel_try_jobs<I, J>(jobs: I) -> Result<()>
where
    I: IntoIterator<Item = J>,
    J: FnOnce() -> Result<()> + Send,
{
    let mut it = jobs.into_iter();
    let first = match it.next() {
        None => return Ok(()),
        Some(j) => j,
    };
    let second = match it.next() {
        None => return first(), // single chunk: inline, zero-alloc
        Some(j) => j,
    };
    thread::scope(|s| {
        let mut handles = Vec::new();
        handles.push(s.spawn(first));
        handles.push(s.spawn(second));
        for job in it {
            handles.push(s.spawn(job));
        }
        let mut err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if err.is_none() {
                        err = Some(e);
                    }
                }
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        match err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    })
}

/// Number of rows in a `len`-element row-major panel with `row_len`-wide
/// rows, or a typed error when the panel is ragged.
///
/// `row_len == 0` is a valid shape only for the empty panel — the retired
/// `len / row_len.max(1)` folklore silently reported `len` rows there.
pub fn panel_rows(len: usize, row_len: usize) -> Result<usize> {
    if row_len == 0 {
        ensure!(len == 0,
                "ragged panel: {} values cannot tile into rows of 0", len);
        return Ok(0);
    }
    ensure!(len % row_len == 0,
            "ragged panel: {} values do not tile into rows of {}",
            len, row_len);
    Ok(len / row_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_then_more_work() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn zero_workers_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<_> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty() {
        let got: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn parallel_map_chunks_covers_range() {
        let chunks = parallel_map_chunks(103, 4, |r| r.len());
        assert_eq!(chunks.iter().sum::<usize>(), 103);
    }

    #[test]
    fn parallel_map_chunks_single_thread() {
        let chunks = parallel_map_chunks(10, 1, |r| (r.start, r.end));
        assert_eq!(chunks, vec![(0, 10)]);
    }

    #[test]
    fn chunk_len_matches_parallel_map_chunks_boundaries() {
        for &(n, threads) in &[(103usize, 4usize), (10, 1), (7, 16), (1, 3),
                               (12, 3), (13, 3), (0, 4)] {
            let want: Vec<(usize, usize)> =
                parallel_map_chunks(n, threads, |r| (r.start, r.end));
            let chunk = chunk_len(n, threads);
            let data: Vec<usize> = (0..n).collect();
            let got: Vec<(usize, usize)> = data
                .chunks(chunk)
                .scan(0usize, |start, c| {
                    let s = *start;
                    *start += c.len();
                    Some((s, s + c.len()))
                })
                .collect();
            assert_eq!(got, want, "n={} threads={}", n, threads);
        }
    }

    #[test]
    fn try_jobs_single_runs_inline() {
        let caller = thread::current().id();
        let mut ran_on = None;
        {
            let slot = &mut ran_on;
            parallel_try_jobs([move || {
                *slot = Some(thread::current().id());
                Ok(())
            }])
            .unwrap();
        }
        assert_eq!(ran_on, Some(caller));
    }

    #[test]
    fn try_jobs_disjoint_chunks_fill_the_panel() {
        let n = 103usize;
        let threads = 4usize;
        let chunk = chunk_len(n, threads);
        let mut panel = vec![0usize; n];
        let jobs = panel.chunks_mut(chunk).enumerate().map(|(t, c)| {
            move || {
                for (i, v) in c.iter_mut().enumerate() {
                    *v = t * chunk + i + 1;
                }
                Ok(())
            }
        });
        parallel_try_jobs(jobs).unwrap();
        let want: Vec<usize> = (1..=n).collect();
        assert_eq!(panel, want);
    }

    #[test]
    fn try_jobs_first_error_in_job_order_wins() {
        let jobs: Vec<Box<dyn FnOnce() -> Result<()> + Send>> = vec![
            Box::new(|| Ok(())),
            Box::new(|| Err(anyhow::anyhow!("chunk 1 failed"))),
            Box::new(|| Err(anyhow::anyhow!("chunk 2 failed"))),
        ];
        let err = parallel_try_jobs(jobs).unwrap_err();
        assert!(err.to_string().contains("chunk 1 failed"), "{}", err);
    }

    #[test]
    fn try_jobs_empty_is_a_noop() {
        let jobs: [fn() -> Result<()>; 0] = [];
        parallel_try_jobs(jobs).unwrap();
    }

    #[test]
    fn panel_rows_counts_and_rejects_ragged_shapes() {
        assert_eq!(panel_rows(12, 4).unwrap(), 3);
        assert_eq!(panel_rows(0, 4).unwrap(), 0);
        assert_eq!(panel_rows(0, 0).unwrap(), 0);
        let err = panel_rows(13, 4).unwrap_err();
        assert!(err.to_string().contains("ragged panel"), "{}", err);
        let err = panel_rows(3, 0).unwrap_err();
        assert!(err.to_string().contains("ragged panel"), "{}", err);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = data.iter().sum();
        let par: f64 = parallel_map_chunks(data.len(), 7, |r| {
            data[r].iter().sum::<f64>()
        })
        .iter()
        .sum();
        assert!((serial - par).abs() < 1e-9);
    }
}
