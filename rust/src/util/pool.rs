//! Worker thread pool + scoped parallel map (no rayon/tokio offline).
//!
//! Two tools:
//! * [`ThreadPool`] — long-lived workers consuming boxed jobs from a shared
//!   queue; used by the coordinator for replication fan-out.
//! * [`parallel_map_chunks`] — scoped data-parallel helper for the
//!   `native_par` ablation backend: splits an index range over N threads and
//!   merges results in order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool with a `join`-style barrier.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// `n == 0` is clamped to 1.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok(Msg::Run(job)) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cv.notify_all();
                        }
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            }));
        }
        ThreadPool { tx, handles, pending }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Submit a job; it may run on any worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx.send(Msg::Run(Box::new(f))).expect("pool workers alive");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Scoped parallel map over an index range: calls `f(i)` for `i in 0..n`
/// across `threads` OS threads and returns the results in index order.
///
/// `f` only needs `Sync` borrows — perfect for read-only panels.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // Safety-free: disjoint index writes guarded by the mutex.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|o| o.expect("all indices computed")).collect()
}

/// Split `0..n` into contiguous chunks, run `f(chunk_range)` per thread, and
/// return per-chunk results in order — the shape reductions want.
pub fn parallel_map_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let chunk = n.div_ceil(threads);
    let ranges: Vec<_> = (0..threads)
        .map(|t| (t * chunk).min(n)..((t + 1) * chunk).min(n))
        .filter(|r| !r.is_empty())
        .collect();
    let mut out: Vec<Option<T>> = (0..ranges.len()).map(|_| None).collect();
    thread::scope(|s| {
        for (slot, r) in out.iter_mut().zip(ranges) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(r));
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn join_then_more_work() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn zero_workers_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<_> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_empty() {
        let got: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn parallel_map_chunks_covers_range() {
        let chunks = parallel_map_chunks(103, 4, |r| r.len());
        assert_eq!(chunks.iter().sum::<usize>(), 103);
    }

    #[test]
    fn parallel_map_chunks_single_thread() {
        let chunks = parallel_map_chunks(10, 1, |r| (r.start, r.end));
        assert_eq!(chunks, vec![(0, 10)]);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = data.iter().sum();
        let par: f64 = parallel_map_chunks(data.len(), 7, |r| {
            data[r].iter().sum::<f64>()
        })
        .iter()
        .sum();
        assert!((serial - par).abs() < 1e-9);
    }
}
