//! Declarative command-line parsing (the offline registry carries no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! flags, positional arguments, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Kind {
    Value,
    Bool,
}

#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    kind: Kind,
    default: Option<&'static str>,
    help: &'static str,
}

/// A declarative flag set for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    cmd: String,
    about: &'static str,
    specs: Vec<FlagSpec>,
    values: BTreeMap<&'static str, Vec<String>>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn new(cmd: &str, about: &'static str) -> Self {
        Args { cmd: cmd.to_string(), about, ..Default::default() }
    }

    /// Declare a value flag with an optional default.
    pub fn flag(mut self, name: &'static str, default: Option<&'static str>,
                help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, kind: Kind::Value, default, help });
        self
    }

    /// Declare a boolean flag (present = true).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, kind: Kind::Bool, default: None, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}\n\nUSAGE: simopt {} [FLAGS]", self.about, self.cmd);
        for sp in &self.specs {
            let d = sp.default.map(|d| format!(" [default: {}]", d)).unwrap_or_default();
            let _ = writeln!(out, "  --{:<18} {}{}", sp.name, sp.help, d);
        }
        out
    }

    /// Parse a raw argument list (not including the program/subcommand name).
    pub fn parse(mut self, raw: &[String]) -> Result<Self, CliError> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{}\n\n{}", name, self.usage())))?
                    .clone();
                let val = match (spec.kind, inline) {
                    (Kind::Bool, None) => "true".to_string(),
                    (Kind::Bool, Some(v)) => v,
                    (Kind::Value, Some(v)) => v,
                    (Kind::Value, None) => {
                        i += 1;
                        raw.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{} needs a value", name)))?
                    }
                };
                self.values.entry(spec.name).or_default().push(val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    // -- typed getters -------------------------------------------------------

    pub fn get(&self, name: &'static str) -> Option<String> {
        if let Some(vs) = self.values.get(name) {
            return vs.last().cloned();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.map(|d| d.to_string()))
    }

    pub fn get_usize(&self, name: &'static str) -> Result<usize, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("missing --{}", name)))?;
        v.parse().map_err(|_| CliError(format!("--{} expects an integer, got '{}'", name, v)))
    }

    pub fn get_u64(&self, name: &'static str) -> Result<u64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("missing --{}", name)))?;
        v.parse().map_err(|_| CliError(format!("--{} expects an integer, got '{}'", name, v)))
    }

    pub fn get_f64(&self, name: &'static str) -> Result<f64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("missing --{}", name)))?;
        v.parse().map_err(|_| CliError(format!("--{} expects a number, got '{}'", name, v)))
    }

    pub fn get_bool(&self, name: &'static str) -> bool {
        self.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// Comma-separated list of integers, e.g. `--sizes 128,512`.
    pub fn get_usize_list(&self, name: &'static str) -> Result<Vec<usize>, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("missing --{}", name)))?;
        v.split(',')
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{}: bad integer '{}'", name, t)))
            })
            .collect()
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, name: &'static str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').filter(|t| !t.is_empty()).map(|t| t.trim().to_string()).collect())
            .unwrap_or_default()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("run", "run one experiment")
            .flag("size", Some("128"), "problem dimension")
            .flag("sizes", None, "comma list")
            .switch("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&raw(&[])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 128);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = spec().parse(&raw(&["--size", "512"])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 512);
        let a = spec().parse(&raw(&["--size=2048"])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 2048);
    }

    #[test]
    fn bool_switch() {
        let a = spec().parse(&raw(&["--verbose"])).unwrap();
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn last_flag_wins() {
        let a = spec().parse(&raw(&["--size", "1", "--size", "2"])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 2);
    }

    #[test]
    fn usize_list() {
        let a = spec().parse(&raw(&["--sizes", "128, 512,2048"])).unwrap();
        assert_eq!(a.get_usize_list("sizes").unwrap(), vec![128, 512, 2048]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(spec().parse(&raw(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(spec().parse(&raw(&["--size"])).is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = spec().parse(&raw(&["--size", "abc"])).unwrap();
        assert!(a.get_usize("size").is_err());
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse(&raw(&["pos1", "--size", "4", "pos2"])).unwrap();
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = spec().parse(&raw(&["--help"])).unwrap_err();
        assert!(e.0.contains("USAGE"));
        assert!(e.0.contains("--size"));
    }
}
