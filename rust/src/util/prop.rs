//! Miniature property-based testing harness (the offline registry has no
//! proptest).  Deterministic SplitMix64 generator, configurable case count,
//! and greedy size-shrinking for failures.
//!
//! ```no_run
//! # // no_run: doctest binaries skip the workspace rpath flags and cannot
//! # // find libstdc++ (pulled in via the xla native deps) at load time.
//! use simopt::util::prop::check;
//! check("reverse twice is identity", 200,
//!       |g| g.vec_f64(0..32, -1e3..1e3),
//!       |v| {
//!           let mut r = v.clone();
//!           r.reverse();
//!           r.reverse();
//!           r == *v
//!       });
//! ```

use std::fmt::Debug;
use std::ops::Range;

/// Deterministic generator handed to case builders.
pub struct Gen {
    state: u64,
    /// Current size bound in [0,1]; shrinking retries lower it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9E3779B97F4A7C15), size: 1.0 }
    }

    fn next_u64(&mut self) -> u64 {
        // SplitMix64
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn u64_in(&mut self, r: Range<u64>) -> u64 {
        let span = r.end - r.start;
        if span == 0 {
            return r.start;
        }
        r.start + self.next_u64() % span
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.u64_in(r.start as u64..r.end as u64) as usize
    }

    /// Unit uniform in [0,1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        r.start + self.unit() * (r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.f64_in(r.start as f64..r.end as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Length scaled by the current shrink size.
    pub fn len_in(&mut self, r: Range<usize>) -> usize {
        let hi = r.start + (((r.end - r.start) as f64) * self.size).ceil() as usize;
        self.usize_in(r.start..hi.max(r.start + 1).min(r.end))
    }

    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.len_in(len);
        (0..n).map(|_| self.f64_in(vals.clone())).collect()
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.len_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0..items.len())]
    }
}

/// Run `cases` random cases of `prop` over inputs from `make`.
///
/// On failure, retries the same seed at smaller `size` bounds to report a
/// smaller counterexample, then panics with the case and seed.
pub fn check<T: Debug>(
    name: &str,
    cases: u64,
    make: impl Fn(&mut Gen) -> T,
    prop: impl Fn(&T) -> bool,
) {
    for seed in 0..cases {
        let mut g = Gen::new(seed);
        let input = make(&mut g);
        if !prop(&input) {
            // greedy shrink: same seed, smaller size budget
            let mut smallest = input;
            for step in 1..=4 {
                let mut g = Gen::new(seed);
                g.size = 1.0 / (1 << step) as f64;
                let candidate = make(&mut g);
                if !prop(&candidate) {
                    smallest = candidate;
                }
            }
            panic!(
                "property '{}' failed (seed {}):\n  counterexample: {:?}",
                name, seed, smallest
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64_in(0..1000), b.u64_in(0..1000));
        }
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let x = g.f64_in(-2.0..3.0);
            assert!((-2.0..3.0).contains(&x));
            let n = g.usize_in(5..10);
            assert!((5..10).contains(&n));
        }
    }

    #[test]
    fn unit_in_zero_one() {
        let mut g = Gen::new(3);
        for _ in 0..1000 {
            let u = g.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn vec_lengths_in_range() {
        let mut g = Gen::new(2);
        for _ in 0..100 {
            let v = g.vec_f32(3..17, 0.0..1.0);
            assert!((3..17).contains(&v.len()));
        }
    }

    #[test]
    fn passing_property_passes() {
        check("abs is nonnegative", 100, |g| g.f64_in(-5.0..5.0), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_case() {
        check("always fails", 10, |g| g.usize_in(0..5), |_| false);
    }

    #[test]
    fn shrink_reports_smaller_case() {
        // Property fails for vectors longer than 8; the shrink pass should
        // find one not larger than the original.
        let result = std::panic::catch_unwind(|| {
            check("len<=8", 50, |g| g.vec_f64(0..64, 0.0..1.0), |v| v.len() <= 8)
        });
        assert!(result.is_err());
    }
}
