//! Standard-library-only substrates.
//!
//! The build environment resolves crates from an offline registry that only
//! carries the `xla` crate and its build dependencies, so the conveniences a
//! production service would usually pull in (serde, clap, rayon, criterion,
//! proptest) are implemented here from scratch:
//!
//! * [`json`] — JSON parser/writer (artifact manifest, result files)
//! * [`cli`] — declarative command-line parsing
//! * [`pool`] — worker thread pool + scoped parallel map
//! * [`stats`] — streaming moments, confidence intervals, RSE traces
//! * [`prop`] — miniature property-based testing harness
//! * [`timer`] — monotonic timing helpers used by the bench harness
//! * [`profile`] — the always-on per-phase profiler (DESIGN.md §15)
//! * [`log`] — leveled structured (key=value) stderr logger (§18)
//! * [`trace`] — request-scoped trace ids, spans, Chrome-JSONL export
//!   (§18)

pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod profile;
pub mod prop;
pub mod stats;
pub mod timer;
pub mod trace;
