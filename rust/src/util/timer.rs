//! Monotonic timing helpers shared by the coordinator and bench harness.

use std::sync::OnceLock;
use std::time::Instant;

/// Process-wide monotonic microseconds since the first call.  This is the
/// one clock the tracing spans (DESIGN.md §18), the queue's enqueue
/// timestamps, and the structured log prefix all share, so intervals
/// recorded on different threads are directly comparable.
pub fn monotonic_us() -> u64 {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    let origin = *ORIGIN.get_or_init(Instant::now);
    origin.elapsed().as_micros() as u64
}

/// Simple scope timer returning elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Human-readable duration: picks ns/µs/ms/s.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 7 * 6);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn restart_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let e = t.restart();
        assert!(e > 0.0);
        assert!(t.elapsed_s() < e + 1.0);
    }

    #[test]
    fn monotonic_us_never_goes_backward() {
        let a = monotonic_us();
        let b = monotonic_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let c = monotonic_us();
        assert!(b >= a);
        assert!(c > a, "2ms of sleep must advance the µs clock");
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
        assert!(fmt_duration(300.0).ends_with("min"));
    }
}
