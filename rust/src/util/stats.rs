//! Statistics for the paper's reporting conventions: mean ± 2σ confidence
//! bands (Figure 2), and the relative-squared-error trace of Table 2.

/// Streaming mean/variance (Welford).  Numerically stable for long traces.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator); 0 for fewer than two points.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// The paper's Figure-2 band: mean ± 2σ.
    pub fn band2(&self) -> (f64, f64) {
        (self.mean - 2.0 * self.std(), self.mean + 2.0 * self.std())
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Relative squared error exactly as the paper defines under Table 2:
/// RSE = ((y_t − y*) / y_t)² × 100 [percent].
pub fn rse_percent(y_t: f64, y_star: f64) -> f64 {
    if y_t == 0.0 {
        return f64::NAN;
    }
    let r = (y_t - y_star) / y_t;
    r * r * 100.0
}

/// RSE trace for a whole objective trajectory against its final value.
pub fn rse_trace(objs: &[f64]) -> Vec<f64> {
    if objs.is_empty() {
        return Vec::new();
    }
    let y_star = *objs.last().unwrap();
    objs.iter().map(|&y| rse_percent(y, y_star)).collect()
}

/// Index into a trace at a checkpoint, clamping to the last entry (used when
/// a run is shorter than the paper's 10 000-step convention).
pub fn at_checkpoint(trace: &[f64], it: usize) -> f64 {
    if trace.is_empty() {
        return f64::NAN;
    }
    trace[it.min(trace.len() - 1)]
}

/// Format `mean (± 2σ)` the way Table 2 prints cells.
pub fn fmt_pm(mean: f64, std: f64) -> String {
    format!("{:.2}% (±{:.2}%)", mean, 2.0 * std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.count(), 5);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 10.0);
    }

    #[test]
    fn online_single_point() {
        let mut o = OnlineStats::new();
        o.push(42.0);
        assert_eq!(o.mean(), 42.0);
        assert_eq!(o.var(), 0.0);
    }

    #[test]
    fn band_is_symmetric() {
        let mut o = OnlineStats::new();
        for x in [1.0, 3.0] {
            o.push(x);
        }
        let (lo, hi) = o.band2();
        assert!((hi + lo - 2.0 * o.mean()).abs() < 1e-12);
        assert!(hi > lo);
    }

    #[test]
    fn rse_definition() {
        // y_t = 2, y* = 1 → ((2-1)/2)^2 = 0.25 → 25%
        assert!((rse_percent(2.0, 1.0) - 25.0).abs() < 1e-12);
        // converged point has zero RSE
        assert_eq!(rse_percent(5.0, 5.0), 0.0);
        assert!(rse_percent(0.0, 1.0).is_nan());
    }

    #[test]
    fn rse_trace_ends_at_zero() {
        let objs = [10.0, 5.0, 2.0, 1.0];
        let t = rse_trace(&objs);
        assert_eq!(t.len(), 4);
        assert_eq!(*t.last().unwrap(), 0.0);
        assert!(t[0] > t[2]);
    }

    #[test]
    fn checkpoint_clamps() {
        let t = [4.0, 3.0, 2.0];
        assert_eq!(at_checkpoint(&t, 1), 3.0);
        assert_eq!(at_checkpoint(&t, 99), 2.0);
        assert!(at_checkpoint(&[], 0).is_nan());
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert!(rse_trace(&[]).is_empty());
    }

    #[test]
    fn fmt_table2_cell() {
        assert_eq!(fmt_pm(85.07, 4.87), "85.07% (±9.74%)");
    }
}
