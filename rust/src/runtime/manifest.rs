//! `artifacts/manifest.json` schema: the typed contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unsupported dtype '{}' in manifest", other),
        })
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// One input or output tensor signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1) // scalar () → 1
    }

    fn from_json(v: &Value) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .context("io entry missing 'name'")?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Value::as_arr)
            .context("io entry missing 'shape'")?
            .iter()
            .map(|d| d.as_usize().context("shape dim must be a nonneg int"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            v.get("dtype").and_then(Value::as_str).context("io missing dtype")?,
        )?;
        Ok(IoSpec { name, shape, dtype })
    }
}

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub entry: String,
    pub task: String,
    pub file: String,
    pub params: BTreeMap<String, i64>,
    /// Whether the program returns a result tuple (aot.py default) or a
    /// bare single output (device-resident chaining, see runtime docs).
    pub tuple_output: bool,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let get_str = |k: &str| -> Result<String> {
            Ok(v.get(k)
                .and_then(Value::as_str)
                .with_context(|| format!("artifact missing '{}'", k))?
                .to_string())
        };
        let mut params = BTreeMap::new();
        if let Some(p) = v.get("params").and_then(Value::as_obj) {
            for (k, pv) in p {
                params.insert(
                    k.clone(),
                    pv.as_i64().with_context(|| format!("param '{}' not an int", k))?,
                );
            }
        }
        let ios = |k: &str| -> Result<Vec<IoSpec>> {
            v.get(k)
                .and_then(Value::as_arr)
                .with_context(|| format!("artifact missing '{}'", k))?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        Ok(ArtifactMeta {
            name: get_str("name")?,
            entry: get_str("entry")?,
            task: get_str("task")?,
            file: get_str("file")?,
            params,
            tuple_output: v
                .get("tuple_output")
                .and_then(Value::as_bool)
                .unwrap_or(true),
            inputs: ios("inputs")?,
            outputs: ios("outputs")?,
        })
    }

    /// Does this artifact match every (key, value) requirement?
    pub fn matches(&self, entry: &str, reqs: &[(&str, i64)]) -> bool {
        self.entry == entry
            && reqs.iter().all(|(k, v)| self.params.get(*k) == Some(v))
    }
}

/// The parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = Value::parse(text).context("manifest.json is not valid JSON")?;
        let artifacts = root
            .get("artifacts")
            .and_then(Value::as_arr)
            .context("manifest missing 'artifacts' array")?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, artifacts })
    }

    /// First artifact matching `entry` + param requirements.
    pub fn find(&self, entry: &str, reqs: &[(&str, i64)]) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.matches(entry, reqs))
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All values of integer parameter `key` available for `entry`, sorted —
    /// how the sweep CLI discovers which sizes were AOT-compiled.
    pub fn available_params(&self, entry: &str, key: &str) -> Vec<i64> {
        let mut out: Vec<i64> = self
            .artifacts
            .iter()
            .filter(|a| a.entry == entry)
            .filter_map(|a| a.params.get(key).copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "mv_epoch_d128_n64_m25", "entry": "mv_epoch",
         "task": "mean_variance", "file": "mv_epoch_d128_n64_m25.hlo.txt",
         "params": {"d": 128, "n": 64, "m": 25},
         "inputs": [
           {"name": "w", "shape": [128], "dtype": "f32"},
           {"name": "key", "shape": [2], "dtype": "u32"},
           {"name": "k_epoch", "shape": [], "dtype": "i32"}],
         "outputs": [
           {"name": "w_out", "shape": [128], "dtype": "f32"},
           {"name": "obj", "shape": [], "dtype": "f32"}]},
        {"name": "mv_epoch_d512_n64_m25", "entry": "mv_epoch",
         "task": "mean_variance", "file": "mv_epoch_d512_n64_m25.hlo.txt",
         "params": {"d": 512, "n": 64, "m": 25},
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts[0];
        assert_eq!(a.entry, "mv_epoch");
        assert_eq!(a.params["d"], 128);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![128]);
        assert_eq!(a.inputs[1].dtype, Dtype::U32);
        assert_eq!(a.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[2].elements(), 1);
    }

    #[test]
    fn find_by_params() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.find("mv_epoch", &[("d", 128)]).is_some());
        assert!(m.find("mv_epoch", &[("d", 512), ("n", 64)]).is_some());
        assert!(m.find("mv_epoch", &[("d", 999)]).is_none());
        assert!(m.find("nv_grad", &[]).is_none());
    }

    #[test]
    fn available_params_sorted() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.available_params("mv_epoch", "d"), vec![128, 512]);
        assert!(m.available_params("nv_grad", "d").is_empty());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a/b")).unwrap();
        let p = m.hlo_path(&m.artifacts[0]);
        assert_eq!(p, PathBuf::from("/a/b/mv_epoch_d128_n64_m25.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
        let bad_dtype = r#"{"artifacts":[{"name":"x","entry":"e","task":"t",
            "file":"f","params":{},
            "inputs":[{"name":"a","shape":[1],"dtype":"f64"}],
            "outputs":[]}]}"#;
        assert!(Manifest::parse(bad_dtype, PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercised against the actual artifacts when they exist (CI runs
        // `make artifacts` first).
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(!m.artifacts.is_empty());
            assert!(!m.available_params("mv_epoch", "d").is_empty());
        }
    }
}
