//! PJRT execution engine: compile-once cache + typed, shape-checked calls.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactMeta, Dtype, Manifest};

/// A typed argument to an artifact call.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    U32(&'a [u32]),
    I32(&'a [i32]),
    ScalarI32(i32),
    ScalarF32(f32),
}

impl<'a> Arg<'a> {
    fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(_) | Arg::ScalarF32(_) => Dtype::F32,
            Arg::U32(_) => Dtype::U32,
            Arg::I32(_) | Arg::ScalarI32(_) => Dtype::I32,
        }
    }

    fn elements(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::U32(v) => v.len(),
            Arg::I32(v) => v.len(),
            Arg::ScalarI32(_) | Arg::ScalarF32(_) => 1,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                shape,
                bytes_of(v),
            )?,
            Arg::U32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U32,
                shape,
                bytes_of(v),
            )?,
            Arg::I32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                shape,
                bytes_of(v),
            )?,
            Arg::ScalarI32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                shape,
                &v.to_le_bytes(),
            )?,
            Arg::ScalarF32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                shape,
                &v.to_le_bytes(),
            )?,
        };
        Ok(lit)
    }
}

fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: plain-old-data reinterpretation for f32/u32 slices.
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

/// A device-resident buffer pinned to its source literal (PJRT host→device
/// transfers are asynchronous; dropping the literal early is a
/// use-after-free).
pub struct DeviceBuf {
    buf: xla::PjRtBuffer,
    _lit: xla::Literal,
}

impl DeviceBuf {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

/// A buffer-or-host argument for the device-resident call path.
pub enum BufArg<'a> {
    /// Host data, uploaded for this call.
    Host(Arg<'a>),
    /// An existing device buffer (e.g. a prior upload) — no host↔device
    /// traffic.
    Dev(&'a DeviceBuf),
}

/// One compiled artifact, callable with shape-checked arguments.
pub struct Exec {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Exec {
    fn check_args(&self, n_args: usize) -> Result<()> {
        if n_args != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                n_args
            );
        }
        Ok(())
    }

    fn check_host_arg(&self, pos: usize, arg: &Arg) -> Result<()> {
        let spec = &self.meta.inputs[pos];
        if arg.dtype() != spec.dtype {
            bail!(
                "{}: input '{}' expects {:?}, got {:?}",
                self.meta.name, spec.name, spec.dtype, arg.dtype()
            );
        }
        if arg.elements() != spec.elements() {
            bail!(
                "{}: input '{}' expects {} elements (shape {:?}), got {}",
                self.meta.name, spec.name, spec.elements(), spec.shape,
                arg.elements()
            );
        }
        Ok(())
    }

    /// Upload host data as a device buffer shaped like input `pos` of this
    /// artifact (for long-lived constants: cost vectors, datasets, ...).
    ///
    /// `buffer_from_host_literal` is asynchronous: the source literal must
    /// stay alive until the transfer completes (the crate exposes no await
    /// hook).  [`DeviceBuf`] pins the literal for the buffer's lifetime.
    pub fn upload(&self, pos: usize, arg: Arg) -> Result<DeviceBuf> {
        self.check_host_arg(pos, &arg)?;
        let lit = arg.to_literal(&self.meta.inputs[pos].shape)?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(DeviceBuf { buf, _lit: lit })
    }

    fn unpack_outputs(&self, bufs: Vec<Vec<xla::PjRtBuffer>>)
        -> Result<Vec<xla::Literal>> {
        let mut first = bufs
            .into_iter()
            .next()
            .context("no device output")?
            .into_iter()
            .next()
            .context("no buffer output")?
            .to_literal_sync()?;
        let outs = if self.meta.tuple_output {
            first.decompose_tuple()?
        } else {
            vec![first]
        };
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with typed host args; returns one `Literal` per output.
    pub fn call(&self, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        self.check_args(args.len())?;
        let mut literals = Vec::with_capacity(args.len());
        for (pos, arg) in args.iter().enumerate() {
            self.check_host_arg(pos, arg)?;
            literals.push(arg.to_literal(&self.meta.inputs[pos].shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        self.unpack_outputs(result)
    }

    /// Execute with a mix of host args (uploaded per call) and resident
    /// device buffers; outputs come back to the host.
    ///
    /// PJRT execution is asynchronous: the per-call uploads must stay alive
    /// until the outputs have been materialized (`to_literal_sync` blocks on
    /// the computation), so `_owned` is dropped only after unpacking.
    pub fn call_b(&self, args: &[BufArg]) -> Result<Vec<xla::Literal>> {
        let (result, _owned) = self.execute_mixed(args)?;
        let outs = self.unpack_outputs(result)?;
        Ok(outs)
    }

    /// Raw `execute_b` passthrough (debug/bench instrumentation).
    pub fn raw_execute_b(&self, bufs: &[&xla::PjRtBuffer])
        -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute_b::<&xla::PjRtBuffer>(bufs)?)
    }

    fn execute_mixed(&self, args: &[BufArg])
        -> Result<(Vec<Vec<xla::PjRtBuffer>>, Vec<DeviceBuf>)> {
        self.check_args(args.len())?;
        // Per-call uploads live here — returned to the caller so they
        // outlive the (asynchronous) computation.
        let mut owned: Vec<DeviceBuf> = Vec::new();
        let mut order: Vec<(bool, usize)> = Vec::with_capacity(args.len());
        for (pos, arg) in args.iter().enumerate() {
            match arg {
                BufArg::Host(a) => {
                    self.check_host_arg(pos, a)?;
                    let lit = a.to_literal(&self.meta.inputs[pos].shape)?;
                    let buf = self.client.buffer_from_host_literal(None, &lit)?;
                    owned.push(DeviceBuf { buf, _lit: lit });
                    order.push((true, owned.len() - 1));
                }
                BufArg::Dev(_) => order.push((false, pos)),
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|&(is_owned, i)| {
                if is_owned {
                    &owned[i].buf
                } else {
                    match args[i] {
                        BufArg::Dev(b) => b.buffer(),
                        _ => unreachable!(),
                    }
                }
            })
            .collect();
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        drop(refs);
        Ok((result, owned))
    }

    /// Convenience: call and convert every output to `Vec<f32>`.
    pub fn call_f32(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        self.call(args)?
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// Literal → Vec<f32> helper.
pub fn f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Literal → f32 scalar helper.
pub fn f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Compile-once artifact engine over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load (compile-once) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let meta = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact '{}' not in manifest", name))?
            .clone();
        let path = self.manifest.hlo_path(&meta);
        let path_str = path
            .to_str()
            .context("artifact path is not valid UTF-8")?;
        anyhow::ensure!(
            path.exists(),
            "artifact file {} missing — re-run `make artifacts`",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let exec = Rc::new(Exec { meta, exe, client: self.client.clone() });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&exec));
        Ok(exec)
    }

    /// Load by (entry, param requirements), e.g. `("mv_epoch", &[("d", 128)])`.
    pub fn load_by_params(&self, entry: &str, reqs: &[(&str, i64)])
        -> Result<Rc<Exec>> {
        let meta = self.manifest.find(entry, reqs).with_context(|| {
            format!(
                "no artifact for entry '{}' with params {:?}; available: {:?} — \
                 re-run `make artifacts` (or aot.py with --mv-dims/--nv-dims/--lr-dims)",
                entry,
                reqs,
                self.manifest
                    .artifacts
                    .iter()
                    .filter(|a| a.entry == entry)
                    .map(|a| &a.name)
                    .collect::<Vec<_>>()
            )
        })?;
        let name = meta.name.clone();
        self.load(&name)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

// No `Send`/`Sync`: the underlying PJRT handles are raw pointers.  The
// coordinator schedules all XLA jobs on the thread owning the Engine; the
// CPU PJRT runtime itself multithreads the compute internally.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_shapes_and_dtypes() {
        let v = [1.0f32, 2.0];
        let a = Arg::F32(&v);
        assert_eq!(a.dtype(), Dtype::F32);
        assert_eq!(a.elements(), 2);
        assert_eq!(Arg::ScalarI32(5).elements(), 1);
        assert_eq!(Arg::ScalarI32(5).dtype(), Dtype::I32);
        let k = [1u32, 2];
        assert_eq!(Arg::U32(&k).dtype(), Dtype::U32);
    }

    #[test]
    fn bytes_of_roundtrip() {
        let v = [1.0f32, -2.5];
        let b = bytes_of(&v);
        assert_eq!(b.len(), 8);
        assert_eq!(f32::from_le_bytes(b[0..4].try_into().unwrap()), 1.0);
        assert_eq!(f32::from_le_bytes(b[4..8].try_into().unwrap()), -2.5);
    }

    // Engine-level integration tests live in rust/tests/integration_runtime.rs
    // (they need the artifacts directory and a PJRT client).
}
