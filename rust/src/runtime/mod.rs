//! The AOT bridge: load `artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client, and expose
//! typed, shape-checked execution to the backends.
//!
//! Interchange is HLO **text** (see DESIGN.md §6): jax ≥ 0.5 serializes
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids.
//!
//! ```no_run
//! use simopt::runtime::{Engine, Arg};
//! let engine = Engine::new("artifacts").unwrap();
//! let exec = engine.load_by_params("mv_epoch", &[("d", 128)]).unwrap();
//! ```

pub mod exec;
pub mod manifest;

pub use exec::{Arg, BufArg, DeviceBuf, Engine, Exec};
pub use manifest::{ArtifactMeta, Dtype, IoSpec, Manifest};
